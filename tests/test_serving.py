"""Serving engines: compiled generate == step-by-step decode; EOS freezing;
mixed-prompt-length compile cache; continuous batching == static generates."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model, init_params
from repro.serving import ContinuousEngine, Engine, Scheduler, ServeConfig

KEY = jax.random.PRNGKey(0)


def _manual_greedy(model, params, prompts, new):
    """Reference decode: prefill + explicit per-step decode_fn calls."""
    S = prompts.shape[1]
    logits, cache = jax.jit(functools.partial(model.prefill_fn, pad_to=S + new + 1))(
        params, {"tokens": prompts}
    )
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    out = []
    for i in range(new):
        out.append(np.asarray(cur))
        logits, cache = jax.jit(model.decode_fn)(params, cache, cur, jnp.int32(S + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(out, 1)


def test_engine_greedy_matches_manual_decode():
    cfg = configs.get_smoke("tinyllama_1_1b")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S, NEW = 2, 32, 6
    prompts = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    eng = Engine(model, ServeConfig(max_new=NEW, temperature=0.0))
    toks = np.asarray(eng.generate(params, {"tokens": prompts}))
    np.testing.assert_array_equal(toks, _manual_greedy(model, params, prompts, NEW))


def test_engine_mixed_prompt_lengths_use_correct_positions():
    """Regression: the compiled generate used to be cached keyed on nothing, so
    a second call with a different prompt length decoded at the first call's
    positions. Both lengths must match the manual reference."""
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    NEW = 4
    eng = Engine(model, ServeConfig(max_new=NEW, temperature=0.0))
    for S in (16, 24):
        prompts = jax.random.randint(jax.random.PRNGKey(S), (2, S), 0, cfg.vocab)
        toks = np.asarray(eng.generate(params, {"tokens": prompts}))
        np.testing.assert_array_equal(
            toks, _manual_greedy(model, params, prompts, NEW)
        )
    assert len(eng._gen) == 2  # one compiled program per prompt shape


def test_engine_eos_freezes_sequences():
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S = 2, 16
    prompts = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    # pick the first greedily generated token as "EOS" so it triggers immediately
    eng0 = Engine(model, ServeConfig(max_new=4, temperature=0.0))
    first = int(np.asarray(eng0.generate(params, {"tokens": prompts}))[0, 0])
    eng = Engine(model, ServeConfig(max_new=6, temperature=0.0, eos_id=first))
    toks = np.asarray(eng.generate(params, {"tokens": prompts}))
    row = toks[0]
    hit = np.where(row == first)[0]
    assert hit.size > 0
    assert (row[hit[0]:] == first).all()  # frozen after EOS


def test_continuous_matches_static_on_mixed_length_trace():
    """A mixed-length request trace through the scheduler must yield greedy
    outputs token-identical to per-request static generates, with one prefill
    compile per length bucket and one shared step program."""
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    NEW = 4
    scfg = ServeConfig(max_new=NEW, temperature=0.0)
    lengths = [8, 12, 8, 16, 12, 8]
    prompts = [
        jax.random.randint(jax.random.PRNGKey(10 + i), (1, L), 0, cfg.vocab)
        for i, L in enumerate(lengths)
    ]
    static = Engine(model, scfg)
    want = [np.asarray(static.generate(params, {"tokens": p}))[0] for p in prompts]

    eng = ContinuousEngine(model, scfg, num_slots=2, max_prompt_len=16)
    sched = Scheduler(eng, params)
    rids = [sched.submit(p[0]) for p in prompts]
    results = sched.run(timeout=600)
    assert len(results) == len(prompts)
    for rid, w in zip(rids, want):
        got = sched.poll(rid)
        assert got is not None and got.finish_reason == "length"
        np.testing.assert_array_equal(np.asarray(got.tokens), w)
    # 3 length buckets -> 3 prefill compiles; admission/eviction never recompiles
    assert len(eng._prefill_sigs) == 3
    # 6 requests x 3 steps each on 2 slots => slots were reused mid-stream
    assert sched.steps < len(prompts) * (NEW - 1)


def test_chunked_prefill_matches_static():
    """Long prompts admitted chunk-by-chunk (fixed 8-token chunks interleaved
    with decode steps) must yield greedy outputs token-identical to the static
    per-request generates; full chunks share compiled programs across prompt
    lengths (only remainder chunks are per-length)."""
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    assert model.prefill_chunk_fn is not None  # dense decoder exposes chunking
    params = init_params(jax.random.PRNGKey(1), model.specs)
    NEW = 4
    scfg = ServeConfig(max_new=NEW, temperature=0.0)
    lengths = [8, 20, 26, 8, 20]
    prompts = [
        jax.random.randint(jax.random.PRNGKey(10 + i), (1, L), 0, cfg.vocab)
        for i, L in enumerate(lengths)
    ]
    static = Engine(model, scfg)
    want = [np.asarray(static.generate(params, {"tokens": p}))[0] for p in prompts]
    eng = ContinuousEngine(model, scfg, num_slots=2, max_prompt_len=26,
                           prefill_chunk=8)
    sched = Scheduler(eng, params)
    rids = [sched.submit(p[0]) for p in prompts]
    results = sched.run(timeout=600)
    assert len(results) == len(prompts)
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(np.asarray(sched.poll(rid).tokens), w)
    # chunking actually ran: len-8 prompts take the whole-prefill path (one
    # sig), longer prompts chunk — full chunks (0,8),(8,8),(16,8) shared,
    # remainders (16,4),(24,2) per-length
    assert len(eng._prefill_sigs) == 1
    assert sorted(eng._chunk_sigs) == [(0, 8), (8, 8), (16, 4), (16, 8), (24, 2)]


def test_admission_is_age_fair_across_buckets():
    """Regression: the old policy admitted from the oldest request's bucket
    until EMPTY, so under sustained long-prompt load a short prompt that
    arrived in between was starved. Age-fair admission re-picks the globally
    oldest pending request for each free slot."""
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    scfg = ServeConfig(max_new=3, temperature=0.0)
    eng = ContinuousEngine(model, scfg, num_slots=2, max_prompt_len=16)
    tick = iter(range(10_000))
    sched = Scheduler(eng, params, clock=lambda: float(next(tick)))
    long_p = [jax.random.randint(jax.random.PRNGKey(30 + i), (1, 16), 0, cfg.vocab)
              for i in range(3)]
    short_p = jax.random.randint(jax.random.PRNGKey(40), (1, 8), 0, cfg.vocab)
    r_long0 = sched.submit(long_p[0][0])   # t=0
    r_short = sched.submit(short_p[0])     # t=1
    r_long1 = sched.submit(long_p[1][0])   # t=2
    r_long2 = sched.submit(long_p[2][0])   # t=3
    sched.run()
    # the 2 slots must admit the two globally oldest first: long0 then short —
    # NOT long0+long1 (the old drain-the-oldest-bucket policy)
    t_admit = {r: sched.poll(r).t_admit for r in (r_long0, r_short, r_long1, r_long2)}
    assert t_admit[r_long0] < t_admit[r_short] < t_admit[r_long1] < t_admit[r_long2]


def test_continuous_eos_evicts_and_refills_slot():
    """EOS finishes a request early; the freed slot admits the next pending
    request while the other slot keeps decoding."""
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    prompts = [
        jax.random.randint(jax.random.PRNGKey(20 + i), (1, 8), 0, cfg.vocab)
        for i in range(3)
    ]
    # choose request 0's second greedy token as EOS so it finishes mid-decode
    probe = Engine(model, ServeConfig(max_new=2, temperature=0.0))
    eos = int(np.asarray(probe.generate(params, {"tokens": prompts[0]}))[0, 1])

    scfg = ServeConfig(max_new=6, temperature=0.0, eos_id=eos)
    eng = ContinuousEngine(model, scfg, num_slots=1, max_prompt_len=8)
    sched = Scheduler(eng, params)
    rids = [sched.submit(p[0]) for p in prompts]
    results = sched.run(timeout=600)
    assert len(results) == 3
    first = sched.poll(rids[0])
    assert first.finish_reason == "eos"
    assert first.tokens[-1] == eos and len(first.tokens) <= 6
    # every request matches its own static generate (trimmed after first EOS)
    static = Engine(model, scfg)
    for rid, p in zip(rids, prompts):
        got = sched.poll(rid)
        w = np.asarray(static.generate(params, {"tokens": p}))[0]
        np.testing.assert_array_equal(np.asarray(got.tokens), w[: len(got.tokens)])
        if got.finish_reason == "eos":  # static freezes to EOS past the finish
            assert (w[len(got.tokens):] == eos).all() if len(got.tokens) < 6 else True


def test_slot_leak_guard_evicts_requeues_and_drains():
    """Regression: a request that never finishes (decode loop that never hits
    EOS, or a backend bug) used to pin its slot forever — run() spun until the
    wall-clock timeout raised with the slot still held. With max_slot_steps
    the slot is force-evicted (freed + engine.on_evict), the request requeued
    at the head of its bucket up to max_requeues times, then failed with an
    'evicted' completion — the queue always drains."""
    import itertools
    import types

    from repro.serving import Completion, SlotScheduler
    from repro.serving.slotring import SlotRingEngine, slot_update

    class NeverEngine(SlotRingEngine):
        """Slots never finish on their own; records forced evictions."""

        def __init__(self):
            self.evicted = []
            super().__init__(num_slots=2)

        def init_state(self):
            return {"rid": jnp.zeros((2,), jnp.int32)}

        def _step_impl(self, params, state):
            return state, state["rid"]

        def _admit_impl(self, state, rid, slot):
            return slot_update(state, {"rid": rid}, slot)

        def on_evict(self, slot):
            self.evicted.append(slot)

    class NeverScheduler(SlotScheduler):
        def submit(self):
            rid = self._next_rid
            self._next_rid += 1
            self.buckets[0].append(
                types.SimpleNamespace(rid=rid, t_submit=self.clock()))
            return rid

        def _start_admission(self, req, slot):
            self.state = self.engine._admit_fn(
                self.state, jnp.int32(req.rid), jnp.int32(slot))
            self.running[slot] = (req, self.clock())
            return []

        def _collect(self, emitted):
            return []                  # nothing ever finishes normally

        def _fail_eviction(self, slot, record):
            req, t_admit = record
            return Completion(req.rid, [], "evicted", 0, req.t_submit,
                              t_admit, self.clock())

    def fake_clock(counter=itertools.count()):
        return float(next(counter))

    # ungated: the leak reproduces — run() can only time out
    leaky = NeverScheduler(NeverEngine(), None, fake_clock)
    leaky.submit()
    with pytest.raises(TimeoutError, match="did not drain"):
        leaky.run(timeout=50.0)
    assert 0 in leaky.running and 0 not in leaky.free  # slot still pinned

    # guard rejects a useless deadline
    with pytest.raises(ValueError, match="max_slot_steps"):
        NeverScheduler(NeverEngine(), None, fake_clock, max_slot_steps=0)

    # gated: both requests get evicted, requeued once, evicted again, failed
    eng = NeverEngine()
    sched = NeverScheduler(eng, None, fake_clock,
                           max_slot_steps=3, max_requeues=1)
    rids = [sched.submit(), sched.submit()]
    results = sched.run(timeout=10_000.0)
    assert sorted(results) == sorted(rids)
    assert all(results[r].finish_reason == "evicted" for r in rids)
    assert sched.steps == 6                  # 3 per attempt, 2 attempts
    assert len(eng.evicted) == 4             # 2 slots x 2 attempts
    assert not sched.running and sorted(sched.free) == [0, 1]
