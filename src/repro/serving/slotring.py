"""Backend-agnostic slot-ring core for continuous-batching serving.

A slot ring is a fixed number of resident request *slots* driven by ONE jitted
multi-slot step program and ONE jitted admission program.  The contract a
backend implements:

* ``init_state()`` returns a pytree whose leaves carry a leading ``num_slots``
  axis — per-slot caches / queries / RNG keys / flags stacked slot-major;
* ``_step_impl(params, state) -> (state, emitted)`` advances EVERY slot one
  step in a single compiled launch (empty slots compute harmlessly);
* admission overwrites one slot's rows via ``slot_update`` (per-leaf
  ``dynamic_update_slice``) — step-granular, never a recompile.

Two backends share this seam: the LM decode loop
(``repro.serving.engine.ContinuousEngine`` — one vmapped decode step per
emitted token) and the HDC similarity-search service
(``repro.serving.hdc.HDCEngine`` — one banked multi-tenant OTA serve launch
per step, every slot completing each step).  The request queue / admission
policy on top is ``repro.serving.scheduler.SlotScheduler`` and its backend
subclasses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slot_update(state, new, slot):
    """Write ``new`` — a pytree of per-slot values WITHOUT the slot axis — into
    row ``slot`` of the slot-stacked ``state`` (matching treedef, leading slot
    axes).  Scalars (next token, position, done flag) and arrays (cache rows,
    RNG keys, query batches) all go through the same per-leaf
    ``dynamic_update_slice``, so one compiled admit program covers the whole
    backend state."""

    def put(live, x):
        x = jnp.asarray(x, live.dtype)
        return jax.lax.dynamic_update_slice_in_dim(live, x[None], slot, axis=0)

    return jax.tree.map(put, state, new)


class SlotRingEngine:
    """Slot-ring base: owns the slot count and the jitted step/admit wrappers.

    Subclasses define the state pytree (``init_state``), the per-step compute
    (``_step_impl``) and the admission payload (``_admit_impl``); the base
    provides the single-compile discipline — ``self._step_fn`` and
    ``self._admit_fn`` are jitted ONCE here, so a stream of variable requests
    re-enters the same two programs for the life of the engine.
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self._step_fn = jax.jit(self._step_impl)
        self._admit_fn = jax.jit(self._admit_impl)
        self._variants: dict = {}

    # -- backend contract ----------------------------------------------------

    def init_state(self):
        """Slot-stacked state pytree (leading num_slots axis on every leaf)."""
        raise NotImplementedError

    def _step_impl(self, params, state):
        """(params, state) -> (state, emitted): one step for every slot."""
        raise NotImplementedError

    def _admit_impl(self, state, *payload):
        """Swap one request's payload into a slot (ends with the slot index)."""
        raise NotImplementedError

    # -- drive ---------------------------------------------------------------

    def step(self, params, state):
        """One step for every slot. Returns (state, per-slot emissions)."""
        return self._step_fn(params, state)

    def step_variant(self, key, build):
        """Compile-once-per-VARIANT step programs.

        Backends whose step can run in a small set of modes (e.g. the HDC
        link controller switching bundling width or collective) build each
        mode's program lazily through here: ``build()`` runs only on the
        first request for ``key``, after which switching between variants is
        a dict lookup — the slot state is shape-stable across variants by
        contract, so no admission or state rebuild is ever needed."""
        fn = self._variants.get(key)
        if fn is None:
            fn = self._variants[key] = build()
        return fn

    def on_barrier(self):
        """Hook run by the scheduler at each step barrier (the device-sync
        point of ``_collect``): the one safe place for host-side control
        decisions that retarget the NEXT step — the HDC `LinkController`
        re-fits/quarantines here. Default: no-op."""

    def on_evict(self, slot: int):
        """Hook run by the scheduler when it forcibly evicts ``slot`` (e.g.
        a deadline-expired request). The slot's stale state rows stay in
        place — by the slot-ring contract they compute harmlessly until the
        next admission overwrites them — so the default is a no-op; backends
        with per-slot host bookkeeping (caches, in-flight admissions) clean
        it up here."""
