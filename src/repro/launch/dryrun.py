import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell on
the production meshes and record roofline inputs.

The two lines above run before ANY other import — jax locks the device count on
first init, and the dry-run needs 512 placeholder CPU devices to build the
(2, 16, 16) multi-pod mesh. Smoke tests and benchmarks must NOT import this
module (they see the real single CPU device).

Per cell this produces benchmarks/artifacts/dryrun/<mesh>/<arch>__<cell>.json:
  * compiled.memory_analysis()  — bytes/device (proves the sharding fits or not);
  * normalized_cost_analysis()  — raw XLA numbers (scan bodies counted once);
  * analysis.hlo_cost.analyze() — trip-count-scaled per-device FLOPs / HBM bytes /
    collective bytes by type (the §Roofline inputs);
  * params, MODEL_FLOPS, timings.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --cell train_4k --multi-pod
  python -m repro.launch.dryrun --all --jobs 8          # full 40-cell sweep, both meshes
  python -m repro.launch.dryrun --arch hdc-scaleout --cell serve   # paper system
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun")


def lower_cell(arch: str, cell_name: str, multi_pod: bool, opt_kind: str = "adamw",
               flash_vjp: bool = True, uneven_heads: bool = False,
               capacity_factor: float | None = None, expand_kv: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.models import layers as _layers
    _layers.FLASH_CUSTOM_VJP = flash_vjp
    _layers.EXPAND_KV_EARLY = expand_kv
    _layers.FLASH_P_BF16 = bool(int(os.environ.get("REPRO_FLASH_P_BF16", "0")))
    _layers.REDUCE_BF16 = bool(int(os.environ.get("REPRO_REDUCE_BF16", "0")))
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat, configs
    from repro.analysis import hlo_cost, roofline
    from repro.configs.shapes import CELLS, cell_applicable, input_specs
    from repro.distributed.sharding import spec_for_shape, tree_shardings, use_rules
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_model
    from repro.models.base import count_params, param_axes, param_shapes
    from repro.train.loop import build_train_fns, merged_rules
    from repro.train.optimizer import OptConfig

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    if arch in ("hdc-scaleout", "hdc_scaleout"):
        return _lower_hdc(cell_name, mesh, chips, t0)

    cfg = configs.get_config(arch)
    model = get_model(cfg)
    cell = CELLS[cell_name]
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell_name, "status": "skipped", "why": why}

    if capacity_factor is not None and cfg.moe is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, capacity_factor=capacity_factor))
        model = get_model(cfg)
    kind, shapes, axes = input_specs(cfg, cell)
    rules = merged_rules(cfg)
    rules_act = rules
    if uneven_heads:
        # uneven (padded) sharding is legal for with_sharding_constraint inside
        # the program but not for jit in_shardings -> only activations get it.
        rules_act = dict(rules) | {"__uneven__": ("heads",)}
    p_shapes = param_shapes(model.specs)
    p_axes = param_axes(model.specs)
    n_params = count_params(model.specs)

    with compat.set_mesh(mesh), use_rules(rules_act):
        p_sh = tree_shardings(mesh, p_shapes, p_axes, rules)
        b_sh = {
            k: NamedSharding(mesh, spec_for_shape(axes[k], shapes[k].shape, rules, mesh))
            for k in shapes
        }
        if kind == "train":
            state_dtype = jnp.bfloat16 if n_params > 2e11 else jnp.float32
            opt = OptConfig(kind=opt_kind, state_dtype=state_dtype)
            fns = build_train_fns(model, mesh, opt, jit=False)
            key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
            _, o_struct = jax.eval_shape(fns.init, key_s)
            o_sh = fns.opt_shardings
            jitted = jax.jit(
                fns.step,
                in_shardings=(fns.param_shardings, o_sh, b_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_shapes, o_struct, shapes, key_s)
        elif kind == "prefill":
            jitted = jax.jit(model.prefill_fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_shapes, shapes)
        else:  # decode
            cache_shapes, cache_axes = model.cache_specs_fn(cell.batch, cell.seq)
            c_sh = tree_shardings(mesh, cache_shapes, cache_axes, rules)
            tok_sh = NamedSharding(mesh, spec_for_shape(("batch",), (cell.batch,), rules, mesh))
            jitted = jax.jit(
                model.decode_fn,
                in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                p_shapes, cache_shapes,
                jax.ShapeDtypeStruct((cell.batch,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat.normalized_cost_analysis(compiled)
    hc = hlo_cost.analyze(compiled.as_text())
    mf = roofline.model_flops(cfg, cell, n_params)
    rl = roofline.roofline_terms(hc.flops, hc.hbm_bytes, hc.coll_total, chips=1)  # per-device
    rec = {
        "arch": arch,
        "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "chips": chips,
        "params": n_params,
        "memory_analysis": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_size_in_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis_raw": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "hlo_per_device": {
            "flops": hc.flops,
            "hbm_bytes": hc.hbm_bytes,
            "collective": hc.collective,
            "raw_flops_single_trip": hc.raw_flops,
        },
        "model_flops_global": mf,
        "roofline_s": {
            "compute": rl.compute_s,
            "memory": rl.memory_s,
            "collective": rl.collective_s,
            "dominant": rl.dominant,
        },
        "useful_flops_ratio": mf / max(hc.flops * chips, 1.0),
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
    }
    return rec


def _lower_hdc(cell_name: str, mesh, chips: int, t0: float) -> dict:
    """Paper-system dry-run: OTA serve (+wired baseline) and HDC one-shot train."""
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.analysis import hlo_cost
    from repro.core import scaleout

    packed = cell_name.endswith("_packed")
    base = cell_name[: -len("_packed")] if packed else cell_name
    collective = {"serve_rsag": "rs_ag", "serve_psumpacked": "psum_packed"}.get(
        base, "psum"
    )
    # multi-tenant serve: 8 resident tenants x 8 slots of 512 trials each —
    # the same 4096-trial wire load as the single-tenant serve cells, issued
    # as ONE banked launch
    SLOTS = TENANTS = 8
    mt = base == "serve_hdc_multitenant"
    # ultra-sparse serve: million-dimension HVs at ~0.2% density — queries are
    # k_max sorted int32 index lists, the wire is the index_ag all-gather, the
    # prototype store stays packed. There is no _packed variant: sparse IS its
    # own representation (and its prototypes are always packed words).
    sparse_cell = base == "serve_sparse"
    cfg = scaleout.ScaleOutConfig(
        n_classes=102_400, dim=1_048_576 if sparse_cell else 2048,
        m_tx=3, n_rx_cores=1024,
        batch=512 if mt else 4096,
        use_kernels=False,
        collective="index_ag" if sparse_cell else collective,
        representation=("sparse" if sparse_cell
                        else "packed" if packed else "unpacked"),
        k_max=2048 if sparse_cell else 0,
        noise="bitplane",
        channel="symbol" if base in ("serve_symbol", "serve_adaptive")
        else "bsc",
        # coarse-to-fine at WHYPE scale: c_core=100 rows/core screened as 10
        # strict-majority group summaries, exact rescore on the best 4 groups
        **({"coarse_group": 10, "coarse_keep": 4} if base == "serve_topk"
           else {}),
    )
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    e_per = -(-cfg.m_tx // model_size)
    hv_last = cfg.words if packed else cfg.dim
    hv_dtype = jnp.uint32 if packed else jnp.uint8
    n_trials = cfg.batch * (SLOTS if mt else 1)
    if mt:
        fn = scaleout.make_mt_ota_serve(mesh, cfg)
        args = (
            jax.ShapeDtypeStruct((TENANTS, cfg.n_classes, hv_last), hv_dtype),
            jax.ShapeDtypeStruct(
                (SLOTS, cfg.batch, model_size, e_per, hv_last), hv_dtype
            ),
            jax.ShapeDtypeStruct((SLOTS,), jnp.int32),
            phy.state_shape_structs(cfg.n_rx_cores, cfg.m_tx),
            jax.ShapeDtypeStruct((SLOTS, 2), jnp.uint32),
        )
    elif base == "serve_adaptive":
        # living-channel serve: one ChannelProcess tick (phase drift + guard
        # monitor) fused ahead of the symbol-tier serve under shard_map — the
        # cell that catches ProcessState sharding-spec regressions at the
        # production 1024-core scale
        fn = scaleout.make_ota_serve(
            mesh, cfg, process=phy.PhaseDriftProcess(guard_dims=64)
        )
        args = (
            jax.ShapeDtypeStruct((cfg.n_classes, hv_last), hv_dtype),
            jax.ShapeDtypeStruct((cfg.batch, model_size, e_per, hv_last), hv_dtype),
            phy.pstate_shape_structs(cfg.n_rx_cores, cfg.m_tx),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
    elif base == "serve_faulty":
        # fault-injected serve: one static-fault tick + erasure-aware votes +
        # stuck-at masks + the serve_rows failover gather fused under
        # shard_map — the cell that catches FaultState sharding-spec
        # regressions at the production 1024-core scale
        from repro import faults
        fn = scaleout.make_ota_serve(
            mesh, cfg, faults=faults.StaticFaults()
        )
        m_slots = model_size * e_per
        args = (
            jax.ShapeDtypeStruct((cfg.n_classes, hv_last), hv_dtype),
            jax.ShapeDtypeStruct((cfg.batch, model_size, e_per, hv_last), hv_dtype),
            phy.state_shape_structs(cfg.n_rx_cores, cfg.m_tx),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            faults.fstate_shape_structs(cfg.n_rx_cores, m_slots, cfg.words),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
    elif base == "serve_sparse":
        if packed:
            return {"arch": "hdc-scaleout", "cell": cell_name,
                    "status": "skipped",
                    "why": "serve_sparse has no _packed variant — sparse is "
                           "its own representation (packed prototype words, "
                           "int32 index-list queries)"}
        fn = scaleout.make_ota_serve(mesh, cfg)
        args = (
            jax.ShapeDtypeStruct((cfg.n_classes, cfg.words), jnp.uint32),
            jax.ShapeDtypeStruct(
                (cfg.batch, model_size, e_per, cfg.k_max), jnp.int32
            ),
            phy.state_shape_structs(cfg.n_rx_cores, cfg.m_tx),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
    elif base in ("serve", "serve_wired", "serve_rsag", "serve_psumpacked",
                  "serve_symbol", "serve_topk"):
        fn = (scaleout.make_wired_serve if base == "serve_wired"
              else scaleout.make_ota_serve)(mesh, cfg)
        args = (
            jax.ShapeDtypeStruct((cfg.n_classes, hv_last), hv_dtype),
            jax.ShapeDtypeStruct((cfg.batch, model_size, e_per, hv_last), hv_dtype),
            phy.state_shape_structs(cfg.n_rx_cores, cfg.m_tx),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
    elif base == "train":
        fn = scaleout.make_hdc_train(mesh, cfg)
        args = (
            jax.ShapeDtypeStruct((cfg.batch, hv_last), hv_dtype),
            jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
        )
    else:
        return {"arch": "hdc-scaleout", "cell": cell_name, "status": "skipped",
                "why": "cells: serve | serve_psumpacked | serve_rsag |"
                       " serve_symbol | serve_topk | serve_adaptive |"
                       " serve_faulty | serve_wired | serve_hdc_multitenant |"
                       " train (each also as <cell>_packed) | serve_sparse"}
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    hc = hlo_cost.analyze(compiled.as_text())
    return {
        "arch": "hdc-scaleout", "cell": cell_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok", "chips": chips,
        "config": {"classes": cfg.n_classes, "dim": cfg.dim, "m_tx": cfg.m_tx,
                   "rx_cores": cfg.n_rx_cores, "batch": cfg.batch,
                   "representation": cfg.representation,
                   "collective": cfg.collective,
                   "channel": cfg.channel,
                   **({"k_max": cfg.k_max} if cfg.sparse else {}),
                   **({"coarse_group": cfg.coarse_group,
                       "coarse_keep": cfg.coarse_keep}
                      if cfg.coarse_group else {}),
                   **({"slots": SLOTS, "tenants": TENANTS} if mt else {})},
        "memory_analysis": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "hlo_per_device": {
            "flops": hc.flops, "hbm_bytes": hc.hbm_bytes, "collective": hc.collective,
            "collective_bytes": hc.coll_total,
            "collective_bytes_per_trial": hc.coll_total / n_trials,
            "hbm_bytes_per_trial": hc.hbm_bytes / n_trials,
        },
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
    }


def _out_path(arch, cell, multi_pod, tag=""):
    mesh = ("pod2" if multi_pod else "pod1") + (f"-{tag}" if tag else "")
    d = os.path.abspath(os.path.join(ARTIFACTS, mesh))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch.replace('/', '_')}__{cell}.json")


def run_one(arch, cell, multi_pod, force=False, tag="", flash_vjp=True,
            uneven_heads=False, capacity_factor=None, expand_kv=False):
    path = _out_path(arch, cell, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        rec = lower_cell(arch, cell, multi_pod, flash_vjp=flash_vjp,
                         uneven_heads=uneven_heads, capacity_factor=capacity_factor,
                         expand_kv=expand_kv)
    except Exception as e:  # a failure here is a bug in the sharding config
        rec = {"arch": arch, "cell": cell, "status": "error",
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs x cells x both meshes")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact subdir suffix (perf variants)")
    ap.add_argument("--flash-vjp", type=int, default=1)
    ap.add_argument("--uneven-heads", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--expand-kv", type=int, default=0)
    args = ap.parse_args()

    if not args.all:
        rec = run_one(args.arch, args.cell, args.multi_pod, force=args.force,
                      tag=args.tag, flash_vjp=bool(args.flash_vjp),
                      uneven_heads=bool(args.uneven_heads),
                      capacity_factor=args.capacity_factor,
                      expand_kv=bool(args.expand_kv))
        print(json.dumps({k: v for k, v in rec.items() if k != "traceback"}, indent=1))
        if rec["status"] == "error":
            print(rec.get("traceback", ""), file=sys.stderr)
            sys.exit(1)
        return

    from repro import configs as _c
    from repro.configs.shapes import CELLS as _cells

    jobs = []
    for multi_pod in (False, True):
        for arch in _c.ARCHS:
            for cell in _cells:
                jobs.append((arch.replace("_", "-"), cell, multi_pod))
        for cell in ("serve", "serve_psumpacked", "serve_rsag", "serve_symbol",
                     "serve_topk", "serve_adaptive", "serve_faulty",
                     "serve_wired", "serve_hdc_multitenant",
                     "train", "serve_packed", "serve_psumpacked_packed",
                     "serve_rsag_packed", "serve_symbol_packed",
                     "serve_topk_packed", "serve_adaptive_packed",
                     "serve_faulty_packed", "serve_wired_packed",
                     "serve_hdc_multitenant_packed", "train_packed",
                     "serve_sparse"):
            jobs.append(("hdc-scaleout", cell, multi_pod))

    pending = [j for j in jobs if args.force or not os.path.exists(_out_path(*j, tag=args.tag))]
    print(f"{len(jobs)} cells total, {len(pending)} to run, jobs={args.jobs}")
    procs: list[tuple[subprocess.Popen, tuple]] = []
    results = []
    while pending or procs:
        while pending and len(procs) < args.jobs:
            arch, cell, mp = pending.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--cell", cell,
                   "--flash-vjp", str(args.flash_vjp)]
            if args.tag:
                cmd += ["--tag", args.tag]
            if mp:
                cmd.append("--multi-pod")
            if args.force:
                cmd.append("--force")
            p = subprocess.Popen(cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
            procs.append((p, (arch, cell, mp)))
        for p, meta in procs[:]:
            if p.poll() is not None:
                procs.remove((p, meta))
                arch, cell, mp = meta
                path = _out_path(arch, cell, mp, tag=args.tag)
                status = "?"
                if os.path.exists(path):
                    with open(path) as f:
                        status = json.load(f).get("status")
                results.append((meta, status))
                print(f"[{len(results)}/{len(jobs)}] {arch} {cell} {'pod2' if mp else 'pod1'}: {status}")
        time.sleep(1.0)
    bad = [r for r in results if r[1] not in ("ok", "skipped")]
    print(f"done: {len(results)} ran, {len(bad)} errors")
    for meta, st in bad:
        print("  ERROR:", meta)


if __name__ == "__main__":
    main()
