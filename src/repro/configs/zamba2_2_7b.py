"""Zamba2 2.7B [arXiv:2411.15242] — hybrid: Mamba-2 backbone + shared attn block.

54 Mamba-2 layers (d_inner 5120, state 64, head_dim 64 -> 80 ssd heads) with one
*shared* transformer block (32H MHA kv=32, head_dim 80, d_ff 10240) applied every
6 layers (9 invocations, one weight set), d_model=2560 vocab=32000.
long_500k runs: SSD state is O(1); the shared-attn KV cache (9 entries) is
sequence-sharded.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, SSMSettings

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMSettings(kind="mamba2", d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    shared_attn_every=6,
    subquadratic=True,
    rules_override={"kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        ssm=SSMSettings(kind="mamba2", d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
        shared_attn_every=2, loss_chunk=32, remat=False,
    )
