"""HDC-as-a-service: the similarity-search backend of the slot ring.

The paper's end state — a wireless-on-chip similarity-search fabric serving
"heavy traffic from millions of users" — maps here onto the same continuous
batching machinery that fronts the LMs (``repro.serving.slotring`` /
``scheduler.SlotScheduler``), with three pieces:

* ``TenantRegistry`` — many classifier *tenants* resident at once. Every
  tenant's prototype bank occupies one row of ONE banked store
  [max_tenants, C, d|W] whose class axis is sharded over ``model`` exactly
  like the standalone serve (each tenant's classes live on the same IMC
  cores). Onboarding/eviction is a jitted ``dynamic_update_slice`` of one
  tenant row — no recompile, the serve step never changes shape.
* ``HDCEngine`` — a ``SlotRingEngine`` whose state is per-slot query batches +
  tenant store-rows + RNG keys, and whose step is ONE
  ``scaleout.make_mt_ota_serve`` launch: the full wire path (OTA vote
  collective, guard-bit packing, pluggable PHY channel) runs slot-batched,
  and the per-core search is a single ``hamming_topk_banked`` call whose bank
  axis spans (slot, core[, permuted bank]) via the ``bank_rows`` indirection.
  Unlike LM decode, every slot COMPLETES each step — admission latency is the
  only queueing — so the emission is the (pred, maxsim) pair itself.
* ``HDCScheduler`` — the ``SlotScheduler`` specialization: requests name a
  tenant, admission swaps the query batch into a free slot, and every running
  slot finishes at each step barrier.

Per-slot results are bit-identical to a standalone ``make_ota_serve`` of that
request against its tenant's codebook with the request's own key (see
`make_mt_ota_serve`), so multi-tenant batching is purely a throughput/latency
optimization — pinned by tests/test_serving_hdc.py across representations and
channels.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import faults, phy
from repro.core import classifier
from repro.core import hypervector as hv
from repro.core.scaleout import ScaleOutConfig, make_mt_ota_serve
from repro.serving import slotring
from repro.serving.scheduler import SlotScheduler


@dataclasses.dataclass
class HDCRequest:
    rid: int
    tenant: Any                  # tenant id (registry key)
    queries: Any                 # [B, S_tx, e_per, d|W]
    key: Any
    t_submit: float


@dataclasses.dataclass
class HDCCompletion:
    rid: int
    tenant: Any
    pred: np.ndarray             # [B] int32 (baseline) or [B, M] (permuted)
    maxsim: np.ndarray
    t_submit: float
    t_admit: float
    t_finish: float
    status: str = "ok"           # "ok" | "evicted" (deadline-expired slot)

    @property
    def latency(self) -> float:
        """Submit-to-finish wall time (includes queueing)."""
        return self.t_finish - self.t_submit


def _store_write(store, protos, row):
    """Overwrite tenant row `row` of the banked store — the onboarding op."""
    return jax.lax.dynamic_update_slice(store, protos[None], (row, 0, 0))


def multicentroid_bank(key, protos: jax.Array, k_c: int, cfg: ScaleOutConfig,
                       **train_kwargs) -> jax.Array:
    """Expand a [C, d|W] codebook into a class-major [C*k_c, d|W] centroid bank.

    The serve fabric is class-count-agnostic — a multi-centroid tenant is just
    a tenant with ``k_c`` banks per class, onboarded into a registry/config
    built with ``n_classes = C * k_c``. Centroids come from
    `classifier.train_multicentroid` (majority-based k-means in packed space);
    the class-major layout means a serve prediction ``p`` maps back to class
    ``p // k_c`` (`centroid_to_class`), and the tie convention is preserved:
    among equidistant centroids the serve picks the lowest flat index, which
    is the lowest (class, centroid) pair. Returns the representation the
    config serves (packed words or unpacked bits)."""
    cents = classifier.train_multicentroid(key, protos, k_c, **train_kwargs)
    c, _, w = cents.shape
    bank = cents.reshape(c * k_c, w)
    if not cfg.packed:
        bank = hv.unpack(bank, cfg.dim).astype(jnp.uint8)
    return bank


def centroid_to_class(pred: jax.Array, k_c: int) -> jax.Array:
    """Map class-major centroid predictions (from `multicentroid_bank`) back
    to class labels. Works elementwise on any shape (baseline [B] or
    permuted [B, M] predictions alike)."""
    return pred // k_c


def _admit_many_impl(state, queries, rows, keys, slots):
    """Scatter K admissions into the slot ring in ONE compiled program.

    `queries`/`keys` arrive as K-tuples and are stacked INSIDE the trace —
    an eager `jnp.stack` before the call costs ~2K dispatches, which at small
    trial batches outweighs the serve step itself."""
    return {
        "queries": state["queries"].at[slots].set(jnp.stack(queries)),
        "row": state["row"].at[slots].set(rows),
        "key": state["key"].at[slots].set(jnp.stack(keys)),
    }


class TenantRegistry:
    """Resident per-tenant prototype banks in one class-sharded store.

    ``store`` is [max_tenants, n_classes, d|W] with the class axis sharded
    over ``model`` (the same placement a standalone serve gives one tenant's
    codebook). ``onboard``/``evict`` edit one row via a single jitted
    ``dynamic_update_slice`` (row index traced — one compiled program for the
    registry's lifetime); evicted rows keep their stale contents, which is
    safe because no slot maps to them until re-onboarding overwrites the row.
    """

    def __init__(self, mesh: Mesh, cfg: ScaleOutConfig, max_tenants: int):
        if max_tenants < 1:
            raise ValueError("max_tenants must be >= 1")
        self.cfg = cfg
        self.max_tenants = max_tenants
        last = cfg.words if cfg.packed else cfg.dim
        dtype = jnp.uint32 if cfg.packed else jnp.uint8
        self.store = jax.device_put(
            jnp.zeros((max_tenants, cfg.n_classes, last), dtype),
            NamedSharding(mesh, P(None, "model", None)),
        )
        self._write = jax.jit(_store_write, donate_argnums=0)
        self.rows: dict[Any, int] = {}
        self._free: list[int] = list(range(max_tenants))

    def onboard(self, tenant_id, protos: jax.Array) -> int:
        """Install a tenant's [C, d|W] prototype bank; returns its store row."""
        if tenant_id in self.rows:
            raise ValueError(f"tenant {tenant_id!r} already onboarded")
        if not self._free:
            raise ValueError(
                f"registry full ({self.max_tenants} tenants); evict first"
            )
        want = self.store.shape[1:]
        if tuple(protos.shape) != want or protos.dtype != self.store.dtype:
            raise ValueError(
                f"prototype bank must be {want} {self.store.dtype}, got "
                f"{tuple(protos.shape)} {protos.dtype}"
            )
        row = self._free.pop(0)
        self.store = self._write(self.store, protos, jnp.int32(row))
        self.rows[tenant_id] = row
        return row

    def evict(self, tenant_id) -> None:
        """Free a tenant's row (contents stay until the row is reused)."""
        if tenant_id not in self.rows:
            raise ValueError(f"tenant {tenant_id!r} not onboarded")
        self._free.append(self.rows.pop(tenant_id))


class HDCEngine(slotring.SlotRingEngine):
    """Slot-ring HDC backend: N resident query batches, one multi-tenant OTA
    serve launch per step.

    State leaves: per-slot query batches [N, B, S_tx, e_per, d|W], tenant
    store-rows [N] and RNG keys [N, 2]. The step is stateless compute — every
    slot completes, emitting its (pred, maxsim) — so the scheduler frees all
    running slots each step. ``params`` for `step` is (store, channel state):
    the live registry store rides in per call, so onboarding between steps
    needs no engine rebuild.
    """

    def __init__(self, mesh: Mesh, cfg: ScaleOutConfig,
                 chan_state: phy.ChannelState, *, num_slots: int,
                 max_tenants: int, batch: int | None = None):
        self.mesh = mesh
        self.cfg = cfg
        self.chan_state = chan_state
        self.batch = cfg.batch if batch is None else batch
        self.registry = TenantRegistry(mesh, cfg, max_tenants)
        self._serve = self._build_serve(cfg)
        self._admit_many_fn = jax.jit(_admit_many_impl)
        model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
        self._qshape = (
            self.batch, model_size, -(-cfg.m_tx // model_size),
            cfg.words if cfg.packed else cfg.dim,
        )
        super().__init__(num_slots)

    def _build_serve(self, cfg: ScaleOutConfig):
        """Build the serve program for ``cfg`` (hook for the adaptive engine,
        which rebuilds under link-controller cfg variants)."""
        return make_mt_ota_serve(self.mesh, cfg)

    @property
    def params(self):
        """(store, channel state) — fetched fresh each step so tenant
        onboarding/eviction between steps is visible without a rebuild."""
        return self.registry.store, self.chan_state

    def init_state(self) -> dict:
        n = self.num_slots
        dtype = jnp.uint32 if self.cfg.packed else jnp.uint8
        return {
            "queries": jnp.zeros((n,) + self._qshape, dtype),
            "row": jnp.zeros((n,), jnp.int32),   # empty slots search row 0;
            #   their garbage results are never collected by the scheduler
            "key": jnp.zeros((n, 2), jnp.uint32),
        }

    def _admit_impl(self, state, queries, row, key, slot):
        return slotring.slot_update(
            state, {"queries": queries, "row": row, "key": key}, slot
        )

    def admit_into_slot(self, state, queries: jax.Array, tenant_id, slot: int,
                        key: jax.Array) -> dict:
        """Swap one request's query batch into `slot`, bound to its tenant's
        current store row."""
        row = self._tenant_row(tenant_id)
        if tuple(queries.shape) != self._qshape:
            raise ValueError(
                f"queries must be {self._qshape}, got {tuple(queries.shape)}"
            )
        return self._admit_fn(
            state, queries, jnp.int32(row), key, jnp.int32(slot)
        )

    def _tenant_row(self, tenant_id) -> int:
        row = self.registry.rows.get(tenant_id)
        if row is None:
            raise ValueError(f"tenant {tenant_id!r} not onboarded")
        return row

    def admit_many(self, state, queries: list, tenant_ids: list,
                   slots: list, keys: list) -> dict:
        """Admit K requests in one compiled scatter (one program per distinct
        K — at most ``num_slots`` programs for the engine's lifetime). A
        per-request ``_admit_fn`` dispatch costs about half a standalone
        serve, so filling 8 slots one-by-one would erase the step's batching
        win; scattering them at once keeps admission at ~1 dispatch/step."""
        rows = [self._tenant_row(t) for t in tenant_ids]
        for q in queries:
            if tuple(q.shape) != self._qshape:
                raise ValueError(
                    f"queries must be {self._qshape}, got {tuple(q.shape)}"
                )
        return self._admit_many_fn(
            state, tuple(queries), np.asarray(rows, np.int32),
            tuple(keys), np.asarray(slots, np.int32),
        )

    def step(self, params, state):
        store, chan_state = params
        pred, maxsim = self._serve(
            store, state["queries"], state["row"], chan_state, state["key"]
        )
        return state, (pred, maxsim)


@dataclasses.dataclass(frozen=True)
class LinkControllerConfig:
    """Hysteresis knobs for the closed-loop link controller.

    Per-RX actions (cheapest first): ``patience`` consecutive steps with the
    guard-monitor flip-rate estimate above the analytic band trigger an EM
    re-fit of that receiver's decision regions; a re-fit whose refreshed BER is
    STILL above ``quarantine_ber`` (or that failed outright) counts as a *bad*
    re-fit, and ``quarantine_after`` consecutive bad re-fits quarantine the
    core (its classes drop out of the top-1 reduction). Quarantined cores keep
    evolving, being monitored and re-fit; ``release_after`` consecutive re-fits
    landing below ``release_ber`` release them. The bad/good thresholds are
    deliberately split (0.25 vs 0.10 by default) so a core oscillating around
    one threshold cannot flap in and out of quarantine.

    Fleet action: when the quarantined fraction reaches ``drop_frac`` the
    controller degrades the whole link — bundling width drops to ``m_floor``
    (odd; the non-transmitting TXs abstain, shapes unchanged) and, if
    ``alt_collective`` is set, the vote collective switches (e.g.
    ``psum_packed`` -> ``rs_ag``) — and restores the build-time mode once the
    fraction falls back below. Both directions ride the quarantine hysteresis,
    so the fleet mode cannot flap faster than cores enter/leave quarantine.
    """

    patience: int = 2
    band_kwargs: dict | None = None
    quarantine_ber: float = 0.25
    quarantine_after: int = 3
    release_ber: float = 0.10
    release_after: int = 2
    drop_frac: float = 0.25
    m_floor: int = 1
    alt_collective: str | None = None


class LinkController:
    """Host-side closed-loop link adaptation, run at the step barrier.

    Everything here is numpy on already-synced device values (the scheduler's
    ``_collect`` has just blocked on the step's predictions), so the controller
    costs no extra device round-trips and never touches the compiled serve —
    its outputs are a modified process state (re-fit / quarantine masks folded
    in) and an optional fleet-mode flag the engine maps to a prebuilt serve
    variant. Decisions and their step indices accumulate in ``trace`` for the
    benchmark artifact.
    """

    def __init__(self, cfg: LinkControllerConfig, pstate: "phy.ProcessState"):
        self.cfg = cfg
        kw = cfg.band_kwargs or {}
        self.band = np.asarray(phy.monitor_band(pstate, **kw))
        n = self.band.shape[0]
        self._over = np.zeros(n, np.int32)    # consecutive out-of-band steps
        self._bad = np.zeros(n, np.int32)     # consecutive bad re-fits
        self._good = np.zeros(n, np.int32)    # consecutive good re-fits
        self.quarantined = np.zeros(n, bool)
        self.degraded = False
        self.trace: list[dict] = []
        self._t = 0

    @property
    def n_refits(self) -> int:
        return sum(len(e["rows"]) for e in self.trace if e["action"] == "refit")

    def act(self, pstate: "phy.ProcessState"):
        """One barrier decision. Returns (pstate', degraded | None) — the
        second slot is non-None only on the step the fleet mode flips."""
        cfg = self.cfg
        kw = cfg.band_kwargs or {}
        self._t += 1
        est = np.asarray(pstate.est)
        self._over = np.where(est > self.band, self._over + 1, 0)
        refit = self._over >= cfg.patience
        if refit.any():
            pstate = phy.recharacterize(pstate, jnp.asarray(refit))
            # band refresh ONLY for the re-fit rows: a global recompute would
            # fold the live (drifting) BER of every other row into its own
            # band and ratchet the monitor open (see phy.adaptive_rollout)
            self.band = np.where(
                refit, np.asarray(phy.monitor_band(pstate, **kw)), self.band
            )
            self._over[refit] = 0
            self.trace.append({
                "t": self._t, "action": "refit",
                "rows": np.nonzero(refit)[0].tolist(),
            })
            # judge each re-fit: a freshly characterized core whose BER is
            # still bad is physically degraded (fade/interferer), not stale
            ber = np.asarray(pstate.chan.ber)
            valid = np.asarray(pstate.chan.valid)
            bad_now = refit & (~valid | (ber > cfg.quarantine_ber))
            good_now = refit & valid & (ber < cfg.release_ber)
            self._bad = np.where(
                bad_now, self._bad + 1, np.where(refit, 0, self._bad)
            )
            self._good = np.where(
                good_now, self._good + 1, np.where(refit, 0, self._good)
            )
            newq = (~self.quarantined) & (self._bad >= cfg.quarantine_after)
            rel = self.quarantined & (self._good >= cfg.release_after)
            if newq.any() or rel.any():
                self.quarantined = (self.quarantined | newq) & ~rel
                pstate = phy.set_quarantine(
                    pstate, jnp.asarray(self.quarantined)
                )
                if newq.any():
                    self.trace.append({
                        "t": self._t, "action": "quarantine",
                        "rows": np.nonzero(newq)[0].tolist(),
                    })
                if rel.any():
                    self.trace.append({
                        "t": self._t, "action": "release",
                        "rows": np.nonzero(rel)[0].tolist(),
                    })
        frac = float(self.quarantined.mean())
        want = frac >= cfg.drop_frac
        switched = None
        if want != self.degraded:
            self.degraded = switched = want
            self.trace.append({
                "t": self._t,
                "action": "m_drop" if want else "m_restore",
                "quarantined_frac": frac,
            })
        return pstate, switched


class AdaptiveHDCEngine(HDCEngine):
    """HDCEngine over a LIVING channel with a closed-loop link controller.

    The serve program is the process-threading variant of
    ``make_mt_ota_serve``: each step first evolves the channel one tick of
    ``process`` (same schedule for every data shard — the process key is held
    fixed and the time index is folded inside the step), then serves every
    slot against the evolved channel with quarantined cores masked out of the
    top-1 reduction. The evolved process state is staged per step and
    committed at the scheduler's barrier (``on_barrier``), where the
    ``LinkController`` re-fits / quarantines / switches fleet mode; fleet-mode
    switches swap between serve programs prebuilt through ``step_variant``
    keyed on (m_active, collective) — slot state is shape-stable across
    variants, so a switch is a dict lookup, never a recompile or re-admission.

    Needs ``process.guard_dims > 0``: the guard-symbol monitor is the only
    observation channel, so with no guard block the estimates never move and
    the controller never acts (the serve still tracks the evolving channel).
    """

    def __init__(self, mesh: Mesh, cfg: ScaleOutConfig,
                 chan_state: phy.ChannelState, *, process,
                 num_slots: int, max_tenants: int, batch: int | None = None,
                 process_key: jax.Array | None = None,
                 controller: LinkControllerConfig | None = None):
        self.process = process
        self.pstate = process.init(chan_state)
        self.process_key = (jax.random.PRNGKey(0) if process_key is None
                            else process_key)
        self.controller = self._make_controller(controller, self.pstate)
        self._pending: phy.ProcessState | None = None
        super().__init__(mesh, cfg, chan_state, num_slots=num_slots,
                         max_tenants=max_tenants, batch=batch)
        self._variants[(cfg.m_act, cfg.collective)] = self._serve

    def _make_controller(self, controller: LinkControllerConfig | None,
                         pstate: "phy.ProcessState") -> "LinkController":
        """Controller factory — the fault-tolerant engine swaps in its
        `FaultController` here without re-plumbing the constructor."""
        return LinkController(controller or LinkControllerConfig(), pstate)

    def _build_serve(self, cfg: ScaleOutConfig):
        return make_mt_ota_serve(self.mesh, cfg, process=self.process)

    @property
    def params(self):
        """(store, process state) — the evolving pstate replaces the frozen
        channel state of the static engine."""
        return self.registry.store, self.pstate

    def step(self, params, state):
        store, pstate = params
        pred, maxsim, pstate2 = self._serve(
            store, state["queries"], state["row"], pstate, state["key"],
            self.process_key,
        )
        self._pending = pstate2
        return state, (pred, maxsim)

    def on_barrier(self):
        """Commit the step's evolved process state and let the controller act.

        Called by the scheduler right after the step's device sync, so the
        controller reads settled values; any state it rewrites (re-fit
        centroids, quarantine mask) is picked up by the NEXT step through
        ``params``."""
        if self._pending is None:
            return
        self.pstate, self._pending = self._pending, None
        self.pstate, switched = self.controller.act(self.pstate)
        if switched is not None:
            self._apply_fleet_mode(switched)

    def _apply_fleet_mode(self, degraded: bool) -> None:
        cc = self.controller.cfg
        if phy.get_channel(self.cfg.channel).wire != "votes":
            return  # combo wire: no M-drop / vote-collective alternatives
        if degraded:
            m = cc.m_floor if cc.m_floor % 2 == 1 else max(cc.m_floor - 1, 1)
            coll = cc.alt_collective or self.cfg.collective
        else:
            m = self.cfg.m_tx
            coll = self.cfg.collective
        live = dataclasses.replace(
            self.cfg, m_active=None if m == self.cfg.m_tx else m,
            collective=coll,
        )
        self._serve = self.step_variant(
            (live.m_act, live.collective), lambda: self._build_serve(live)
        )
        self.controller.trace.append({
            "t": self.controller._t, "action": "link_mode",
            "m_active": live.m_act, "collective": live.collective,
        })


@dataclasses.dataclass(frozen=True)
class FaultControllerConfig(LinkControllerConfig):
    """`LinkControllerConfig` plus the quarantine→remap promotion knob.

    ``remap_after`` consecutive barriers spent quarantined promote a core
    from the soft path (masked out of the top-1, still monitored, released
    if its link recovers) to the hard path: it is declared DEAD in the
    `faults.FaultState` and its class banks fail over onto healthy
    same-shard cores (`faults.plan_failover`). Promotion is one-way — a
    remapped core's bank is served elsewhere, so releasing it would race
    the failover — which is why ``remap_after`` sits well above
    ``release_after``: only a core the release hysteresis has repeatedly
    failed to rescue is written off.
    """

    remap_after: int = 3


class FaultController(LinkController):
    """`LinkController` that escalates persistent quarantine to failover.

    The soft loop (re-fit → quarantine → release) handles recoverable
    degradation; `promote` runs right after it at each barrier and counts
    the barriers each core has spent quarantined. At ``remap_after`` the
    core is promoted into ``FaultState.dead_rx`` and the shard's serve
    plan is re-dealt host-side — same compiled serve, the remap rides the
    traced ``serve_rows``/``rx_mask`` inputs. Trace action: ``"remap"``.
    """

    def __init__(self, cfg: FaultControllerConfig, pstate: "phy.ProcessState"):
        super().__init__(cfg, pstate)
        self._q_barriers = np.zeros(self.band.shape[0], np.int32)

    def promote(self, fstate: "faults.FaultState",
                cores_per_shard: int) -> "faults.FaultState":
        """One barrier's promotion decision; returns the (possibly re-dealt)
        fault state the NEXT step serves under."""
        self._q_barriers = np.where(
            self.quarantined, self._q_barriers + 1, 0
        ).astype(np.int32)
        newly_dead = (
            (self._q_barriers >= self.cfg.remap_after)
            & ~np.asarray(fstate.dead_rx)
        )
        if not newly_dead.any():
            return fstate
        fstate = faults.inject(
            fstate, dead_rx=np.asarray(fstate.dead_rx) | newly_dead
        )
        fstate = faults.plan_failover(fstate, cores_per_shard)
        self.trace.append({
            "t": self._t, "action": "remap",
            "rows": np.nonzero(newly_dead)[0].tolist(),
        })
        return fstate


class FaultTolerantHDCEngine(AdaptiveHDCEngine):
    """`AdaptiveHDCEngine` that also threads a live `faults.FaultState`.

    The serve program is the process+faults variant of ``make_mt_ota_serve``:
    each step evolves the channel AND the fault state one tick (transient
    vote erasures redraw, wearout accumulates), serves every slot
    erasure-aware with dead cores' banks failed over, and stages both evolved
    states for the barrier. At ``on_barrier`` the `FaultController` first
    runs the soft loop it inherits, then promotes persistently-quarantined
    cores into the fault state (see `FaultController.promote`).

    With the all-healthy state and the ``static`` fault model this engine is
    bit-identical to `AdaptiveHDCEngine` — fault awareness costs nothing
    until faults exist (pinned in tests/test_faults.py).
    """

    def __init__(self, mesh: Mesh, cfg: ScaleOutConfig,
                 chan_state: phy.ChannelState, *, process, fault_model,
                 num_slots: int, max_tenants: int, batch: int | None = None,
                 process_key: jax.Array | None = None,
                 fault_key: jax.Array | None = None,
                 fstate: "faults.FaultState | None" = None,
                 controller: LinkControllerConfig | None = None):
        self.fault_model = fault_model
        model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
        self._cores_per_shard = cfg.n_rx_cores // model_size
        self.fstate = (faults.healthy_for(cfg, model_size)
                       if fstate is None else fstate)
        self.fault_key = (jax.random.PRNGKey(1) if fault_key is None
                          else fault_key)
        self._pending_fstate: "faults.FaultState | None" = None
        super().__init__(mesh, cfg, chan_state, process=process,
                         num_slots=num_slots, max_tenants=max_tenants,
                         batch=batch, process_key=process_key,
                         controller=controller)

    def _make_controller(self, controller, pstate):
        return FaultController(controller or FaultControllerConfig(), pstate)

    def _build_serve(self, cfg: ScaleOutConfig):
        return make_mt_ota_serve(self.mesh, cfg, process=self.process,
                                 faults=self.fault_model)

    def step(self, params, state):
        store, pstate = params
        pred, maxsim, pstate2, fstate2 = self._serve(
            store, state["queries"], state["row"], pstate, state["key"],
            self.process_key, self.fstate, self.fault_key,
        )
        self._pending = pstate2
        self._pending_fstate = fstate2
        return state, (pred, maxsim)

    def on_barrier(self):
        """Commit both evolved states, run the soft loop, then promote."""
        if self._pending_fstate is not None:
            self.fstate, self._pending_fstate = self._pending_fstate, None
        super().on_barrier()
        self.fstate = self.controller.promote(
            self.fstate, self._cores_per_shard
        )


class HDCScheduler(SlotScheduler):
    """Tenant-aware request queue over an ``HDCEngine``.

    Every running slot finishes at each step barrier (an HDC request is one
    launch, not a token loop), so continuous batching here means: free slots
    refill from the age-ordered queue every step, and a step serves however
    many tenants are resident — the single-launch amortization the benchmark
    measures against per-request standalone serves.
    """

    def __init__(self, engine: HDCEngine,
                 clock: Callable[[], float] = time.monotonic,
                 *, max_slot_steps: int | None = None, max_requeues: int = 1):
        super().__init__(engine, None, clock,
                         max_slot_steps=max_slot_steps,
                         max_requeues=max_requeues)

    def submit(self, tenant_id, queries: jax.Array, *,
               key: jax.Array | None = None) -> int:
        """Queue one trial batch [B, S_tx, e_per, d|W] for `tenant_id`.
        `key` seeds the request's PHY noise stream (default: fold of the rid)."""
        if tenant_id not in self.engine.registry.rows:
            raise ValueError(f"tenant {tenant_id!r} not onboarded")
        rid = self._next_rid
        self._next_rid += 1
        req = HDCRequest(
            rid, tenant_id, queries,
            key if key is not None else jax.random.PRNGKey(rid), self.clock(),
        )
        # one bucket: HDC query batches are shape-uniform by construction
        self.buckets[0].append(req)
        return rid

    def _step_params(self):
        return self.engine.params

    def _fail_eviction(self, slot: int, record):
        """Deadline eviction (an HDC slot completes every step, so this only
        fires if the step loop itself stalls): empty result, status marks it."""
        req, t_admit = record
        return HDCCompletion(
            req.rid, req.tenant, np.zeros((0,), np.int32),
            np.zeros((0,), np.float32), req.t_submit, t_admit, self.clock(),
            status="evicted",
        )

    def _admit_free_slots(self) -> list:
        """Batched admission: every free slot fills from the age-ordered queue
        in ONE ``admit_many`` scatter (overrides the base per-request loop —
        per-request admit dispatches would eat the step's batching win)."""
        batch = []
        while self.free:
            req = self._pop_oldest()
            if req is None:
                break
            # tenant may have been evicted between submit and admission
            if req.tenant not in self.engine.registry.rows:
                raise RuntimeError(
                    f"tenant {req.tenant!r} evicted with request {req.rid} queued"
                )
            batch.append((req, self.free.pop(0)))
        if batch:
            self.state = self.engine.admit_many(
                self.state,
                [r.queries for r, _ in batch],
                [r.tenant for r, _ in batch],
                [s for _, s in batch],
                [r.key for r, _ in batch],
            )
            t_admit = self.clock()
            for req, slot in batch:
                self.running[slot] = (req, t_admit)
        return []

    def _collect(self, emitted) -> list:
        pred, maxsim = emitted
        p = np.asarray(pred)        # device sync: this is the step barrier
        s = np.asarray(maxsim)
        self.engine.on_barrier()    # adaptive engines: commit the evolved
        #   process state + run the link controller on settled values
        finished = []
        for slot in sorted(self.running):
            req, t_admit = self.running.pop(slot)
            done = HDCCompletion(
                req.rid, req.tenant, p[slot], s[slot],
                req.t_submit, t_admit, self.clock(),
            )
            self.results[req.rid] = done
            self.free.append(slot)
            finished.append(done)
        return finished
