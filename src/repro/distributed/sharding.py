"""Logical-axis sharding rules engine (MaxText-style).

Model code annotates tensors with *logical* axis names (``batch``, ``embed``,
``heads``, ``experts``, ...). A per-architecture rule table maps logical axes to
mesh axes; the engine resolves annotations to ``PartitionSpec``s, dropping any
mesh axis that does not divide the concrete dimension (GSPMD would pad, but even
shardings keep the dry-run memory analysis honest).

Two consumers:
* parameter/init shardings — ``tree_shardings`` over a pytree of logical-axes
  tuples (every model exposes ``param_axes()`` mirroring its params);
* activation constraints — ``shard(x, 'batch', 'seq', 'embed')`` inside jitted
  code, reading the ambient rules installed by ``use_rules``.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

# logical axis -> mesh axis | tuple of mesh axes | None (replicated)
AxisRules = Mapping[str, Any]

# Batch always spreads over every data-parallel mesh axis (incl. the pod axis in
# the multi-pod mesh — mesh axes absent from the mesh are dropped at resolve time).
DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,        # decode KV cache length; long-context rules map it to "data"
    "embed": None,
    "heads": "model",
    "kv_heads": "model",   # dropped automatically when kv_heads % model != 0
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "moe_groups": ("pod", "data"),
    "state": None,         # SSM state dim
    "inner": "model",      # SSM d_inner
    "conv": None,
    "classes": "model",    # HDC associative-memory shard (= the N IMC cores)
    "hv_dim": None,
    "tx": None,
    "fsdp": ("pod", "data"),  # ZeRO-3-ish weight sharding axis (opt-in per arch)
}

_current_rules: contextvars.ContextVar[AxisRules] = contextvars.ContextVar(
    "sharding_rules", default=DEFAULT_RULES
)


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    """Install `rules` (a full table, e.g. DEFAULT_RULES | {...}) for the scope."""
    tok = _current_rules.set(rules)
    try:
        yield rules
    finally:
        _current_rules.reset(tok)


def current_rules() -> AxisRules:
    return _current_rules.get()


def _mesh_axis_sizes() -> Mapping[str, int] | None:
    return compat.current_mesh_axis_sizes()


def _resolve(
    logical_axes: Sequence[str | None],
    rules: AxisRules,
    shape: Sequence[int] | None,
    axis_sizes: Mapping[str, int] | None,
) -> P:
    # logical axes listed under the "__uneven__" rules key may shard unevenly
    # (GSPMD pads, e.g. 56 heads -> 4 per device on a 16-way axis with 12.5%
    # padding waste) — opt-in because padding costs FLOPs but removes the much
    # larger replication cost for head counts that don't divide the mesh.
    uneven_ok = set(rules.get("__uneven__", ()))
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical_axes):
        if name is None:
            out.append(None)
            continue
        if name == "__uneven__":
            raise KeyError("__uneven__ is a rules option, not a logical axis")
        if name not in rules:
            raise KeyError(f"unknown logical axis {name!r}")
        mapped = rules[name]
        if mapped is None:
            out.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        keep = []
        for ax in axes:
            if axis_sizes is not None and ax not in axis_sizes:
                continue  # mesh axis not present in this mesh (e.g. "pod" single-pod)
            if ax in used:
                continue  # each mesh axis may appear once per spec
            size = None if axis_sizes is None else axis_sizes[ax]
            if shape is not None and size is not None:
                dim = shape[i]
                cur = 1
                for k in keep:
                    cur *= axis_sizes[k]
                if dim % (cur * size) != 0:
                    if not (name in uneven_ok and dim >= cur * size):
                        continue  # would shard unevenly -> drop this mesh axis
            keep.append(ax)
            used.add(ax)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_spec(logical_axes: Sequence[str | None], rules: AxisRules | None = None) -> P:
    """Resolve logical axes to a PartitionSpec without shape information."""
    return _resolve(logical_axes, rules or current_rules(), None, _mesh_axis_sizes())


def spec_for_shape(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    rules: AxisRules | None = None,
    mesh: Mesh | None = None,
) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-dividing mesh axes."""
    sizes = (
        dict(zip(mesh.axis_names, mesh.axis_sizes))
        if mesh is not None
        else _mesh_axis_sizes()
    )
    return _resolve(logical_axes, rules or current_rules(), shape, sizes)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Activation sharding constraint by logical axes (no-op outside a mesh)."""
    sizes = _mesh_axis_sizes()
    if sizes is None:
        return x
    spec = _resolve(logical_axes, current_rules(), x.shape, sizes)
    return jax.lax.with_sharding_constraint(x, spec)


def tree_shardings(mesh: Mesh, params_shape: Any, params_axes: Any, rules: AxisRules | None = None) -> Any:
    """NamedShardings for a params pytree.

    params_shape: pytree of ShapeDtypeStruct (from eval_shape);
    params_axes: matching pytree of logical-axes tuples.
    """
    rules = rules or current_rules()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    leaves, treedef = jax.tree.flatten(params_shape)
    axes_leaves = treedef.flatten_up_to(params_axes)  # axes tuples stay whole
    shardings = [
        NamedSharding(mesh, _resolve(a, rules, s.shape, sizes))
        for s, a in zip(leaves, axes_leaves)
    ]
    return jax.tree.unflatten(treedef, shardings)
