"""Distributed training loop: GSPMD train step + fault-tolerant host runner.

Two gradient-synchronization modes:

* "dense"          — standard: autodiff over the globally-sharded loss; GSPMD
                     inserts the f32/bf16 gradient all-reduces implied by the
                     parameter shardings. Grad-accum microbatching via lax.scan.
* "sign_majority"  — the paper's OTA collective applied to training: per-device
                     gradients are computed inside a shard_map over the data/pod
                     axes (model axes stay auto/GSPMD), 1-bit sign-quantized and
                     majority-voted (`sign_allreduce`), optionally through the
                     OTA BER channel. 32× less DP traffic; parameters are kept
                     replicated across dp axes in this mode (FSDP rules are
                     stripped — the honest trade, see DESIGN.md).

The host-level `Trainer` adds checkpoint/restart (atomic keep-k), O(1)
data skip-ahead on resume, and a failure-injection hook used by the
fault-tolerance tests. Straggler mitigation and multi-host watchdog behaviour
are documented in launch/train.py (single-process simulation here).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import version as compat_version
from repro.distributed import collectives
from repro.distributed.sharding import (
    DEFAULT_RULES,
    spec_for_shape,
    tree_shardings,
    use_rules,
)
from repro.models.base import init_params, param_axes, param_shapes
from repro.train import optimizer as opt_lib


def merged_rules(cfg) -> dict:
    return dict(DEFAULT_RULES) | dict(getattr(cfg, "rules_override", {}) or {})


def _strip_dp(rules: dict) -> dict:
    """Remove pod/data mesh axes from every rule (sign_majority mode: params and
    therefore grads must be identical along dp axes up to the batch shard)."""
    def strip(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        kept = tuple(a for a in axes if a not in ("pod", "data"))
        return kept[0] if len(kept) == 1 else (kept or None)
    out = {k: strip(v) for k, v in rules.items()}
    out["batch"] = ("pod", "data")       # batch stays data-parallel
    out["moe_groups"] = ("pod", "data")
    out["fsdp"] = None
    return out


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclasses.dataclass
class TrainFns:
    step: Callable          # (params, opt_state, batch, key) -> (params, opt_state, metrics)
    init: Callable          # (key) -> (params, opt_state)
    param_shardings: Any
    opt_shardings: Any
    batch_spec: Callable    # shapes dict -> shardings dict
    rules: dict


def build_train_fns(
    model,
    mesh: Mesh,
    opt_cfg: opt_lib.OptConfig,
    *,
    microbatch: int = 1,
    ota_ber: float | None = None,
    jit: bool = True,
) -> TrainFns:
    cfg = model.cfg
    rules = merged_rules(cfg)
    if opt_cfg.kind == "sign_majority":
        rules = _strip_dp(rules)
    p_axes = param_axes(model.specs)
    p_shapes = param_shapes(model.specs)
    param_shardings = tree_shardings(mesh, p_shapes, p_axes, rules)
    dp = _dp_axes(mesh)

    def batch_sharding(shapes: dict, axes: dict):
        return {
            k: NamedSharding(mesh, spec_for_shape(axes[k], shapes[k].shape, rules, mesh))
            for k in shapes
        }

    # ---------------- loss/grad ----------------
    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        """Microbatch grad accumulation via lax.scan over the batch split."""
        if microbatch == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0
            return x.reshape((microbatch, b // microbatch) + x.shape[1:])
        mb = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, xs):
            g_acc, l_acc = acc
            (loss, metrics), grads = grad_fn(params, xs)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / microbatch, g_acc, grads)
            return (g_acc, l_acc + loss / microbatch), metrics

        (grads, loss), metrics = jax.lax.scan(body, (zeros, 0.0), mb)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    # ---------------- step ----------------
    if opt_cfg.kind == "adamw":
        def step(params, opt_state, batch, key):
            del key
            with use_rules(rules):
                loss, metrics, grads = accumulate(params, batch)
                new_params, new_state, om = opt_lib.adamw_update(opt_cfg, grads, opt_state, params)
            return new_params, new_state, {"loss": loss, **metrics, **om}

        def init(key):
            params = init_params(key, model.specs)
            return params, opt_lib.adamw_init(opt_cfg, params)

        opt_state_axes = {
            "m": opt_lib.zero1_axes(p_axes),
            "v": opt_lib.zero1_axes(p_axes),
            "step": (),
        }
    elif opt_cfg.kind == "sign_majority":
        # Model axes normally stay auto (GSPMD shards the per-device gradient
        # compute); 0.4.x XLA cannot partition lax.scan inside a partially
        # manual computation, so there the body goes fully manual and every
        # model column redundantly computes the same gradients (params and
        # batch shards are identical along "model" — correct, just unsharded).
        partial_auto = compat_version.has_partial_auto_shard_map()
        axes_set = set(dp) if partial_auto else set(mesh.axis_names)
        # In the fully-manual body no mesh axis is available to GSPMD, so
        # in-body activation constraints must resolve to replicated.
        body_rules = rules if partial_auto else {k: None for k in rules}
        dp_spec = P(dp if len(dp) > 1 else dp[0])
        n_dp = 1
        for a in dp:
            n_dp *= mesh.axis_sizes[mesh.axis_names.index(a)]

        def per_device(params, batch, key, dp_idx):
            # dp_idx: [1] shard of the dp-linear iota — this device's index
            # along the dp axes. Threaded in as a sharded input because
            # lax.axis_index inside a partially-auto shard_map does not lower
            # on 0.4.x XLA (see collectives.sign_allreduce).
            with use_rules(body_rules):
                loss, metrics, grads = accumulate(params, batch)
            votes = jax.tree.map(
                lambda g: collectives.sign_allreduce(
                    g, dp, key=key, ber=ota_ber, device_index=dp_idx[0]
                ),
                grads,
            )
            loss = jax.lax.pmean(loss, dp)
            return votes, loss, metrics

        def step(params, opt_state, batch, key):
            batch_specs = jax.tree.map(lambda x: dp_spec, batch)
            votes, loss, metrics = compat.shard_map(
                per_device,
                mesh=mesh,
                in_specs=(P(), batch_specs, P(), dp_spec),
                out_specs=(P(), P(), P()),
                axis_names=axes_set,
                check_vma=False,
            )(params, batch, key, jnp.arange(n_dp, dtype=jnp.int32))
            with use_rules(rules):
                new_params, new_state, om = opt_lib.sign_update(opt_cfg, votes, opt_state, params)
            return new_params, new_state, {"loss": loss, **metrics, **om}

        def init(key):
            params = init_params(key, model.specs)
            return params, opt_lib.sign_init(opt_cfg, params)

        opt_state_axes = {"mom": opt_lib.zero1_axes(p_axes), "step": ()}
    else:
        raise ValueError(opt_cfg.kind)

    opt_shardings = {
        k: (tree_shardings(mesh, p_shapes, v, rules) if k != "step" else NamedSharding(mesh, P()))
        for k, v in opt_state_axes.items()
    }

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1))
    return TrainFns(step, init, param_shardings, opt_shardings, batch_sharding, rules)


# ---------------------------------------------------------------------------
# fault-tolerant host runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10


class Trainer:
    """Single-process simulation of the multi-host runner.

    On a real cluster each host runs this loop under a watchdog (see
    launch/train.py): a crashed/straggling host is restarted and rejoins at the
    latest checkpoint; the data pipeline skips ahead in O(1).
    """

    def __init__(self, fns: TrainFns, pipeline, tcfg: TrainerConfig, mesh: Mesh):
        self.fns = fns
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.mesh = mesh

    def run(self, key: jax.Array, fail_at: int | None = None, quiet: bool = False):
        from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

        tcfg = self.tcfg
        start = latest_step(tcfg.ckpt_dir)
        if start is not None:
            like = jax.eval_shape(lambda k: self.fns.init(k), key)
            shardings = (self.fns.param_shardings, self.fns.opt_shardings)
            (params, opt_state), extra = restore_checkpoint(
                tcfg.ckpt_dir, start, like, shardings
            )
            step0 = int(extra["data_step"])
        else:
            params, opt_state = self.fns.init(key)
            step0 = 0

        losses = []
        for step in range(step0, tcfg.steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.pipeline.batch(step)
            params, opt_state, metrics = self.fns.step(params, opt_state, batch, key)
            losses.append(float(metrics["loss"]))
            if not quiet and (step % tcfg.log_every == 0 or step == tcfg.steps - 1):
                print(f"step {step:5d}  loss {losses[-1]:.4f}  lr {float(metrics['lr']):.2e}")
            if (step + 1) % tcfg.ckpt_every == 0 or step == tcfg.steps - 1:
                save_checkpoint(
                    tcfg.ckpt_dir, step + 1, (params, opt_state),
                    extra={"data_step": step + 1}, keep=tcfg.keep,
                )
        return params, opt_state, losses
