"""Version-portable view of XLA's compiled-program cost analysis.

``Compiled.cost_analysis()`` changed shape across JAX versions:

* 0.4.x returns a *list* of per-program property dicts (usually length 1;
  multi-program executables produce one dict per program);
* newer JAX returns a single flat dict.

``normalized_cost_analysis`` canonicalizes both (plus a None result from
backends without cost modeling) into one flat ``{metric: float}`` dict, so
callers can always do ``cost["flops"]`` / ``cost.get("bytes accessed")``.
Dispatch is on the actual returned value, not the JAX version, so the shim
also survives backends that diverge from their pin's default.
"""
from __future__ import annotations

from typing import Any, Mapping


def normalized_cost_analysis(compiled: Any) -> dict:
    """Canonical flat dict of XLA cost metrics for a compiled program.

    Accepts anything with a ``cost_analysis()`` method (``jax.stages.Compiled``).
    Multi-program lists are merged by summing numeric values per key — the
    total cost of executing every program once.
    """
    cost = compiled.cost_analysis()
    return normalize_cost_result(cost)


def normalize_cost_result(cost: Any) -> dict:
    """Canonicalize a raw cost_analysis() return value (see module docstring)."""
    if cost is None:
        return {}
    if isinstance(cost, Mapping):
        return dict(cost)
    if isinstance(cost, (list, tuple)):
        dicts = [c for c in cost if isinstance(c, Mapping)]
        if not dicts:
            return {}
        if len(dicts) == 1:
            return dict(dicts[0])
        merged: dict = {}
        for d in dicts:
            for k, v in d.items():
                if isinstance(v, (int, float)) and isinstance(merged.get(k, 0.0), (int, float)):
                    merged[k] = merged.get(k, 0.0) + v
                else:
                    merged.setdefault(k, v)
        return merged
    raise TypeError(f"unrecognized cost_analysis() result type: {type(cost).__name__}")
