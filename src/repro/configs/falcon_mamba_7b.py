"""Falcon-Mamba 7B [arXiv:2410.05355] — attention-free Mamba-1 SSM.

64L d_model=4096 (d_inner 8192, ssm_state=16, conv 4, dt_rank 256) vocab=65024.
Sharding: d_inner TP over "model" (the recurrence is elementwise across
channels); long_500k runs natively (O(1) state per token).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, SSMSettings

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMSettings(kind="mamba1", d_state=16, d_conv=4, expand=2, dt_rank=256, chunk=128),
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab=512,
        ssm=SSMSettings(kind="mamba1", d_state=8, d_conv=4, expand=2, dt_rank=8, chunk=16),
        loss_chunk=32, remat=False,
    )
