"""Feature detection for the version-sensitive JAX surface this repo touches.

The repo targets JAX 0.4.x through >= 0.6; the APIs below moved or changed
shape across that range. Everything outside ``repro.compat`` must go through
the shims in this package instead of touching these names directly (the test
suite greps for violations).

Detection is done by probing the live ``jax`` module, not by parsing version
strings: the point is "does *this* runtime have the API", which also lets the
unit tests monkeypatch a feature in or out and exercise both branches of every
shim on a single pin.
"""
from __future__ import annotations

import inspect

import jax

# Feature name -> (what it gates, where the shim lives)
_FEATURE_DOC = {
    "axis_type": "jax.sharding.AxisType / make_mesh(axis_types=...)  [compat.mesh.make_mesh]",
    "make_mesh": "top-level jax.make_mesh                            [compat.mesh.make_mesh]",
    "make_mesh_axis_types": "jax.make_mesh accepts axis_types=       [compat.mesh.make_mesh]",
    "set_mesh": "jax.set_mesh context manager                        [compat.mesh.set_mesh]",
    "use_mesh": "jax.sharding.use_mesh context manager               [compat.mesh.set_mesh]",
    "get_abstract_mesh": "jax.sharding.get_abstract_mesh             [compat.sharding.current_mesh]",
    "top_level_shard_map": "jax.shard_map(axis_names=, check_vma=)   [compat.sharding.shard_map]",
    "dict_cost_analysis": "Compiled.cost_analysis() returns a dict   [compat.xla.normalized_cost_analysis]",
    "lax_map_batch_size": "jax.lax.map accepts batch_size=           [compat.control.lax_map_batched]",
}


def has_axis_type() -> bool:
    return hasattr(jax.sharding, "AxisType")


def has_make_mesh() -> bool:
    return hasattr(jax, "make_mesh")


def make_mesh_takes_axis_types() -> bool:
    if not has_make_mesh():
        return False
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return False


def has_set_mesh() -> bool:
    return hasattr(jax, "set_mesh")


def has_use_mesh() -> bool:
    return hasattr(jax.sharding, "use_mesh")


def has_get_abstract_mesh() -> bool:
    return hasattr(jax.sharding, "get_abstract_mesh")


def has_top_level_shard_map() -> bool:
    return hasattr(jax, "shard_map")


def has_partial_auto_shard_map() -> bool:
    """Whether shard_map bodies with leftover *auto* (GSPMD) mesh axes can
    contain ``lax.scan`` / ``lax.axis_index``. 0.4.x XLA hard-crashes
    (CHECK sharding.IsManualSubgroup) partitioning a scan inside a partially
    manual computation and rejects the partition-id op axis_index lowers to;
    both were fixed alongside the top-level shard_map API."""
    return has_top_level_shard_map()


def has_lax_map_batch_size() -> bool:
    try:
        return "batch_size" in inspect.signature(jax.lax.map).parameters
    except (TypeError, ValueError):
        return False


def has_dict_cost_analysis() -> bool:
    """dict-shaped Compiled.cost_analysis() landed together with the new mesh
    API surface; 0.4.x returns a list of dicts. We can't probe the return shape
    without compiling a program, so this keys off a sibling API from the same
    era. ``normalized_cost_analysis`` itself dispatches on the actual value and
    never consults this flag."""
    return has_top_level_shard_map()


def detect_features() -> dict[str, bool]:
    """Snapshot of every capability flag against the live jax module."""
    return {
        "axis_type": has_axis_type(),
        "make_mesh": has_make_mesh(),
        "make_mesh_axis_types": make_mesh_takes_axis_types(),
        "set_mesh": has_set_mesh(),
        "use_mesh": has_use_mesh(),
        "get_abstract_mesh": has_get_abstract_mesh(),
        "top_level_shard_map": has_top_level_shard_map(),
        "partial_auto_shard_map": has_partial_auto_shard_map(),
        "dict_cost_analysis": has_dict_cost_analysis(),
        "lax_map_batch_size": has_lax_map_batch_size(),
    }


# Import-time snapshot, for logging/diagnostics. The shims re-probe at call
# time so monkeypatching (and late jax plugin loading) is honored; treat this
# table as informational, not as the dispatch source of truth.
VERSION_FEATURES: dict[str, bool] = detect_features()


def describe() -> str:
    """Human-readable capability table (used by launch diagnostics)."""
    lines = [f"jax {jax.__version__} compat features:"]
    for k, v in detect_features().items():
        lines.append(f"  {'+' if v else '-'} {k:22s} {_FEATURE_DOC.get(k, '')}")
    return "\n".join(lines)
