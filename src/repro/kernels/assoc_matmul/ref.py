"""Pure-jnp oracle for the bipolar associative-memory matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def assoc_matmul_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Bipolar dot products: q [B, d] uint8{0,1}, protos [C, d] uint8 -> [B, C] f32.

    dot = (2q-1)·(2p-1) in [-d, d]; equals d - 2·hamming(q, p).  This is the MXU
    formulation of the IMC crossbar MVM (Fig. 2): prototypes as conductances, query
    as voltages, dots as output currents.
    """
    qb = 2.0 * q.astype(jnp.float32) - 1.0
    pb = 2.0 * protos.astype(jnp.float32) - 1.0
    return qb @ pb.T
