"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def save(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def timed(fn, *args, **kw):
    """Wall-time fn(*args, **kw), blocking on any device results first —
    without the block, JAX's async dispatch makes this measure enqueue time."""
    t0 = time.time()
    out = fn(*args, **kw)
    import jax

    jax.block_until_ready(out)
    return out, time.time() - t0
