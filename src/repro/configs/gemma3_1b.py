"""Gemma-3 1B [hf:google/gemma-3-1b-pt] — dense GQA, 5:1 local:global attention.

26L d_model=1152 4H (GQA kv=1, head_dim 256) d_ff=6912 vocab=262144.
Pattern: 5 sliding-window (512) layers per global layer; dual RoPE theta
(10k local / 1M global); qk-norm; sandwich (pre+post) norms; tied embeddings;
sqrt(d) embedding scale; gelu MLP.

long_500k runs: global-layer KV is small (kv=1, head_dim 256) and is sharded over
the mesh; local layers see a 512-token window.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

_PATTERN = tuple((512 if (i + 1) % 6 != 0 else -1) for i in range(26))

CONFIG = ModelConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    window_pattern=_PATTERN,
    qk_norm=True,
    sandwich_norm=True,
    tie_embeddings=True,
    emb_scale=True,
    act="gelu",
    subquadratic=True,
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=96, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab=512, window_pattern=tuple((64 if (i + 1) % 6 != 0 else -1) for i in range(6)),
        loss_chunk=64, remat=False,
    )
