"""Production mesh builders.

Defined as functions (not module constants) so importing this module never
touches jax device state — device count is locked on first jax init, and the
dry-run needs to set XLA_FLAGS first.

Mesh layout (TPU v5e pods):
* single-pod: (16, 16) = ("data", "model") — 256 chips, 2D ICI torus.
* multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod" axis
  crosses the DCI/optical boundary, so rules put only batch (and ZeRO state) on
  it — no layer-wise collective traverses pods.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU CI: 1 device) as ("data","model")."""
    n = len(jax.devices())
    d = 1
    for cand in range(int(n**0.5), 0, -1):
        if n % cand == 0:
            d = cand
            break
    return make_mesh((d, n // d), ("data", "model"))
