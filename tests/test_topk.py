"""Multi-centroid associative memory: fused top-k kernel, masked majority,
k-means-in-packed-space training, and the coarse-to-fine two-level serve.

Single-device layers (kernel vs oracle, tie-breaking, masked majority,
multi-centroid train/predict) run in-process; the serve layers run on 8 fake
CPU devices via subprocess (same pattern as test_distributed.py — the main
test process must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier, hypervector as hv
from repro.core.scaleout import ScaleOutConfig, _validate_coarse
from repro.kernels import common
from repro.kernels.hamming import hamming_topk_banked
from repro.kernels.hamming.ops import _streamed_topk_banked
from repro.kernels.hamming.ref import hamming_topk_k_banked_ref
from repro.serving.hdc import centroid_to_class, multicentroid_bank

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

KEY = jax.random.PRNGKey(0)

# (g, b, c, d): multi-tile class axes, non-multiple-of-block shapes, c < k
# headroom, and a c spanning several 128-row tiles
SHAPES = [(4, 8, 128, 512), (3, 5, 7, 224), (8, 16, 2, 512), (1, 9, 300, 1024)]


def run8(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def _banks(g, b, c, d, seed=0):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, seed + g * b * c))
    q = hv.pack(hv.random_hv(k1, g * b, d)).reshape(g, b, -1)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, -1)
    return q, p


@pytest.mark.parametrize("g,b,c,d", SHAPES)
@pytest.mark.parametrize("use_kernel", [True, False])
def test_topk_matches_oracle(g, b, c, d, use_kernel):
    q, p = _banks(g, b, c, d)
    for k in sorted({1, 2, min(5, c)}):
        got_d, got_i = hamming_topk_banked(
            q, p, k=k, use_kernel=use_kernel, interpret=True
        )
        ref_d, ref_i = hamming_topk_k_banked_ref(q, p, k)
        np.testing.assert_array_equal(np.asarray(got_d), np.asarray(ref_d))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_topk_k1_bit_identical_to_fused_top1(use_kernel):
    g, b, c, d = 3, 7, 260, 512
    q, p = _banks(g, b, c, d, seed=1)
    top1_d, top1_i = hamming_topk_banked(
        q, p, use_kernel=use_kernel, interpret=True
    )
    k_d, k_i = hamming_topk_banked(
        q, p, k=1, use_kernel=use_kernel, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(k_d[..., 0]), np.asarray(top1_d))
    np.testing.assert_array_equal(np.asarray(k_i[..., 0]), np.asarray(top1_i))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_topk_tie_breaking_across_tiles(use_kernel):
    # adversarial ties: every prototype row identical, so every distance ties
    # and rank r must be class index r (first minimum at every rank) — with a
    # tiny bc the class axis spans many tiles, so the merge carry must
    # preserve the cross-tile rank order, not just the within-tile one
    g, b, c, d, k = 2, 4, 24, 256, 6
    kq, kp = jax.random.split(jax.random.fold_in(KEY, 99))
    q = hv.pack(hv.random_hv(kq, g * b, d)).reshape(g, b, -1)
    row = hv.pack(hv.random_hv(kp, g, d))
    p = jnp.broadcast_to(row[:, None, :], (g, c, row.shape[-1]))
    got_d, got_i = hamming_topk_banked(
        q, p, k=k, bc=8, use_kernel=use_kernel, interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(got_i),
        np.broadcast_to(np.arange(k, dtype=np.int32), (g, b, k)),
    )
    assert bool(jnp.all(got_d == got_d[..., :1]))
    # controlled distances: row j of each bank is the query with exactly j
    # bits flipped, and the 12 rows are duplicated at col j+12 — the exact
    # rank order is forced: (dist 0, col 0), (dist 0, col 12), (dist 1,
    # col 1), ... interleaving copies across the 8-wide tile boundaries
    q_bits = hv.random_hv(jax.random.fold_in(KEY, 3), g, d)
    flips = np.zeros((12, d), np.uint8)
    for j in range(12):
        flips[j, :j] = 1
    p_bits = np.asarray(q_bits)[:, None, :] ^ flips[None]   # [g, 12, d]
    p2 = jnp.concatenate([hv.pack(jnp.asarray(p_bits))] * 2, axis=1)
    q2 = hv.pack(q_bits)[:, None, :]                        # b = 1
    d2, i2 = hamming_topk_banked(
        q2, p2, k=6, bc=8, use_kernel=use_kernel, interpret=True
    )
    want_d = np.repeat(np.arange(3, dtype=np.int32), 2)     # 0,0,1,1,2,2
    want_i = np.array([0, 12, 1, 13, 2, 14], np.int32)
    np.testing.assert_array_equal(
        np.asarray(d2), np.broadcast_to(want_d, (g, 1, 6))
    )
    np.testing.assert_array_equal(
        np.asarray(i2), np.broadcast_to(want_i, (g, 1, 6))
    )


def test_streamed_topk_both_branches_match_oracle():
    g, b, c, d, k = 2, 6, 70, 512, 5
    q, p = _banks(g, b, c, d, seed=4)
    ref = hamming_topk_k_banked_ref(q, p, k)
    for key_encode in (True, False):
        got = _streamed_topk_banked(q, p, 16, key_encode=key_encode, k=k)
        for gx, rx in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(gx), np.asarray(rx))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_topk_bank_rows_indirection(use_kernel):
    t, g, b, c, d, k = 5, 8, 3, 40, 256, 3
    kq, kp, kr = jax.random.split(jax.random.fold_in(KEY, 5), 3)
    q = hv.pack(hv.random_hv(kq, g * b, d)).reshape(g, b, -1)
    table = hv.pack(hv.random_hv(kp, t * c, d)).reshape(t, c, -1)
    rows = jax.random.randint(kr, (g,), 0, t, dtype=jnp.int32)  # repeats likely
    got = hamming_topk_banked(
        q, table, k=k, bank_rows=rows, use_kernel=use_kernel, interpret=True
    )
    ref = hamming_topk_k_banked_ref(q, jnp.take(table, rows, axis=0), k)
    for gx, rx in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(gx), np.asarray(rx))


def test_hamming_blocks_policy():
    # defaults below / at the tall-C threshold; explicit overrides always win
    assert common.hamming_blocks(64, 512) == (common.BQ, common.BC)
    assert common.hamming_blocks(64, common.TALL_C) == (common.BQ, 4 * common.BC)
    assert common.hamming_blocks(64, 10 * common.TALL_C) == (
        common.BQ, 4 * common.BC
    )
    assert common.hamming_blocks(64, common.TALL_C - 1) == (common.BQ, common.BC)
    assert common.hamming_blocks(64, common.TALL_C, bq=4, bc=32) == (4, 32)
    assert common.hamming_blocks(64, 512, bc=256) == (common.BQ, 256)


def test_majority_packed_masked_matches_numpy():
    m, n, d = 9, 6, 256
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 6))
    bits = hv.random_hv(k1, m * n, d).reshape(m, n, d)
    hvs = hv.pack(bits)
    mask = jax.random.bernoulli(k2, 0.6, (m, n))
    got = hv.unpack(hv.majority_packed_masked(hvs, mask), d)
    b_np, m_np = np.asarray(bits), np.asarray(mask)
    counts = (b_np * m_np[..., None]).sum(0)
    want = (counts * 2 > m_np.sum(0)[..., None]).astype(np.uint8)
    np.testing.assert_array_equal(np.asarray(got), want)
    # empty mask -> all-zero words; full mask == unmasked majority_packed
    zero = hv.majority_packed_masked(hvs, jnp.zeros((m, n), bool))
    assert not np.asarray(zero).any()
    full = hv.majority_packed_masked(hvs[:, 0], jnp.ones((m,), bool))
    np.testing.assert_array_equal(
        np.asarray(full), np.asarray(hv.majority_packed(hvs[:, 0]))
    )
    # the threshold comparator must accept a TRACED mask (k-means assignment)
    jitted = jax.jit(hv.majority_packed_masked)
    np.testing.assert_array_equal(
        np.asarray(jitted(hvs, mask)),
        np.asarray(hv.majority_packed_masked(hvs, mask)),
    )


def test_train_multicentroid_accuracy():
    c, d, k_c = 20, 512, 4
    protos = hv.random_hv(jax.random.fold_in(KEY, 7), c, d)
    cents = classifier.train_multicentroid(
        jax.random.PRNGKey(1), protos, k_c, samples_per_class=16, ber=0.08
    )
    assert cents.shape == (c, k_c, d // 32) and cents.dtype == jnp.uint32
    # centroids stay near their class prototype: well under the d/2 distance
    # of an unrelated random HV
    pp = hv.pack(protos)
    dist = jax.vmap(lambda ce, pr: hv.hamming_distance_packed(ce, pr[None]))(
        cents, pp
    )
    assert int(jnp.max(dist)) < d // 4
    # clean queries classify perfectly; noisy queries should too at this scale
    for ber in (0.0, 0.1):
        qs = hv.flip_bits_packed(jax.random.PRNGKey(2), pp, ber)
        pred = classifier.multicentroid_predict(qs, cents, use_kernels=False)
        np.testing.assert_array_equal(np.asarray(pred), np.arange(c))


def test_multicentroid_bank_serving_helpers():
    c, d, k_c = 10, 256, 3
    protos = hv.random_hv(jax.random.fold_in(KEY, 8), c, d)
    for rep in ("packed", "unpacked"):
        cfg = ScaleOutConfig(n_classes=c * k_c, dim=d, m_tx=3, n_rx_cores=2,
                             batch=4, representation=rep)
        bank = multicentroid_bank(jax.random.PRNGKey(3), protos, k_c, cfg,
                                  samples_per_class=8)
        last = cfg.words if cfg.packed else cfg.dim
        assert bank.shape == (c * k_c, last) and bank.dtype == (
            jnp.uint32 if cfg.packed else jnp.uint8
        )
        # class-major layout: flat row i*k_c + j is class i's j-th centroid
        cents = classifier.train_multicentroid(
            jax.random.PRNGKey(3), protos, k_c, samples_per_class=8
        )
        flat = cents.reshape(c * k_c, -1)
        if not cfg.packed:
            flat = hv.unpack(flat, d).astype(jnp.uint8)
        np.testing.assert_array_equal(np.asarray(bank), np.asarray(flat))
    pred = jnp.array([[0, 2], [5, 29]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(centroid_to_class(pred, k_c)),
        np.asarray(pred) // k_c,
    )


def test_coarse_validation():
    base = dict(n_classes=64, dim=512, m_tx=3, n_rx_cores=8, batch=8)
    _validate_coarse(ScaleOutConfig(**base))  # coarse off: always fine
    _validate_coarse(ScaleOutConfig(**base, coarse_group=4, coarse_keep=2))
    with pytest.raises(ValueError, match="permuted"):
        _validate_coarse(ScaleOutConfig(**base, permuted=True, coarse_group=4))
    with pytest.raises(ValueError, match="divide"):
        _validate_coarse(ScaleOutConfig(**base, coarse_group=3))
    with pytest.raises(ValueError, match="divide"):
        _validate_coarse(ScaleOutConfig(**base, coarse_group=1))
    with pytest.raises(ValueError, match="coarse_keep"):
        _validate_coarse(ScaleOutConfig(**base, coarse_group=4, coarse_keep=0))


def test_coarse_identity_when_keep_covers_all_groups():
    # keep == n_grp means the screen keeps every group — the two-level serve
    # must be BIT-identical to the flat scan (pred AND maxsim), across every
    # vote collective and both representations
    run8("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    for rep in ("unpacked", "packed"):
        for coll in ("psum", "psum_packed", "rs_ag"):
            cfg = scaleout.ScaleOutConfig(
                n_classes=128, dim=512, m_tx=3, n_rx_cores=8, batch=16,
                representation=rep, collective=coll, noise="exact",
                use_kernels=False)
            # c_core=16, gs=4 -> n_grp=4 == keep
            ccfg = dataclasses.replace(cfg, coarse_group=4, coarse_keep=4)
            protos_u = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)
            protos = hv.pack(protos_u) if cfg.packed else protos_u
            _, queries = scaleout.make_queries(
                jax.random.PRNGKey(1), cfg, protos_u, 4)
            state = phy.state_from_ber(
                jnp.full((cfg.n_rx_cores,), 0.05, jnp.float32), cfg.m_tx)
            key = jax.random.PRNGKey(2)
            pf, sf = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)
            pc, sc = scaleout.make_ota_serve(mesh, ccfg)(protos, queries, state, key)
            assert bool(jnp.all(pf == pc)), (rep, coll)
            assert bool(jnp.all(sf == sc)), (rep, coll)
    print("ok")
    """)


def test_coarse_real_screen_matches_flat():
    # keep < n_grp: a REAL screen (survivor rescore on a strict subset). At
    # d=1024 the summary-separation margin makes a screen miss astronomically
    # unlikely, so predictions still match the flat scan trial-for-trial on
    # the same RNG stream
    run8("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    for rep in ("unpacked", "packed"):
        cfg = scaleout.ScaleOutConfig(
            n_classes=512, dim=1024, m_tx=3, n_rx_cores=8, batch=32,
            representation=rep, noise="exact", use_kernels=False)
        # c_core=64, gs=4 -> n_grp=16, keep=2: rescore 8 of 64 rows
        ccfg = dataclasses.replace(cfg, coarse_group=4, coarse_keep=2)
        protos_u = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)
        protos = hv.pack(protos_u) if cfg.packed else protos_u
        _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos_u, 4)
        state = phy.state_from_ber(
            jnp.full((cfg.n_rx_cores,), 0.02, jnp.float32), cfg.m_tx)
        key = jax.random.PRNGKey(2)
        pf, _ = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)
        pc, _ = scaleout.make_ota_serve(mesh, ccfg)(protos, queries, state, key)
        assert bool(jnp.all(pf == pc)), rep
    print("ok")
    """)


def test_coarse_multitenant_identity():
    # the slots path flattens (slot, core) into the kernel's bank axis via
    # bank_rows — keep == n_grp must stay bit-identical to the flat mt serve,
    # with slots SHARING tenant rows to exercise the indirection
    run8("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    S, T = 4, 2
    for rep in ("unpacked", "packed"):
        cfg = scaleout.ScaleOutConfig(
            n_classes=128, dim=512, m_tx=3, n_rx_cores=8, batch=8,
            representation=rep, noise="exact", use_kernels=False)
        ccfg = dataclasses.replace(cfg, coarse_group=4, coarse_keep=4)
        ps = [hv.random_hv(jax.random.fold_in(jax.random.PRNGKey(0), t),
                           cfg.n_classes, cfg.dim) for t in range(T)]
        store = jnp.stack([hv.pack(p) if cfg.packed else p for p in ps])
        qs, keys = [], []
        for s in range(S):
            _, q = scaleout.make_queries(
                jax.random.fold_in(jax.random.PRNGKey(1), s), cfg, ps[s % T], 4)
            qs.append(q)
            keys.append(jax.random.fold_in(jax.random.PRNGKey(2), s))
        rows = jnp.array([s % T for s in range(S)], jnp.int32)
        state = phy.state_from_ber(
            jnp.full((cfg.n_rx_cores,), 0.05, jnp.float32), cfg.m_tx)
        mt_f = scaleout.make_mt_ota_serve(mesh, cfg)
        mt_c = scaleout.make_mt_ota_serve(mesh, ccfg)
        pf, sf = mt_f(store, jnp.stack(qs), rows, state, jnp.stack(keys))
        pc, sc = mt_c(store, jnp.stack(qs), rows, state, jnp.stack(keys))
        assert bool(jnp.all(pf == pc)), rep
        assert bool(jnp.all(sf == sc)), rep
    print("ok")
    """)
