"""Shared helpers for the Pallas kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True`` — the kernel body runs in Python against the
same BlockSpec pipeline, so index maps / tiling bugs surface on CPU.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret mode on anything that is not a real TPU (CPU CI, dry-run host)."""
    return jax.default_backend() != "tpu"


def pad_dim(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    """Pad `axis` of `x` up to the next multiple of `multiple` with `fill`."""
    import jax.numpy as jnp

    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# Canonical Hamming-kernel tile sizes. ``BQ`` rides the 8-sublane dimension of
# the query tile; ``BC`` is one 128-lane row of the class axis. Every hamming
# entry point (fused kernels AND the streamed jnp fallback) resolves its block
# sizes through ``hamming_blocks`` so the tiling policy lives in exactly one
# place.
BQ = 8
BC = 128

# Class-axis size above which the wider class tile pays off (see
# ``hamming_blocks``).
TALL_C = 4096


def hamming_blocks(
    b: int, c: int, bq: int | None = None, bc: int | None = None
) -> tuple[int, int]:
    """Resolve the (bq, bc) tile sizes for a Hamming search over ``b`` queries
    and ``c`` classes; explicit values win, ``None`` takes the policy default.

    Tall class axes (the WHYPE-scale per-core shards and the coarse-to-fine
    screen/rescore) get a 4x wider class tile: 4x fewer revisits of the
    ``(g, i)`` running-min carry per output tile — and 4x fewer unrolled
    chunks in the streamed fallback — while an ``[8, 512, W]`` tile still sits
    far inside VMEM at the paper's word counts.
    """
    if bq is None:
        bq = BQ
    if bc is None:
        bc = 4 * BC if c >= TALL_C else BC
    return bq, bc
