"""Pure-jnp oracle for the packed Hamming similarity-search kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_search_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Packed-word Hamming distances via XOR + popcount.

    q: [B, W] uint32 (bit-packed queries), protos: [C, W] uint32 -> [B, C] int32.
    This is the operation an IMC associative-memory core performs in O(1); here it
    is the memory-bound digital realization used as the kernel oracle.
    """
    x = jnp.bitwise_xor(q[:, None, :], protos[None, :, :])  # [B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_search_banked_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Per-bank packed Hamming distances: q [G, B, W], protos [G, C, W] -> [G, B, C].

    Bank g's queries are compared only against bank g's prototypes — the
    per-IMC-core search of the scale-out serve step, as one batched op.
    """
    x = jnp.bitwise_xor(q[:, :, None, :], protos[:, None, :, :])  # [G, B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
