"""Mixtral 8x22B [arXiv:2401.04088] — MoE 8 experts top-2, sliding-window attn.

56L d_model=6144 48H (GQA kv=8, head_dim 128) expert d_ff=16384 vocab=32768,
window 4096 on every layer (per assignment). Sharding: 8 experts don't divide the
16-way model axis -> TP *inside* experts (d_expert 16384/16), experts replicated;
heads TP (48/16). Pure SWA -> ring KV cache -> long_500k runs.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    rope_theta=1_000_000.0,
    window_pattern=(4096,) * 56,
    moe=MoESettings(n_experts=8, top_k=2, d_expert=16384, group_size=1024, capacity_factor=1.25),
    subquadratic=True,
    rules_override={"experts": None, "expert_mlp": "model", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        window_pattern=(64,) * 2,
        moe=MoESettings(n_experts=4, top_k=2, d_expert=256, group_size=64, capacity_factor=1.5),
        loss_chunk=64, remat=False,
    )
