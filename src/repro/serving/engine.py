"""Serving engines: static-batch generate + continuous-batching slot ring.

Two execution styles over the same model interface (``prefill_fn`` /
``decode_fn`` / ``init_cache_fn``):

* ``Engine`` (static batch): a batch of same-length prompts is prefilled in one
  pass (KV cache padded to prompt + max_new), then ``lax.scan`` drives
  ``max_new`` decode steps entirely on device — one compiled program per prompt
  *shape*, no host round-trips. Compiled programs are cached keyed on every
  input shape (prompt length, vision prefix, ...), so mixed prompt lengths
  across calls each get a correctly-positioned program instead of silently
  reusing the first call's positions.

* ``ContinuousEngine`` (slot ring): a fixed number of decode *slots* share one
  jitted multi-slot step program. Requests are admitted into free slots by a
  per-prompt-shape compiled prefill whose KV cache is swapped into the live
  slot-stacked cache via ``dynamic_update_slice`` — cache row, next token,
  position, done flag, and RNG key, all per slot — and finished rows are
  evicted at step granularity while the remaining slots keep decoding. One
  step program + one admit program serve a stream of variable-length requests
  with no per-request recompile (prefill compiles are bounded by the length
  buckets the scheduler admits from). ``repro.serving.scheduler`` provides the
  request queue / admission policy on top.

Production notes (multi-host): the slot-stacked cache shards batch(slot) over
data axes and kv_heads/kv_seq over model per arch rules, same as the static
cache; admission swaps are slot-local ``dynamic_update_slice`` ops so they
stay on the slot's data shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new: int = 32
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int | None = None


def _sample(cfg: ServeConfig, logits: jax.Array, key: jax.Array) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / cfg.temperature, -1).astype(jnp.int32)


def _prompt_sig(batch: dict) -> tuple:
    """Static-shape signature of a prompt batch: prompt length plus the shape
    and dtype of every extra input (patch_embeds, positions, frames, ...)."""
    return tuple(sorted((k, tuple(v.shape), str(v.dtype)) for k, v in batch.items()))


def _vision_prefix(batch: dict) -> int:
    """Extra decoder positions in front of the prompt (VLM patch embeddings)."""
    return batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0


class Engine:
    """Static-batch engine: one compiled generate per prompt-shape bucket."""

    def __init__(self, model, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self._gen: dict[tuple, Any] = {}

    def _build(self, prompt_len: int, prefix: int):
        model, cfg = self.model, self.cfg
        pos0 = prompt_len + prefix
        pad_to = pos0 + cfg.max_new + 1

        def generate(params, batch, key):
            logits, cache = model.prefill_fn(params, batch, pad_to=pad_to)
            b = logits.shape[0]
            tok0 = _sample(cfg, logits, key)
            done0 = jnp.zeros((b,), bool)

            def step(carry, i):
                cache, tok, done, key = carry
                key, k1 = jax.random.split(key)
                logits, cache = model.decode_fn(params, cache, tok, pos0 + i)
                nxt = _sample(cfg, logits, k1)
                if cfg.eos_id is not None:
                    done = done | (tok == cfg.eos_id)
                    nxt = jnp.where(done, cfg.eos_id, nxt)
                return (cache, nxt, done, key), tok

            (_, _, _, _), toks = jax.lax.scan(
                step, (cache, tok0, done0, key), jnp.arange(cfg.max_new)
            )
            return jnp.moveaxis(toks, 0, 1)  # [B, max_new]

        return jax.jit(generate)

    def generate(self, params, batch: dict, key: jax.Array | None = None) -> jax.Array:
        """batch: model inputs incl. 'tokens' [B, S_prompt]. Returns [B, max_new]."""
        sig = _prompt_sig(batch)
        fn = self._gen.get(sig)
        if fn is None:
            fn = self._gen[sig] = self._build(
                batch["tokens"].shape[1], _vision_prefix(batch)
            )
        return fn(params, batch, key if key is not None else jax.random.PRNGKey(0))


class ContinuousEngine:
    """Slot-ring engine: step-granular admission/eviction over one compiled step.

    State is a pytree whose leaves carry a leading slot axis: the model's B=1
    cache stacked ``num_slots`` high, plus per-slot next-token / position /
    done / RNG-key arrays. Every slot's cache has identical capacity
    ``max_prompt_len (+ vision prefix) + max_new + 1`` regardless of the
    admitted prompt's length, so one decode-step program and one admission
    program cover the whole request stream. Empty slots decode garbage rows
    (fully masked attention — numerically harmless) until the next admission
    overwrites them.
    """

    def __init__(self, model, cfg: ServeConfig, num_slots: int, max_prompt_len: int,
                 max_prefix: int = 0):
        if cfg.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.model = model
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_prompt_len = max_prompt_len
        self.capacity = max_prompt_len + max_prefix + cfg.max_new + 1
        mw = model.cfg.max_window
        if 0 <= mw < max_prompt_len + max_prefix:
            raise ValueError(
                f"pure sliding-window model (window {mw} < max prompt "
                f"{max_prompt_len + max_prefix}): prefill would produce ring caches "
                "whose capacity depends on prompt length, breaking slot uniformity"
            )
        # One jit wrapper: jit itself specializes per prompt shape; the set just
        # tracks the distinct signatures (= compiles) seen, for warmup/telemetry.
        self._prefill = self._build_prefill()
        self._prefill_sigs: set[tuple] = set()
        self._step_fn = jax.jit(self._step_impl)
        self._admit_fn = jax.jit(self._admit_impl)

    # -- state ---------------------------------------------------------------

    def init_state(self) -> dict:
        n = self.num_slots
        cache1 = self.model.init_cache_fn(1, self.capacity)
        return {
            "cache": jax.tree.map(lambda x: jnp.stack([x] * n), cache1),
            "tok": jnp.zeros((n,), jnp.int32),
            "pos": jnp.zeros((n,), jnp.int32),
            "done": jnp.ones((n,), bool),   # empty slots stay EOS-frozen
            "key": jnp.zeros((n, 2), jnp.uint32),
        }

    # -- admission -----------------------------------------------------------

    def _build_prefill(self):
        model, cfg, capacity = self.model, self.cfg, self.capacity

        def prefill(params, batch, key):
            logits, cache = model.prefill_fn(params, batch, pad_to=capacity)
            return cache, _sample(cfg, logits, key)

        return jax.jit(prefill)

    def _admit_impl(self, state, slot_cache, tok0, pos0, key, slot):
        cache = jax.tree.map(
            lambda live, new: jax.lax.dynamic_update_slice_in_dim(
                live, new[None], slot, axis=0
            ),
            state["cache"], slot_cache,
        )
        return {
            "cache": cache,
            "tok": state["tok"].at[slot].set(tok0),
            "pos": state["pos"].at[slot].set(pos0),
            "done": state["done"].at[slot].set(False),
            "key": state["key"].at[slot].set(key),
        }

    def prefill_into_slot(self, params, state, batch: dict, slot: int,
                          key: jax.Array | None = None) -> tuple[dict, int]:
        """Prefill one request (B=1 batch) and swap it into `slot`.

        Returns (new state, first generated token). Compiles once per distinct
        prompt shape; the cache swap itself is one compiled program total.
        """
        assert batch["tokens"].shape[0] == 1, "continuous admission is per-request"
        prompt_len = batch["tokens"].shape[1]
        prefix = _vision_prefix(batch)
        if prompt_len + prefix + self.cfg.max_new + 1 > self.capacity:
            raise ValueError(
                f"prompt_len {prompt_len} (+prefix {prefix}) exceeds engine "
                f"capacity {self.capacity} - max_new {self.cfg.max_new} - 1"
            )
        key = key if key is not None else jax.random.PRNGKey(0)
        self._prefill_sigs.add(_prompt_sig(batch))
        cache, tok0 = self._prefill(params, batch, key)
        state = self._admit_fn(
            state, cache, tok0[0], jnp.int32(prompt_len + prefix), key, jnp.int32(slot)
        )
        return state, int(tok0[0])

    # -- decode --------------------------------------------------------------

    def _step_impl(self, params, state):
        cfg = self.cfg

        def decode_one(cache, tok, pos):
            return self.model.decode_fn(params, cache, tok, pos)

        # [N, 1, V] logits: each slot decodes its own position/cache row.
        logits, cache = jax.vmap(decode_one)(
            state["cache"], state["tok"][:, None], state["pos"]
        )
        keys = jax.vmap(jax.random.split)(state["key"])      # [N, 2, 2]
        key_next, k1 = keys[:, 0], keys[:, 1]
        nxt = jax.vmap(lambda l, k: _sample(cfg, l, k))(logits, k1)[:, 0]
        done = state["done"]
        if cfg.eos_id is not None:
            done = done | (state["tok"] == cfg.eos_id)
            nxt = jnp.where(done, cfg.eos_id, nxt)
        new_state = {
            "cache": cache,
            "tok": nxt,
            "pos": state["pos"] + 1,
            "done": done,
            "key": key_next,
        }
        return new_state, nxt

    def step(self, params, state) -> tuple[dict, jax.Array]:
        """One decode step for every slot. Returns (state, emitted tokens [N])."""
        return self._step_fn(params, state)
