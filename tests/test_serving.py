"""Serving engine: one compiled generate == step-by-step decode; EOS freezing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import get_model, init_params
from repro.serving import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


def test_engine_greedy_matches_manual_decode():
    cfg = configs.get_smoke("tinyllama_1_1b")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S, NEW = 2, 32, 6
    prompts = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    eng = Engine(model, ServeConfig(max_new=NEW, temperature=0.0))
    toks = np.asarray(eng.generate(params, {"tokens": prompts}))

    import functools
    logits, cache = jax.jit(functools.partial(model.prefill_fn, pad_to=S + NEW + 1))(
        params, {"tokens": prompts}
    )
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = []
    for i in range(NEW):
        manual.append(np.asarray(cur))
        logits, cache = jax.jit(model.decode_fn)(params, cache, cur, jnp.int32(S + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = np.stack(manual, 1)
    np.testing.assert_array_equal(toks, manual)


def test_engine_eos_freezes_sequences():
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S = 2, 16
    prompts = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    # pick the first greedily generated token as "EOS" so it triggers immediately
    eng0 = Engine(model, ServeConfig(max_new=4, temperature=0.0))
    first = int(np.asarray(eng0.generate(params, {"tokens": prompts}))[0, 0])
    eng = Engine(model, ServeConfig(max_new=6, temperature=0.0, eos_id=first))
    toks = np.asarray(eng.generate(params, {"tokens": prompts}))
    row = toks[0]
    hit = np.where(row == first)[0]
    assert hit.size > 0
    assert (row[hit[0]:] == first).all()  # frozen after EOS
