from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_spec,
    spec_for_shape,
    shard,
    tree_shardings,
    use_rules,
)
from repro.distributed.collectives import (  # noqa: F401
    majority_allreduce,
    ota_noise,
    sign_allreduce,
)
