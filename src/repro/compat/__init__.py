"""JAX version-portability layer.

The single place the repo touches version-sensitive JAX surface area; every
other module imports these shims instead of the raw APIs (enforced by grep in
the acceptance criteria and exercised on both branches by tests/test_compat.py):

* ``make_mesh(shape, axes)``          — Mesh construction (axis_types vs 0.4.x)
* ``set_mesh(mesh)``                  — ambient-mesh context manager
* ``current_mesh()``                  — ambient-mesh lookup (get_abstract_mesh
                                        vs the 0.4.x thread-local mesh)
* ``current_mesh_axis_sizes()``       — {axis: size} of the ambient mesh
* ``shard_map(...)``                  — new-style signature everywhere
* ``lax_map_batched(f, xs, batch_size=)`` — lax.map chunking (kwarg vs manual)
* ``normalized_cost_analysis(c)``     — flat-dict cost metrics everywhere
* ``VERSION_FEATURES`` / ``detect_features()`` / ``describe()`` — capability table
"""
from repro.compat.control import lax_map_batched
from repro.compat.mesh import make_mesh, set_mesh
from repro.compat.pallas import tpu_compiler_params
from repro.compat.sharding import current_mesh, current_mesh_axis_sizes, shard_map
from repro.compat.tree import tree_flatten_with_path
from repro.compat.version import VERSION_FEATURES, describe, detect_features
from repro.compat.xla import normalize_cost_result, normalized_cost_analysis

__all__ = [
    "make_mesh",
    "set_mesh",
    "current_mesh",
    "current_mesh_axis_sizes",
    "shard_map",
    "lax_map_batched",
    "tpu_compiler_params",
    "tree_flatten_with_path",
    "normalized_cost_analysis",
    "normalize_cost_result",
    "VERSION_FEATURES",
    "detect_features",
    "describe",
]
