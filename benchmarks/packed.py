"""Packed vs unpacked HDC fast path: dry-run HLO bytes + measured trials/s.

  PYTHONPATH=src python -m benchmarks.packed [--fast] [--kernels]

The first entry of the perf trajectory: for the scale-out serve step and the
classifier trial loop, compares the production `representation="unpacked"`
dataflow (uint8 HVs, fp32 bipolar MXU similarity) against the bit-packed fast
path (uint32 words, XOR+popcount) on three axes:

* per-device HBM bytes and collective bytes of the compiled serve step, from
  the trip-count-aware HLO cost analysis of a dry-run compile on an 8-device
  (2 data x 4 model) host mesh — the paper-faithful "psum" OTA collective, the
  guard-bit "psum_packed" variant (votes field-packed into uint32 lanes with
  ACTIVE-SLOT-AWARE fields sized by the M live voters, ONE uint32 psum,
  bit-identical tally, >= 2x fewer wire bytes — asserted), the "rs_ag"
  reduce-scatter variant (packed vote lanes on the scatter leg, d/8-byte
  all-gather with no unpack/repack round-trip when packed), and the physical
  `channel="symbol"` PHY tier (combo psum + in-graph constellation/AWGN/
  decision decode from a real precharacterized ChannelState; its combo psum
  must not exceed the int8 vote psum bytes — asserted). The packed serve
  cells also assert the fused top-1 never materializes the [G, B, C] distance
  tensor in the compiled HLO;
* measured wall-clock serve trials/s on the same mesh (CPU numbers — the
  representation ratio is what transfers, not the absolute rate);
* measured classifier-trial throughput (Table I workload, M=3, permuted).

The timed packed serve cells use the "bitplane" BSC mask generator (the
production noise mode); a separate exact-noise grid then asserts predictions
are bit-identical across {psum, psum_packed, rs_ag} x {unpacked, packed} x
{baseline, permuted} on the same RNG stream. Artifact:
benchmarks/artifacts/packed.json (uploaded per-PR by the CI perf-smoke step,
gated against BENCH_BASELINE.json by benchmarks/check_regression.py).
"""
from __future__ import annotations

import os

# 8 fake CPU devices BEFORE jax initializes — the serve step needs a real
# data x model mesh for its collectives to exist in the HLO.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

from benchmarks.common import save, timed


def _dist_tensor_specs(mesh, cfg) -> list:
    """HLO type strings of the per-device [G, B_l, C_core] distance tensor (and
    its moveaxis'd layout) that the fused top-1 must NOT materialize."""
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    data_size = mesh.devices.size // model_size
    cores = cfg.n_rx_cores // model_size
    b_l = cfg.batch // data_size
    c_core = cfg.n_classes // model_size // cores
    return [f"s32[{cores},{b_l},{c_core}]", f"s32[{b_l},{cores},{c_core}]"]


def _serve_cell(mesh, cfg, protos_u, reps: int, state=None):
    """Compile + analyze + time one serve configuration. Returns a stats dict."""
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.analysis import hlo_cost
    from repro.core import hypervector as hv, scaleout

    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    protos = hv.pack(protos_u) if cfg.packed else protos_u
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos_u, model_size)
    if state is None:
        state = phy.state_from_ber(
            jnp.full((cfg.n_rx_cores,), 0.01, jnp.float32), cfg.m_tx)
    key = jax.random.PRNGKey(2)

    serve = scaleout.make_ota_serve(mesh, cfg)
    # one AOT compile serves both the cost analysis and the timed execution
    # (calling the jitted fn would compile the same program a second time)
    compiled = serve.lower(protos, queries, state, key).compile()
    hc = hlo_cost.analyze_compiled(compiled)
    c_core = cfg.n_classes // cfg.n_rx_cores
    if cfg.packed and c_core > 128:
        # the fused top-1 streams <=128-class prototype chunks through a
        # running (min, argmin) carry: whenever the class axis spans multiple
        # chunks, the full [G, B_l, C_core] distance tensor must not exist
        # ANYWHERE in the compiled program, not even fusion-internal.
        text = compiled.as_text()
        offending = [s for s in _dist_tensor_specs(mesh, cfg) if s in text]
        assert not offending, (
            f"packed serve materializes the distance tensor: {offending}"
        )

    (pred, _), _ = timed(compiled, protos, queries, state, key)  # warm-up
    times = []
    for i in range(reps):
        t0 = time.time()
        out = compiled(protos, queries, state, jax.random.fold_in(key, i))
        jax.block_until_ready(out)
        times.append(time.time() - t0)
    dt = sum(times) / len(times)
    var = sum((t - dt) ** 2 for t in times) / len(times)
    return {
        "representation": cfg.representation,
        "collective": cfg.collective,
        "channel": cfg.channel,
        "noise": cfg.noise,
        "hbm_bytes_per_device": hc.hbm_bytes,
        "collective_bytes_per_device": hc.coll_total,
        "wall_s_per_step": dt,
        # per-rep spread: a gate trip with max >> min is host noise, not a
        # real slowdown — the triage signal rides in the artifact
        "wall_s_std": var ** 0.5,
        "wall_s_min": min(times),
        "wall_s_max": max(times),
        "trials_per_s": cfg.batch / dt,
    }, pred


def run(fast: bool = False, use_kernels: bool = False, quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.compat import make_mesh
    from repro.core import classifier, hypervector as hv, scaleout

    n_dev = jax.device_count()
    model_size = 4 if n_dev >= 8 else 1
    data_size = n_dev // model_size
    mesh = make_mesh((data_size, model_size), ("data", "model"))

    cfg = scaleout.ScaleOutConfig(
        # IMC-realistic balance: few cores, each holding a large associative
        # memory (c_core = 512/1024 rows) — the regime the popcount search and
        # the hamming kernel exist for.
        n_classes=4096 if fast else 8192,
        dim=1024 if fast else 2048,
        m_tx=3,
        n_rx_cores=2 * model_size,
        batch=128 if fast else 256,
        use_kernels=use_kernels,
        noise="bitplane",  # the packed production mask source (unpacked ignores)
        noise_planes=8,    # 2^-8 BER quantization — negligible against an
        #   accuracy curve flat out to BER 0.26 (Fig. 10), and the mask costs
        #   8 random bits/bit instead of the unpacked Bernoulli's 32
    )
    reps = 2 if fast else 5
    protos_u = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)

    out: dict = {
        "config": {
            "mesh": f"{data_size}x{model_size}", "n_classes": cfg.n_classes,
            "dim": cfg.dim, "m_tx": cfg.m_tx, "n_rx_cores": cfg.n_rx_cores,
            "batch": cfg.batch, "use_kernels": use_kernels, "reps": reps,
            "noise": cfg.noise, "noise_planes": cfg.noise_planes,
        },
        "serve": {},
    }

    for coll in ("psum", "psum_packed", "rs_ag"):
        row = {}
        for rep in ("unpacked", "packed"):
            c = dataclasses.replace(cfg, representation=rep, collective=coll)
            row[rep], _ = _serve_cell(mesh, c, protos_u, reps)
        row["hbm_ratio"] = (
            row["unpacked"]["hbm_bytes_per_device"]
            / max(row["packed"]["hbm_bytes_per_device"], 1.0)
        )
        row["collective_ratio"] = (
            row["unpacked"]["collective_bytes_per_device"]
            / max(row["packed"]["collective_bytes_per_device"], 1.0)
        )
        row["speedup"] = (
            row["packed"]["trials_per_s"] / row["unpacked"]["trials_per_s"]
        )
        out["serve"][coll] = row
        if not quiet:
            print(
                f"[serve/{coll}] HBM bytes/device: "
                f"unpacked {row['unpacked']['hbm_bytes_per_device']:.3e}  "
                f"packed {row['packed']['hbm_bytes_per_device']:.3e}  "
                f"ratio {row['hbm_ratio']:.1f}x (target >= 4x)\n"
                f"[serve/{coll}] collective bytes/device ratio "
                f"{row['collective_ratio']:.1f}x   trials/s: "
                f"unpacked {row['unpacked']['trials_per_s']:.0f}  "
                f"packed {row['packed']['trials_per_s']:.0f}  "
                f"({row['speedup']:.2f}x)"
            )

    # the streamed top-k fallback must keep the fused top-1's single-key
    # fusion property: each chunk's min-extraction merge consumes the distance
    # tile chunk-locally, so the full [G, B, C] distance tensor never exists
    # in the compiled HLO — not even fusion-internal.
    from repro.kernels.hamming import hamming_topk_banked

    g_tk, b_tk, c_tk, w_tk = 4, 32, 1024, 32
    kq = jax.random.split(jax.random.PRNGKey(7), 2)
    q_tk = hv.pack(hv.random_hv(kq[0], g_tk * b_tk, w_tk * 32)).reshape(
        g_tk, b_tk, w_tk
    )
    p_tk = hv.pack(hv.random_hv(kq[1], g_tk * c_tk, w_tk * 32)).reshape(
        g_tk, c_tk, w_tk
    )
    topk_fn = jax.jit(
        lambda qq, pp: hamming_topk_banked(qq, pp, k=8, use_kernel=False)
    )
    tk_text = topk_fn.lower(q_tk, p_tk).compile().as_text()
    tk_spec = f"s32[{g_tk},{b_tk},{c_tk}]"
    assert tk_spec not in tk_text, (
        f"streamed top-k fallback materializes the distance tensor {tk_spec}"
    )
    out["topk_fallback_streams"] = True
    if not quiet:
        print(f"[kernels] streamed top-k (k=8): no {tk_spec} in compiled HLO")

    # the physical symbol tier (channel="symbol"): constellation + AWGN +
    # decision-region decode in-graph, from a REAL precharacterized state —
    # the paper's BER abstraction made verifiable. Wire bytes should match the
    # int8 vote psum (the combo psum is int8 at M <= 7).
    state = scaleout.precharacterize_state(cfg)
    row = {}
    for rep in ("unpacked", "packed"):
        c = dataclasses.replace(cfg, representation=rep, channel="symbol",
                                collective="psum")
        row[rep], _ = _serve_cell(mesh, c, protos_u, reps, state=state)
    row["hbm_ratio"] = (
        row["unpacked"]["hbm_bytes_per_device"]
        / max(row["packed"]["hbm_bytes_per_device"], 1.0)
    )
    row["collective_ratio"] = (
        row["unpacked"]["collective_bytes_per_device"]
        / max(row["packed"]["collective_bytes_per_device"], 1.0)
    )
    row["speedup"] = row["packed"]["trials_per_s"] / row["unpacked"]["trials_per_s"]
    out["serve"]["symbol"] = row
    sym_wire = row["unpacked"]["collective_bytes_per_device"]
    psum_wire = out["serve"]["psum"]["unpacked"]["collective_bytes_per_device"]
    out["serve"]["symbol_wire_vs_psum"] = sym_wire / max(psum_wire, 1.0)
    assert sym_wire <= psum_wire * 1.05, (
        f"symbol combo psum {sym_wire:.0f} B should not exceed the int8 vote "
        f"psum {psum_wire:.0f} B at M={cfg.m_tx}"
    )
    if not quiet:
        print(
            f"[serve/symbol] physical-channel serve: HBM bytes/device "
            f"unpacked {row['unpacked']['hbm_bytes_per_device']:.3e}  "
            f"packed {row['packed']['hbm_bytes_per_device']:.3e}  "
            f"trials/s: unpacked {row['unpacked']['trials_per_s']:.0f}  "
            f"packed {row['packed']['trials_per_s']:.0f}; combo-psum wire == "
            f"vote-psum wire: {out['serve']['symbol_wire_vs_psum']:.2f}x"
        )

    # the guard-bit packed vote all-reduce must cut the OTA wire bytes >= 2x
    # vs the int8 psum (active-slot-aware 3-bit fields at M=3 give ~2.5x on
    # this cell regardless of the mesh-axis width)
    for rep in ("unpacked", "packed"):
        cut = (
            out["serve"]["psum"][rep]["collective_bytes_per_device"]
            / max(out["serve"]["psum_packed"][rep]["collective_bytes_per_device"], 1.0)
        )
        out["serve"][f"psum_packed_wire_cut_{rep}"] = cut
        assert cut >= 2.0, (
            f"psum_packed wire cut {cut:.2f}x < 2.0x ({rep} representation — "
            "slot-aware guard bits should give ~2.5x at M=3)"
        )
    if not quiet:
        print(
            "[serve] psum_packed wire cut vs psum: "
            f"unpacked {out['serve']['psum_packed_wire_cut_unpacked']:.2f}x  "
            f"packed {out['serve']['psum_packed_wire_cut_packed']:.2f}x "
            "(target >= 2.0x, slot-aware guard bits)"
        )

    # prediction identity on the same RNG stream, exact-noise masks: every
    # collective x representation must agree bit-for-bit within each bundling
    # (unpacked programs ignore cfg.noise, packed ones replay the same
    # Bernoulli draw with noise="exact").
    id_cfg = dataclasses.replace(cfg, batch=64, n_classes=1024, noise="exact")
    identical = True
    for permuted in (False, True):
        base = None
        for coll in ("psum", "psum_packed", "rs_ag"):
            for rep in ("unpacked", "packed"):
                c = dataclasses.replace(
                    id_cfg, representation=rep, collective=coll, permuted=permuted
                )
                _, pred = _serve_cell(mesh, c, protos_u[: c.n_classes], 1)
                if base is None:
                    base = pred
                else:
                    identical = identical and bool(jnp.all(pred == base))
    out["serve"]["prediction_identical"] = identical
    assert identical, "serve predictions diverged across collective/representation"
    if not quiet:
        print(
            "[serve] predictions identical across {psum, psum_packed, rs_ag} x "
            f"{{unpacked, packed}} x {{baseline, permuted}}: {identical}"
        )

    # classifier trials (Table I workload): packed vs unpacked trials/s
    tcfg = classifier.HDCTaskConfig(n_trials=400 if fast else 2000)
    key = jax.random.PRNGKey(0)
    clf = {}
    for rep in ("unpacked", "packed"):
        acc, _ = timed(classifier.run_accuracy, key, tcfg, 3, 0.01, "permuted",
                       representation=rep, use_kernels=use_kernels)  # compile
        _, dt = timed(classifier.run_accuracy, key, tcfg, 3, 0.01, "permuted",
                      representation=rep, use_kernels=use_kernels)
        clf[rep] = {"accuracy": float(acc), "wall_s": dt,
                    "trials_per_s": tcfg.n_trials / dt}
    clf["speedup"] = clf["packed"]["trials_per_s"] / clf["unpacked"]["trials_per_s"]
    assert clf["packed"]["accuracy"] == clf["unpacked"]["accuracy"], clf
    out["classifier"] = clf
    if not quiet:
        print(
            f"[classifier] trials/s: unpacked {clf['unpacked']['trials_per_s']:.0f}  "
            f"packed {clf['packed']['trials_per_s']:.0f}  ({clf['speedup']:.2f}x), "
            f"identical accuracy {clf['packed']['accuracy']:.4f}"
        )

    save("packed", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI perf-smoke sizes")
    ap.add_argument("--kernels", action="store_true",
                    help="route similarity through the Pallas kernels "
                         "(interpret mode on CPU — slow, but exercises the "
                         "kernel path end-to-end)")
    args = ap.parse_args()
    run(fast=args.fast, use_kernels=args.kernels)


if __name__ == "__main__":
    main()
