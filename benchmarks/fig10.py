"""Fig. 10: classification accuracy vs interconnect error rate (M=1, 100
classes, 512-bit) — the HDC robustness curve that licenses the lossy OTA link."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save
from repro.core import classifier

BERS = (0.0, 0.05, 0.1, 0.15, 0.2, 0.26, 0.3, 0.35, 0.4)


def run(n_trials: int = 600, quiet: bool = False, use_kernels: bool = True,
        representation: str = "unpacked") -> dict:
    """Kernel path on by default (interpret on CPU) so Pallas regressions move
    the figure — accuracy is bit-identical to the jnp path either way."""
    cfg = classifier.HDCTaskConfig(n_trials=n_trials)
    key = jax.random.PRNGKey(0)
    accs = [
        float(classifier.run_accuracy(key, cfg, 1, b, "baseline",
                                      representation=representation,
                                      use_kernels=use_kernels))
        for b in BERS
    ]
    if not quiet:
        for b, a in zip(BERS, accs):
            print(f"BER {b:.2f}  accuracy {a:.4f}")
        print(f"accuracy at BER 0.26: {accs[BERS.index(0.26)]:.4f} (paper: >0.99)")
    out = {"bers": list(BERS), "accuracy": accs,
           "use_kernels": use_kernels, "representation": representation}
    save("fig10", out)
    return out


if __name__ == "__main__":
    run()
