"""Serving launcher: static one-shot generation or continuous-batching replay.

  # static batch, one compiled generate per prompt shape
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 64 --max-new 16

  # continuous batching: replay a synthetic Poisson request trace through the
  # scheduler (mixed prompt lengths, step-granular admission/eviction)
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --stream --requests 32 --rate 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_batch(cfg, key, batch_size: int, prompt_len: int) -> dict:
    batch = {"tokens": jax.random.randint(key, (batch_size, prompt_len), 0, cfg.vocab)}
    if cfg.kind == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (batch_size, cfg.enc_seq, cfg.d_model), cfg.dtype
        )
    if cfg.kind == "vlm":
        from repro.models import vlm as vlm_lib
        sv = 16
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (batch_size, sv, cfg.d_model), cfg.dtype
        )
        batch["positions"] = vlm_lib.default_positions(batch_size, sv, prompt_len, (4, 4))
    return batch


def run_static(args, cfg, model, params, key):
    from repro.serving import Engine, ServeConfig

    batch = build_batch(cfg, key, args.batch, args.prompt_len)
    eng = Engine(model, ServeConfig(max_new=args.max_new, temperature=args.temperature))
    t0 = time.time()
    toks = jax.block_until_ready(eng.generate(params, batch, key))
    t1 = time.time()
    toks2 = jax.block_until_ready(eng.generate(params, batch, key))  # warm
    t2 = time.time()
    print(f"generated {toks.shape} tokens; compile+run {t1-t0:.2f}s, warm {t2-t1:.3f}s "
          f"({args.batch*args.max_new/(t2-t1):.1f} tok/s)")
    print("sample:", jnp.asarray(toks2[0][:12]).tolist())


def run_stream(args, cfg, model, params):
    """Replay a synthetic Poisson trace through the continuous-batching path."""
    from repro.serving import ContinuousEngine, Scheduler, ServeConfig

    if args.prompt_lens:
        lengths = tuple(int(x) for x in args.prompt_lens.split(","))
    else:
        lengths = tuple(sorted({max(4, args.prompt_len // 2), args.prompt_len,
                                args.prompt_len * 2}))
    rng = np.random.default_rng(args.seed)
    req_lens = rng.choice(lengths, size=args.requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, (int(L),)), jnp.int32)
               for L in req_lens]

    eng = ContinuousEngine(
        model, ServeConfig(max_new=args.max_new, temperature=args.temperature),
        num_slots=args.slots, max_prompt_len=max(lengths),
    )

    # Warm every compiled program (one prefill per length bucket, admit, step)
    # on a throwaway scheduler so the replay measures execution, not compiles.
    t0 = time.time()
    warm = Scheduler(eng, params)
    for L in lengths:
        warm.submit(jnp.zeros((int(L),), jnp.int32), max_new=min(2, args.max_new))
    warm.run(timeout=600)
    print(f"warmup: {len(eng._prefill_sigs)} prefill buckets + step/admit compiled "
          f"in {time.time()-t0:.1f}s")

    sched = Scheduler(eng, params)
    t0 = time.monotonic()
    nxt = 0
    while len(sched.results) < args.requests:
        now = time.monotonic() - t0
        while nxt < args.requests and arrivals[nxt] <= now:
            sched.submit(prompts[nxt])
            nxt += 1
        if sched.pending or sched.running:
            sched.step()
        elif nxt < args.requests:
            time.sleep(min(arrivals[nxt] - now, 0.01))
    wall = time.monotonic() - t0

    done = list(sched.results.values())
    n_tok = sum(len(c.tokens) for c in done)
    lat = np.asarray([c.latency for c in done])
    print(f"{args.requests} requests (lens {lengths}, rate {args.rate}/s, "
          f"{args.slots} slots): {wall:.2f}s wall, {n_tok} tokens, "
          f"{n_tok/wall:.1f} tok/s, {sched.steps} decode steps")
    print(f"request latency p50 {np.percentile(lat, 50)*1e3:.0f}ms  "
          f"p95 {np.percentile(lat, 95)*1e3:.0f}ms  max {lat.max()*1e3:.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: replay a Poisson request trace")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=8.0, help="arrivals per second")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated prompt-length buckets (default: derived "
                         "from --prompt-len)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro import configs
    from repro.models import get_model, init_params

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.specs)

    if args.stream:
        if cfg.kind != "decoder":
            raise SystemExit("--stream replay drives text prompts only (kind=decoder)")
        run_stream(args, cfg, model, params)
    else:
        run_static(args, cfg, model, params, key)


if __name__ == "__main__":
    main()
