"""Coarse-to-fine associative search: flat scan vs two-level serve C-sweep.

  PYTHONPATH=src python -m benchmarks.topk [--fast]

The multi-centroid growth path (MEMHD-style k centroids per class, permuted
replicas, multi-tenant banks) multiplies the class axis C while everything
else in the serve step stays fixed — so past a few thousand rows per core the
per-core associative scan IS the step. This benchmark sweeps C over three
orders of magnitude and compares, on the same 8-device (2 data x 4 model)
host mesh and the same RNG stream:

* the flat serve (every query XOR+popcounts all C_core rows of its core), and
* the coarse-to-fine serve (``coarse_group``/``coarse_keep``): screen the
  C_core/gs strict-majority group summaries with the fused top-k, exact
  rescore only the keep*gs survivor rows — per-query row-visits drop from
  C_core to C_core/gs + keep*gs.

Both serves run the identical wire path (same OTA collective, same PHY noise
from the same keys), so predictions are directly comparable trial-for-trial;
the sweep reports the mismatch count (expected 0: the screen keeps 'keep'
groups against an analytic summary-separation margin of z ~ 4.5 sigma at
d=2048, gs=8) and the speedup, which grows with C (superlinear row-visit cut:
at C=16k the coarse step visits ~6.4x fewer rows, at C=100k ~7.7x, with the
summary screen itself shrinking relative to the flat scan as C_core grows).
(The companion streamed-top-k HLO assertion — the fallback's k-widened carry
must never materialize the [G, B, C] distances — lives in benchmarks/packed.py
next to the top-1 distance-tensor assert.)

Artifact: benchmarks/artifacts/topk.json — the C=102400 row is gated against
BENCH_BASELINE.json (parity + speedup floor) by benchmarks/check_regression.py.
"""
from __future__ import annotations

import os

# 8 fake CPU devices BEFORE jax initializes — the serve step needs a real
# data x model mesh for its collectives to exist in the HLO.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

from benchmarks.common import save, timed

# (C, coarse_group, coarse_keep): keep ~ n_grp at tiny C (identity regime),
# then a fixed (8, 8) screen whose row-visit cut scales with C_core. The gate
# sits at the WHYPE class count, where the screen's fixed costs (the per-step
# summary majority, the survivor gather) are fully amortized and the speedup
# (~5.8x on this host) approaches the raw row-visit cut; the C=16384 row
# documents the crossover regime (~3.4x) without gating it.
SWEEP = [(64, 4, 2), (1024, 8, 8), (16384, 8, 8), (102400, 8, 8)]
GATE_C = 102400


def _cell(mesh, cfg, protos_p, queries, state, key, reps):
    """Compile + time one serve variant; returns (trials/s, [eval preds])."""
    import jax

    from repro.core import scaleout

    serve = scaleout.make_ota_serve(mesh, cfg)
    compiled = serve.lower(protos_p, queries, state, key).compile()
    (pred0, _), _ = timed(compiled, protos_p, queries, state, key)  # warm-up
    t0 = time.time()
    for i in range(reps):
        out = compiled(protos_p, queries, state, jax.random.fold_in(key, i))
    jax.block_until_ready(out)
    dt = (time.time() - t0) / reps
    preds = [pred0] + [
        compiled(protos_p, queries, state, jax.random.fold_in(key, i))[0]
        for i in range(reps)
    ]
    return cfg.batch / dt, preds


def run(fast: bool = False, quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.compat import make_mesh
    from repro.core import hypervector as hv, scaleout

    n_dev = jax.device_count()
    model_size = 4 if n_dev >= 8 else 1
    data_size = n_dev // model_size
    mesh = make_mesh((data_size, model_size), ("data", "model"))

    base = scaleout.ScaleOutConfig(
        n_classes=64,          # per-row override below
        dim=2048,              # summary-separation margin z ~ sqrt(d/(pi*gs))
        m_tx=3,
        n_rx_cores=2 * model_size,
        batch=512,             # the serving regime: the per-step in-graph
        #   summary recompute is O(C_core) once per step and amortizes across
        #   the batch — at tiny batches it eats the screen's win
        representation="packed",
        use_kernels=False,     # CPU: streamed fallback is the fast path
        noise="exact",         # same Bernoulli stream flat vs coarse
    )
    ber = 0.02
    reps = 2 if fast else 5
    sweep = SWEEP  # --fast trims reps only: the gate row must always run

    out: dict = {
        "config": {
            "mesh": f"{data_size}x{model_size}", "dim": base.dim,
            "m_tx": base.m_tx, "n_rx_cores": base.n_rx_cores,
            "batch": base.batch, "noise": base.noise, "ber": ber,
            "reps": reps, "gate_c": GATE_C,
        },
        "sweep": [],
    }

    for c, gs, keep in sweep:
        flat_cfg = dataclasses.replace(base, n_classes=c)
        coarse_cfg = dataclasses.replace(
            flat_cfg, coarse_group=gs, coarse_keep=keep
        )
        protos_u = hv.random_hv(jax.random.PRNGKey(c), c, base.dim)
        protos_p = hv.pack(protos_u)
        _, queries = scaleout.make_queries(
            jax.random.PRNGKey(c + 1), flat_cfg, protos_u, model_size
        )
        del protos_u
        state = phy.state_from_ber(
            jnp.full((base.n_rx_cores,), ber, jnp.float32), base.m_tx
        )
        key = jax.random.PRNGKey(2)

        flat_tps, flat_preds = _cell(
            mesh, flat_cfg, protos_p, queries, state, key, reps
        )
        coarse_tps, coarse_preds = _cell(
            mesh, coarse_cfg, protos_p, queries, state, key, reps
        )
        # identical inputs + keys => identical PHY noise => exact comparison
        mism = sum(
            int(jnp.sum(pf != pc))
            for pf, pc in zip(flat_preds, coarse_preds)
        )
        c_core = c // base.n_rx_cores
        row = {
            "c": c, "c_core": c_core, "coarse_group": gs, "coarse_keep": keep,
            "row_visit_cut": c_core / (c_core / gs + keep * gs),
            "flat_trials_per_s": flat_tps,
            "coarse_trials_per_s": coarse_tps,
            "speedup": coarse_tps / flat_tps,
            "mismatches": mism,
            "trials_compared": (reps + 1) * base.batch,
        }
        out["sweep"].append(row)
        if not quiet:
            print(
                f"[topk] C={c:>6}  c_core={c_core:>5}  gs={gs} keep={keep}  "
                f"row-cut {row['row_visit_cut']:.1f}x  trials/s: "
                f"flat {flat_tps:.0f}  coarse {coarse_tps:.0f}  "
                f"({row['speedup']:.2f}x)  mismatches {mism}/"
                f"{row['trials_compared']}"
            )
        assert mism == 0, (
            f"coarse-to-fine diverged from flat scan at C={c}: {mism} "
            f"mismatched predictions"
        )

    save("topk", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI perf-smoke timing (fewer reps; same C sweep — "
                         "the gate row must always run)")
    args = ap.parse_args()
    run(fast=args.fast)


if __name__ == "__main__":
    main()
