"""Kimi K2 1T-A32B [arXiv:2501 (Kimi K2 paper table)] — trillion-param MoE.

61L d_model=7168 64H (GQA kv=8, head_dim 112) vocab=163840; MoE: 384 routed
experts top-8 + 1 shared expert, expert d_ff=2048. Per the assignment all 61
layers are MoE (the released model makes layer 0 dense) and attention is GQA
(the released model uses MLA) — both noted in DESIGN.md §Arch-applicability.

Sharding: EP 384/16 = 24 experts per model shard; expert weights additionally
FSDP-sharded on the embed dim over "data" (1T params -> ~4 GB/chip on the
multi-pod mesh); ZeRO-1 optimizer state.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    rope_theta=50_000.0,
    moe=MoESettings(
        n_experts=384, top_k=8, d_expert=2048, n_shared=1,
        group_size=2048, capacity_factor=1.25,
    ),
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
        moe=MoESettings(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                        group_size=64, capacity_factor=1.5),
        loss_chunk=64, remat=False,
    )
