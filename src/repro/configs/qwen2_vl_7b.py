"""Qwen2-VL 7B [arXiv:2409.12191] — VLM backbone with M-RoPE.

28L d_model=3584 28H (GQA kv=4, head_dim 128) d_ff=18944 vocab=152064.
M-RoPE sections (t, h, w) = (16, 24, 24) over the 64 half-dim slots; dynamic-
resolution vision tower is a stub (input_specs supplies patch embeddings).
Sharding: 28 heads don't divide 16 -> FSDP + MLP TP.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    kind="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
        vocab=512, mrope_sections=(4, 6, 6), loss_chunk=64, remat=False,
    )
