"""Serving launcher: static one-shot generation or continuous-batching replay.

  # static batch, one compiled generate per prompt shape
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 64 --max-new 16

  # continuous batching: replay a synthetic Poisson request trace through the
  # scheduler (mixed prompt lengths, step-granular admission/eviction)
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --stream --requests 32 --rate 8 --slots 4 --max-new 16

  # HDC-as-a-service: multi-tenant continuous batching over the OTA serve
  # path (tenant-tagged Poisson arrivals, one banked launch per step)
  PYTHONPATH=src python -m repro.launch.serve --hdc \
      --requests 64 --rate 200 --slots 8 --tenants 4 --hdc-batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_batch(cfg, key, batch_size: int, prompt_len: int) -> dict:
    batch = {"tokens": jax.random.randint(key, (batch_size, prompt_len), 0, cfg.vocab)}
    if cfg.kind == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (batch_size, cfg.enc_seq, cfg.d_model), cfg.dtype
        )
    if cfg.kind == "vlm":
        from repro.models import vlm as vlm_lib
        sv = 16
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (batch_size, sv, cfg.d_model), cfg.dtype
        )
        batch["positions"] = vlm_lib.default_positions(batch_size, sv, prompt_len, (4, 4))
    return batch


def run_static(args, cfg, model, params, key):
    from repro.serving import Engine, ServeConfig

    batch = build_batch(cfg, key, args.batch, args.prompt_len)
    eng = Engine(model, ServeConfig(max_new=args.max_new, temperature=args.temperature))
    t0 = time.time()
    toks = jax.block_until_ready(eng.generate(params, batch, key))
    t1 = time.time()
    toks2 = jax.block_until_ready(eng.generate(params, batch, key))  # warm
    t2 = time.time()
    print(f"generated {toks.shape} tokens; compile+run {t1-t0:.2f}s, warm {t2-t1:.3f}s "
          f"({args.batch*args.max_new/(t2-t1):.1f} tok/s)")
    print("sample:", jnp.asarray(toks2[0][:12]).tolist())


def run_stream(args, cfg, model, params):
    """Replay a synthetic Poisson trace through the continuous-batching path."""
    from repro.serving import ContinuousEngine, Scheduler, ServeConfig

    if args.prompt_lens:
        lengths = tuple(int(x) for x in args.prompt_lens.split(","))
    else:
        lengths = tuple(sorted({max(4, args.prompt_len // 2), args.prompt_len,
                                args.prompt_len * 2}))
    rng = np.random.default_rng(args.seed)
    req_lens = rng.choice(lengths, size=args.requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, (int(L),)), jnp.int32)
               for L in req_lens]

    eng = ContinuousEngine(
        model, ServeConfig(max_new=args.max_new, temperature=args.temperature),
        num_slots=args.slots, max_prompt_len=max(lengths),
    )

    # Warm every compiled program (one prefill per length bucket, admit, step)
    # on a throwaway scheduler so the replay measures execution, not compiles.
    t0 = time.time()
    warm = Scheduler(eng, params)
    for L in lengths:
        warm.submit(jnp.zeros((int(L),), jnp.int32), max_new=min(2, args.max_new))
    warm.run(timeout=600)
    print(f"warmup: {len(eng._prefill_sigs)} prefill buckets + step/admit compiled "
          f"in {time.time()-t0:.1f}s")

    sched = Scheduler(eng, params)
    t0 = time.monotonic()
    nxt = 0
    while len(sched.results) < args.requests:
        now = time.monotonic() - t0
        while nxt < args.requests and arrivals[nxt] <= now:
            sched.submit(prompts[nxt])
            nxt += 1
        if sched.pending or sched.running:
            sched.step()
        elif nxt < args.requests:
            time.sleep(min(arrivals[nxt] - now, 0.01))
    wall = time.monotonic() - t0

    done = list(sched.results.values())
    n_tok = sum(len(c.tokens) for c in done)
    lat = np.asarray([c.latency for c in done])
    print(f"{args.requests} requests (lens {lengths}, rate {args.rate}/s, "
          f"{args.slots} slots): {wall:.2f}s wall, {n_tok} tokens, "
          f"{n_tok/wall:.1f} tok/s, {sched.steps} decode steps")
    print(f"request latency p50 {np.percentile(lat, 50)*1e3:.0f}ms  "
          f"p95 {np.percentile(lat, 95)*1e3:.0f}ms  max {lat.max()*1e3:.0f}ms")


def run_hdc_stream(args):
    """Multi-tenant HDC serving: tenant-tagged Poisson arrivals through the
    slot-ring ``HDCScheduler`` — every step one banked OTA serve launch."""
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import classifier, hypervector as hv, scaleout
    from repro.serving import HDCEngine, HDCScheduler

    rep = "unpacked" if args.unpacked else "packed"
    cfg = scaleout.ScaleOutConfig(
        n_classes=args.classes, dim=args.dim, m_tx=3, n_rx_cores=8,
        batch=args.hdc_batch, use_kernels=False, representation=rep,
        noise="exact",
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    tcfg = classifier.HDCTaskConfig(n_classes=args.classes, dim=args.dim)
    books = classifier.make_tenant_codebooks(
        jax.random.PRNGKey(0), tcfg, args.tenants
    )
    state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.02), cfg.m_tx)
    eng = HDCEngine(mesh, cfg, state, num_slots=args.slots,
                    max_tenants=args.tenants)
    for t in range(args.tenants):
        eng.registry.onboard(t, hv.pack(books[t]) if cfg.packed else books[t])

    rng = np.random.default_rng(args.seed)
    tenant_of = rng.integers(0, args.tenants, args.requests)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    queries = [
        scaleout.make_queries(jax.random.PRNGKey(100 + i), cfg,
                              books[int(t)], 1)[1]
        for i, t in enumerate(tenant_of)
    ]

    # warm the serve step and the full-ring batched admit before replaying
    t0 = time.time()
    warm = HDCScheduler(eng)
    for _ in range(args.slots):
        warm.submit(0, queries[0])
    warm.run(timeout=600)
    print(f"warmup: mt serve + K={args.slots} admit compiled in "
          f"{time.time()-t0:.1f}s")

    sched = HDCScheduler(eng)
    t0 = time.monotonic()
    nxt = 0
    while len(sched.results) < args.requests:
        now = time.monotonic() - t0
        while nxt < args.requests and arrivals[nxt] <= now:
            sched.submit(int(tenant_of[nxt]), queries[nxt])
            nxt += 1
        if sched.pending or sched.running:
            sched.step()
        elif nxt < args.requests:
            time.sleep(min(arrivals[nxt] - now, 0.01))
    wall = time.monotonic() - t0

    lat = np.asarray([c.latency for c in sched.results.values()])
    n_trials = args.requests * cfg.batch
    print(f"{args.requests} requests x {cfg.batch} trials, {args.tenants} "
          f"tenants ({rep}, rate {args.rate}/s, {args.slots} slots): "
          f"{wall:.2f}s wall, {n_trials/wall:.0f} trials/s, "
          f"{sched.steps} serve steps")
    print(f"request latency p50 {np.percentile(lat, 50)*1e3:.0f}ms  "
          f"p95 {np.percentile(lat, 95)*1e3:.0f}ms  max {lat.max()*1e3:.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM architecture (required unless --hdc)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: replay a Poisson request trace")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=8.0, help="arrivals per second")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated prompt-length buckets (default: derived "
                         "from --prompt-len)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hdc", action="store_true",
                    help="multi-tenant HDC serving over the OTA wire path")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--hdc-batch", type=int, default=4,
                    help="(--hdc) trials per request")
    ap.add_argument("--classes", type=int, default=128)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--unpacked", action="store_true",
                    help="(--hdc) elementwise representation instead of packed")
    args = ap.parse_args()

    if args.hdc:
        run_hdc_stream(args)
        return
    if not args.arch:
        raise SystemExit("--arch is required unless --hdc")

    from repro import configs
    from repro.models import get_model, init_params

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.specs)

    if args.stream:
        if cfg.kind != "decoder":
            raise SystemExit("--stream replay drives text prompts only (kind=decoder)")
        run_stream(args, cfg, model, params)
    else:
        run_static(args, cfg, model, params, key)


if __name__ == "__main__":
    main()
