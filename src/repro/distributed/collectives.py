"""Collectives implementing the paper's OTA majority as mesh operations.

The paper's observation, transplanted to a TPU pod: *a reduce-then-broadcast of
binary data is one collective, and it may be lossy*. On the wireless chip the
superposition happens in the channel; on a pod the same semantics is an all-reduce
whose payload is 1 bit/element (sent as ±1) followed by a sign, with an optional
per-receiver binary-symmetric channel modelling the measured OTA BER.

These run inside ``compat.shard_map`` bodies (manual axes). The float variant
(``sign_allreduce``) is the majority-vote signSGD aggregation used by the
``sign_majority`` gradient-compression mode of the trainer — the beyond-paper
application of the same collective to data-parallel LM training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hypervector as hv


def ota_noise(key: jax.Array, bits: jax.Array, ber, axis_name: str | None = None) -> jax.Array:
    """Binary symmetric channel at rate `ber` on uint8 {0,1} bits.

    When `axis_name` is given, the key is folded with this device's index along
    that axis so every receiver sees an *independent* noisy copy — the paper's
    "each IMC core receives a slightly different version of Q".
    """
    if axis_name is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    flips = jax.random.bernoulli(key, ber, bits.shape)
    return jnp.bitwise_xor(bits, flips.astype(bits.dtype))


def ota_noise_packed(
    key: jax.Array,
    words: jax.Array,
    ber,
    axis_name: str | None = None,
    mode: str = "exact",
    planes: int = 16,
) -> jax.Array:
    """BSC on bit-packed uint32 words [..., W] — the packed serve path's channel.

    mode "exact": the flip mask is the same Bernoulli draw `ota_noise` makes
    (generated per 32-lane block, then packed), so the packed pipeline is
    bit-identical to the unpacked one on the same key. mode "bitplane": the
    mask is drawn directly as uint32 words via a bit-sliced `planes`-plane
    comparator (`hv.bernoulli_words`) — `planes` random bits per mask bit
    instead of 32, and no unpacked intermediate, at 2^-planes BER quantization;
    the production choice when replaying the unpacked stream doesn't matter.
    """
    if axis_name is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    if mode == "exact":
        return hv.flip_bits_packed(key, words, ber)
    if mode == "bitplane":
        return jnp.bitwise_xor(
            words, hv.bernoulli_words(key, ber, words.shape, precision=planes)
        )
    raise ValueError(f"unknown packed noise mode {mode!r}")


def majority_allreduce(
    bits: jax.Array,
    axis_name: str,
    *,
    key: jax.Array | None = None,
    ber=None,
    rx_axis_name: str | None = None,
) -> jax.Array:
    """OTA majority bundling across `axis_name`: uint8 {0,1} shards -> majority bits.

    Equivalent to the paper's over-the-air computation: every device along
    `axis_name` contributes its hypervector; all devices receive maj(·) in a single
    all-reduce. Ties on even group size resolve to 0 (`tally > 0`) — the repo-wide
    convention shared by `hv.majority`/`hv.majority_packed` (without a key) and
    the `kernels.majority` oracle, asserted in tests/test_hdc_core.py.
    Optional (key, ber): apply the OTA error channel to the *received* copy,
    independently per device along `rx_axis_name` (default: the reduce axis).
    """
    bipolar = 2 * bits.astype(jnp.int32) - 1
    votes = jax.lax.psum(bipolar, axis_name)
    out = (votes > 0).astype(jnp.uint8)
    if ber is not None:
        assert key is not None, "OTA noise needs a PRNG key"
        out = ota_noise(key, out, ber, rx_axis_name or axis_name)
    return out


def sign_allreduce(
    x: jax.Array, axis_name: str, *, key=None, ber=None, device_index=None
) -> jax.Array:
    """Majority-vote sign aggregation (1-bit compressed all-reduce) for floats.

    Payload on the wire is sign(x) (1 bit/element vs 32): the majority-vote
    signSGD aggregation [Bernstein et al.] — structurally identical to the
    paper's OTA bundling with gradients in place of query hypervectors. Optional
    BER applies the OTA channel to the result (sign flips), which HDC-style error
    tolerance (and signSGD's) absorbs.

    `device_index`: this device's linear index along the reduce axes, used to
    decorrelate the per-receiver noise. Callers inside a *partially-auto*
    shard_map (the sign_majority trainer) must pass it explicitly (threaded in
    as a sharded iota input): `lax.axis_index` there lowers to a partition-id
    HLO op that 0.4.x XLA's SPMD partitioner rejects. Fully-manual bodies may
    omit it and get the `lax.axis_index` fold, which is fine on every pin.
    """
    votes = jax.lax.psum(jnp.sign(x).astype(jnp.float32), axis_name)
    out = jnp.sign(votes)
    if ber is not None:
        assert key is not None, "OTA noise needs a PRNG key"
        if device_index is not None:
            key = jax.random.fold_in(key, device_index)
        else:
            axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
            for ax in axes:
                key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        flips = jax.random.bernoulli(key, ber, out.shape)
        out = jnp.where(flips, -out, out)
    return out.astype(x.dtype)
