"""Serving throughput: static batch-of-one engine vs continuous batching.

  PYTHONPATH=src python -m benchmarks.serving [--fast]

Offered load is a fixed set of mixed-length requests, all queued at t=0, so
request latency includes queueing — the quantity continuous batching improves.
The static baseline is the one-compile-per-prompt-shape ``Engine`` serving one
request per generate (mixed lengths defeat whole-batch prefill); continuous is
the slot-ring ``ContinuousEngine`` behind the ``Scheduler``. Both paths are
warmed first so the numbers measure execution, not compiles, and the greedy
outputs are cross-checked token-identical before timing is reported.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save, timed


def _pcts(lat: list[float]) -> dict:
    a = np.asarray(lat)
    return {"p50_ms": float(np.percentile(a, 50) * 1e3),
            "p95_ms": float(np.percentile(a, 95) * 1e3),
            "mean_ms": float(a.mean() * 1e3)}


def run(arch: str = "tinyllama-1.1b", n_requests: int = 24, slots: int = 4,
        max_new: int = 16, lengths: tuple = (16, 32, 64), seed: int = 0,
        quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import get_model, init_params
    from repro.serving import ContinuousEngine, Engine, Scheduler, ServeConfig

    cfg = configs.get_smoke(arch)
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    rng = np.random.default_rng(seed)
    req_lens = [int(lengths[i % len(lengths)]) for i in range(n_requests)]
    rng.shuffle(req_lens)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, (L,)), jnp.int32)
               for L in req_lens]
    scfg = ServeConfig(max_new=max_new, temperature=0.0)

    # -- static baseline: sequential batch-of-one generates -------------------
    static = Engine(model, scfg)
    for L in sorted(set(req_lens)):                       # warm compiles
        p = prompts[req_lens.index(L)]
        jax.block_until_ready(static.generate(params, {"tokens": p[None]}))
    static_out, static_lat = [], []
    t0 = time.monotonic()
    for p in prompts:
        toks, _ = timed(static.generate, params, {"tokens": p[None]})
        static_out.append(np.asarray(toks)[0])
        static_lat.append(time.monotonic() - t0)          # incl. queueing behind earlier reqs
    static_wall = time.monotonic() - t0

    # -- continuous: slot ring behind the scheduler ---------------------------
    eng = ContinuousEngine(model, scfg, num_slots=slots,
                           max_prompt_len=max(req_lens))
    warm = Scheduler(eng, params)                         # throwaway: compile everything
    for L in sorted(set(req_lens)):
        warm.submit(jnp.zeros((L,), jnp.int32), max_new=min(2, max_new))
    warm.run(timeout=600)

    sched = Scheduler(eng, params)
    t0 = time.monotonic()
    rids = [sched.submit(p) for p in prompts]
    sched.run(timeout=600)
    cont_wall = time.monotonic() - t0
    cont = [sched.results[r] for r in rids]
    cont_lat = [c.latency for c in cont]

    identical = all(
        np.array_equal(np.asarray(c.tokens), s) for c, s in zip(cont, static_out)
    )
    n_tok = n_requests * max_new
    out = {
        "arch": arch, "n_requests": n_requests, "slots": slots,
        "max_new": max_new, "lengths": sorted(set(req_lens)),
        "token_identical": identical,
        "static": {"wall_s": static_wall, "tok_per_s": n_tok / static_wall,
                   "latency": _pcts(static_lat)},
        "continuous": {"wall_s": cont_wall, "tok_per_s": n_tok / cont_wall,
                       "decode_steps": sched.steps,
                       "latency": _pcts(cont_lat)},
        "speedup": static_wall / cont_wall,
    }
    if not quiet:
        print(f"{n_requests} reqs x {max_new} new (lens {out['lengths']}, "
              f"{slots} slots), token-identical={identical}")
        for name in ("static", "continuous"):
            r = out[name]
            print(f"  {name:>10}: {r['wall_s']:.2f}s  {r['tok_per_s']:.1f} tok/s  "
                  f"p50 {r['latency']['p50_ms']:.0f}ms  p95 {r['latency']['p95_ms']:.0f}ms")
        print(f"  speedup: {out['speedup']:.2f}x")
    save("serving", out)
    return out


def run_hdc(n_requests: int = 512, slots: int = 16, tenants: int = 4,
            batch: int = 4, n_classes: int = 128, dim: int = 512,
            representation: str = "packed", seed: int = 0,
            quiet: bool = False) -> dict:
    """Multi-tenant HDC serving: continuous slot-batched vs static per-tenant.

    The trace is Poisson in arrival ORDER (tenant of request i drawn from a
    seeded exponential-interarrival race between tenants), all queued at t=0
    like the LM bench, with small per-request trial batches — the
    dispatch-bound online-serving regime where one fused multi-tenant launch
    per step (fixed serve-graph cost paid once per `slots` requests, admission
    a single batched scatter) beats one standalone `make_ota_serve` dispatch
    per request. Prediction identity (continuous vs static, elementwise) is
    asserted before timing is reported. Defaults use the bit-packed wire
    representation — the paper's OTA format and the stabler timing.
    """
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.compat import make_mesh
    from repro.core import classifier, hypervector as hv, scaleout
    from repro.serving import HDCEngine, HDCScheduler

    cfg = scaleout.ScaleOutConfig(
        n_classes=n_classes, dim=dim, m_tx=3, n_rx_cores=8, batch=batch,
        use_kernels=False, representation=representation, noise="exact",
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    tcfg = classifier.HDCTaskConfig(n_classes=n_classes, dim=dim)
    books = classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, tenants)
    banks = [hv.pack(b) if cfg.packed else b for b in books]
    state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.02), cfg.m_tx)

    # Poisson race: tenant of each arrival = argmin of per-tenant next-event
    # times under seeded exponential inter-arrivals (deterministic trace)
    rng = np.random.default_rng(seed)
    nxt = rng.exponential(1.0, tenants)
    trace = []
    for _ in range(n_requests):
        t = int(np.argmin(nxt))
        trace.append(t)
        nxt[t] += rng.exponential(1.0)
    reqs = []
    for i, t in enumerate(trace):
        _, q = scaleout.make_queries(jax.random.PRNGKey(100 + i), cfg, books[t], 1)
        reqs.append((t, q, jax.random.PRNGKey(1000 + i)))

    # -- static baseline: one standalone serve call per request ---------------
    serve = scaleout.make_ota_serve(mesh, cfg)
    jax.block_until_ready(serve(banks[0], reqs[0][1], state, reqs[0][2]))  # warm
    static_out, static_lat = [], []
    t0 = time.monotonic()
    for t, q, key in reqs:
        (pred, sim), _ = timed(serve, banks[t], q, state, key)
        static_out.append((np.asarray(pred), np.asarray(sim)))
        static_lat.append(time.monotonic() - t0)          # incl. queueing
    static_wall = time.monotonic() - t0

    # -- continuous: multi-tenant slot ring behind the scheduler --------------
    eng = HDCEngine(mesh, cfg, state, num_slots=slots, max_tenants=tenants)
    for t in range(tenants):
        eng.registry.onboard(t, banks[t])
    warm = HDCScheduler(eng)                              # throwaway: compile
    for _ in range(slots):     # K=slots batched-admit program + the step
        warm.submit(0, reqs[0][1])
    warm.run(timeout=600)

    sched = HDCScheduler(eng)
    t0 = time.monotonic()
    rids = [sched.submit(t, q, key=key) for t, q, key in reqs]
    sched.run(timeout=600)
    cont_wall = time.monotonic() - t0
    cont = [sched.results[r] for r in rids]
    cont_lat = [c.latency for c in cont]

    identical = all(
        np.array_equal(c.pred, sp) and np.array_equal(c.maxsim, ss)
        for c, (sp, ss) in zip(cont, static_out)
    )
    n_trials = n_requests * batch
    out = {
        "n_requests": n_requests, "slots": slots, "tenants": tenants,
        "batch": batch, "n_classes": n_classes, "dim": dim,
        "representation": representation,
        "prediction_identical": identical,
        "static": {"wall_s": static_wall, "trials_per_s": n_trials / static_wall,
                   "latency": _pcts(static_lat)},
        "continuous": {"wall_s": cont_wall, "trials_per_s": n_trials / cont_wall,
                       "steps": sched.steps, "latency": _pcts(cont_lat)},
        "speedup": static_wall / cont_wall,
    }
    if not quiet:
        print(f"{n_requests} reqs x {batch} trials, {tenants} tenants, "
              f"{slots} slots ({representation}), "
              f"prediction-identical={identical}")
        for name in ("static", "continuous"):
            r = out[name]
            print(f"  {name:>10}: {r['wall_s']:.2f}s  {r['trials_per_s']:.0f} trials/s  "
                  f"p50 {r['latency']['p50_ms']:.0f}ms  p95 {r['latency']['p95_ms']:.0f}ms")
        print(f"  speedup: {out['speedup']:.2f}x")
    save("serving_hdc", out)
    return out


def run_drift(n_steps: int = 50, n_trials: int = 512, n_classes: int = 64,
              dim: int = 512, n_rx: int = 16, sigma: float = 0.1,
              guard_dims: int = 128, tail: int = 10, seed: int = 7,
              serve_requests: int = 32, quiet: bool = False) -> dict:
    """Closed-loop robustness under a LIVING channel — the drift benchmark.

    Three sweeps of the same workload (same codebook, same trial keys every
    step, so accuracy differences are channel effects only):

    * **baseline** — StaticProcess (frozen characterized channel): the
      no-drift accuracy ceiling;
    * **static**  — PhaseDriftProcess with the serve pipeline left as
      characterized (open loop): accuracy decays as the constellations rotate
      away from the stale decision regions;
    * **adaptive** — same drift, closed loop: the guard-symbol monitor's
      EW-MA flip-rate estimate trips the analytic band
      (`em.analytic_ber_band`) and triggers per-RX EM re-fits
      (`phy.recharacterize`).

    Reported: tail-window (last ``tail`` steps) accuracy drop of the static
    run vs baseline, and the adaptive run's remaining gap — the closed-loop
    claim gated by check_regression.py is drop >= 3 points, gap <= 1 point.
    Everything is seeded and trial-exact, so the accuracy side is
    machine-independent; the serving side (an ``AdaptiveHDCEngine`` run of
    ``serve_requests`` requests under the same process, reporting trials/s
    and the controller action trace) is timing and gets the usual
    conservative-floor treatment.
    """
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.compat import make_mesh
    from repro.core import classifier, scaleout
    from repro.serving import (AdaptiveHDCEngine, HDCScheduler,
                               LinkControllerConfig)

    scfg = scaleout.ScaleOutConfig(
        n_classes=n_classes, dim=dim, m_tx=3, n_rx_cores=n_rx, batch=4,
        use_kernels=False, noise="exact", channel="symbol",
    )
    state = scaleout.precharacterize_state(scfg)
    tcfg = classifier.HDCTaskConfig(n_classes=n_classes, dim=dim,
                                    n_trials=n_trials)
    key = jax.random.PRNGKey(seed)
    proc = phy.PhaseDriftProcess(sigma=sigma, alpha=0.5, guard_dims=guard_dims)
    band_kwargs = {"cap": 0.05}

    # accuracy sweeps (deterministic given the seed)
    base = classifier.run_drift_sweep(key, tcfg, scfg.m_tx, state,
                                      phy.StaticProcess(), 1)
    static = classifier.run_drift_sweep(key, tcfg, scfg.m_tx, state, proc,
                                        n_steps)
    adapt = classifier.run_drift_sweep(key, tcfg, scfg.m_tx, state, proc,
                                       n_steps, adaptive=True, patience=1,
                                       band_kwargs=band_kwargs)
    baseline_acc = float(base["acc"][0])
    static_tail = float(np.mean(static["acc"][-tail:]))
    adaptive_tail = float(np.mean(adapt["acc"][-tail:]))

    # serving side: the same process driving the slot ring + LinkController
    mesh = make_mesh((1, 1), ("data", "model"))
    books = classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, 2)
    eng = AdaptiveHDCEngine(
        mesh, scfg, state, process=proc, num_slots=4, max_tenants=2,
        controller=LinkControllerConfig(patience=1, band_kwargs=band_kwargs),
    )
    sched = HDCScheduler(eng)
    for t in range(2):
        eng.registry.onboard(t, books[t])
    reqs = []
    for i in range(serve_requests):
        _, q = scaleout.make_queries(jax.random.PRNGKey(100 + i), scfg,
                                     books[i % 2], 1)
        reqs.append((i % 2, q, jax.random.PRNGKey(1000 + i)))
    warm = HDCScheduler(eng)                               # throwaway: compile
    for _ in range(4):
        warm.submit(0, reqs[0][1])
    warm.run(timeout=600)
    t0 = time.monotonic()
    for t, q, k in reqs:
        sched.submit(t, q, key=k)
    sched.run(timeout=600)
    serve_wall = time.monotonic() - t0
    actions: dict[str, int] = {}
    for e in eng.controller.trace:
        actions[e["action"]] = actions.get(e["action"], 0) + 1

    # guard-monitor wire cost: guard_dims int32 disagreement lanes ride the
    # per-step vote collective; compare against the unpacked per-step vote
    # payload (dim int8 lanes x batch queries) of one hop
    guard_bytes = 4 * guard_dims
    payload_bytes = dim * scfg.batch
    out = {
        "scenario": {
            "n_steps": n_steps, "n_trials": n_trials, "n_classes": n_classes,
            "dim": dim, "n_rx": n_rx, "sigma": sigma,
            "guard_dims": guard_dims, "tail": tail, "seed": seed,
        },
        "baseline_acc": baseline_acc,
        "static_tail_acc": static_tail,
        "adaptive_tail_acc": adaptive_tail,
        "static_drop_pts": 100.0 * (baseline_acc - static_tail),
        "adaptive_gap_pts": 100.0 * (baseline_acc - adaptive_tail),
        "acc_static": [float(a) for a in static["acc"]],
        "acc_adaptive": [float(a) for a in adapt["acc"]],
        "n_refits": int(adapt["n_refits"]),
        "guard": {
            "dims": guard_dims,
            "bytes_per_step_per_hop": guard_bytes,
            "overhead_frac": guard_bytes / payload_bytes,
        },
        "serving": {
            "n_requests": serve_requests,
            "wall_s": serve_wall,
            "trials_per_s": serve_requests * scfg.batch / serve_wall,
            "actions": actions,
        },
    }
    if not quiet:
        print(f"drift sweep: {n_rx} RX, C={n_classes}, d={dim}, "
              f"sigma={sigma}, {n_steps} steps x {n_trials} trials")
        print(f"  baseline acc      : {baseline_acc:.3f}")
        print(f"  static  (tail {tail:2d}) : {static_tail:.3f}  "
              f"(drop {out['static_drop_pts']:.1f} pts)")
        print(f"  adaptive(tail {tail:2d}) : {adaptive_tail:.3f}  "
              f"(gap  {out['adaptive_gap_pts']:.1f} pts, "
              f"{out['n_refits']} row re-fits)")
        print(f"  guard wire        : {guard_bytes} B/step/hop "
              f"({100 * out['guard']['overhead_frac']:.1f}% of votes payload)")
        print(f"  adaptive serving  : {out['serving']['trials_per_s']:.0f} "
              f"trials/s, controller actions {actions}")
    save("serving_adaptive", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--fast", action="store_true", help="fewer/shorter requests")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hdc", action="store_true",
                    help="multi-tenant HDC serving instead of the LM bench")
    ap.add_argument("--unpacked", action="store_true",
                    help="(--hdc) elementwise representation instead of packed")
    ap.add_argument("--drift", action="store_true",
                    help="closed-loop living-channel robustness sweep")
    args = ap.parse_args()
    rep = "unpacked" if args.unpacked else "packed"
    if args.drift:
        if args.fast:
            run_drift(n_steps=30, n_trials=128, serve_requests=16)
        else:
            run_drift()
    elif args.hdc:
        if args.fast:
            run_hdc(n_requests=32, slots=max(args.slots, 8), tenants=4, batch=4,
                    n_classes=64, dim=512, representation=rep, seed=args.seed)
        else:
            run_hdc(slots=max(args.slots, 16), representation=rep, seed=args.seed)
    elif args.fast:
        run(args.arch, n_requests=8, slots=args.slots, max_new=8,
            lengths=(16, 32), seed=args.seed)
    else:
        run(args.arch, slots=args.slots, seed=args.seed)


if __name__ == "__main__":
    main()
