"""Spec-first parameter handling.

A model's parameters are declared as a pytree of `ParamSpec`s. From one spec tree
we derive:
* `init_params`   — materialized arrays (smoke tests, real training);
* `param_shapes`  — ShapeDtypeStructs (dry-run lowering of 1T-param configs,
                    no host allocation);
* `param_axes`    — logical sharding axes consumed by distributed.sharding.

Initializers are tagged by name so specs stay hashable/pickle-friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == len(shape)
    init: str = "normal"                  # normal | zeros | ones | scaled(fan_in)
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        s = 1.0 / math.sqrt(fan_in)
        return (s * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(spec.init)


def init_params(key: jax.Array, specs: Any) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


def param_shapes(specs: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def param_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def count_params(specs: Any) -> int:
    return sum(
        math.prod(s.shape) for s in jax.tree.leaves(specs, is_leaf=_is_spec)
    )
