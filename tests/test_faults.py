"""Hard-fault injection: the chaos layer must cost nothing when healthy.

Pins the tentpole contracts: StaticFaults through the fault-threading serve
with the all-healthy `FaultState` is BIT-identical to the fault-free serve on
every channel x collective x representation tier (fault awareness is free
until faults exist); vote erasures reproduce the m_active oracle and agree
across all three vote collectives; dead-RX failover (`plan_failover`) recovers
bit-exactly on a clean link while the unaware serve mispredicts; stuck-at
masks hit the stored rows; fault models evolve under the registry + RNG
discipline of `repro.phy`; and the `FaultController` promotes persistent
quarantine to a remap exactly at `remap_after` barriers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_test_mesh
from repro import faults as faultlib, phy
from repro.core import classifier, hypervector as hv, ota, scaleout


def _cfg(**kw):
    base = dict(n_classes=40, dim=512, m_tx=3, n_rx_cores=4, batch=8,
                use_kernels=False, noise="exact")
    base.update(kw)
    return scaleout.ScaleOutConfig(**base)


@pytest.fixture(scope="module")
def sym_state():
    return scaleout.precharacterize_state(_cfg(channel="symbol"))


def _book_and_queries(cfg, seed=0, qseed=1):
    book = classifier.make_codebook(
        jax.random.PRNGKey(seed),
        classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
    protos = hv.pack(book) if cfg.packed else book
    classes, q = scaleout.make_queries(jax.random.PRNGKey(qseed), cfg, book, 1)
    return book, protos, classes, q


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_fault_registry():
    assert sorted(faultlib.FAULTS) == ["static", "transient_votes", "wearout"]
    m = faultlib.get_fault_model("transient_votes", p_drop=0.2)
    assert isinstance(m, faultlib.TransientVoteFaults) and m.p_drop == 0.2
    with pytest.raises(ValueError, match="unknown fault model"):
        faultlib.get_fault_model("gamma_ray")
    with pytest.raises(ValueError, match="already registered"):
        faultlib.register_fault_model(faultlib.StaticFaults)

    @dataclasses.dataclass(frozen=True)
    class Meteor(faultlib.StaticFaults):
        name = "meteor"

    try:
        faultlib.register_fault_model(Meteor)
        assert isinstance(faultlib.get_fault_model("meteor"), Meteor)
    finally:
        del faultlib.FAULTS["meteor"]


# ---------------------------------------------------------------------------
# FaultState pytree + injection
# ---------------------------------------------------------------------------

def test_fstate_shape_structs_match_healthy():
    f0 = faultlib.healthy_state(4, 3, 16)
    structs = faultlib.fstate_shape_structs(4, 3, 16)
    assert (jax.tree_util.tree_structure(structs)
            == jax.tree_util.tree_structure(f0))
    for leaf, struct in zip(jax.tree_util.tree_leaves(f0),
                            jax.tree_util.tree_leaves(structs)):
        assert leaf.shape == struct.shape, (leaf.shape, struct.shape)
        assert leaf.dtype == struct.dtype, (leaf.dtype, struct.dtype)
    assert f0.n_rx == 4 and f0.m_slots == 3


def test_inject_coerces_index_lists_and_arrays():
    f = faultlib.healthy_state(4, 3, 16)
    g = faultlib.inject(f, dead_rx=[0, 2], vote_drop=[1])
    assert np.asarray(g.dead_rx).tolist() == [True, False, True, False]
    assert np.asarray(g.vote_drop).tolist() == [False, True, False]
    # full arrays pass through with dtype coercion; other leaves untouched
    h = faultlib.inject(f, dead_rx=np.array([True, False, False, False]),
                        serve_rows=np.array([1, 1, 2, 3]))
    assert np.asarray(h.dead_rx).tolist() == [True, False, False, False]
    assert h.serve_rows.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(h.stuck0), np.asarray(f.stuck0))


# ---------------------------------------------------------------------------
# zero-fault bit-identity: the "fault awareness is free" guarantee
# ---------------------------------------------------------------------------

def test_healthy_serve_bit_identity(sym_state):
    """The fault-threading serve under StaticFaults + healthy_state == the
    fault-free serve, bitwise, across every channel x collective x
    representation tier — every fault application is a value identity."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    grid = ([("bsc", c) for c in ("psum", "psum_packed", "rs_ag")]
            + [("symbol", "psum")])
    for channel, coll in grid:
        for rep in ("unpacked", "packed"):
            cfg = _cfg(channel=channel, collective=coll, representation=rep,
                       permuted=True)
            state = (sym_state if channel == "symbol"
                     else phy.state_from_ber(
                         jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx))
            _, protos, _, q = _book_and_queries(cfg)
            serve = scaleout.make_ota_serve(mesh, cfg)
            fserve = scaleout.make_ota_serve(mesh, cfg,
                                             faults=faultlib.StaticFaults())
            fstate = faultlib.healthy_for(cfg, 1)
            fkey = jax.random.PRNGKey(9)
            for step in range(3):
                key = jax.random.PRNGKey(100 + step)
                wp, ws = serve(protos, q, state, key)
                gp, gs, fstate = fserve(protos, q, state, key, fstate, fkey)
                np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp)), \
                    (channel, coll, rep)
                np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
            assert int(fstate.t) == 3


def test_mt_healthy_serve_bit_identity():
    """Same guarantee on the multi-tenant slot-batched path."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    for rep in ("unpacked", "packed"):
        cfg = _cfg(representation=rep, permuted=True)
        state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx)
        tcfg = classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim)
        books = classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, 2)
        store = jnp.stack([hv.pack(b) if cfg.packed else b for b in books])
        rows = jnp.array([1, 0], jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(2)])
        qs = []
        for s in range(2):
            _, q = scaleout.make_queries(jax.random.PRNGKey(50 + s), cfg,
                                         books[int(rows[s])], 1)
            qs.append(q)
        qs = jnp.stack(qs)
        mt = scaleout.make_mt_ota_serve(mesh, cfg)
        fmt = scaleout.make_mt_ota_serve(mesh, cfg,
                                         faults=faultlib.StaticFaults())
        fstate = faultlib.healthy_for(cfg, 1)
        wp, ws = mt(store, qs, rows, state, keys)
        gp, gs, fstate = fmt(store, qs, rows, state, keys, fstate,
                             jax.random.PRNGKey(9))
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        assert int(fstate.t) == 1


# ---------------------------------------------------------------------------
# node faults: dead RX cores + serve_rows failover
# ---------------------------------------------------------------------------

def test_dead_rx_failover_recovers_bit_exactly():
    """On a clean link a dead core's zeroed query copy mispredicts its bank;
    `plan_failover` serves the bank from a healthy core's (identical) copy —
    bit-equal to the fault-free serve, through the SAME compiled program."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = _cfg(representation="packed", permuted=True)
    state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
    _, protos, _, q = _book_and_queries(cfg)
    serve = scaleout.make_ota_serve(mesh, cfg)
    fserve = scaleout.make_ota_serve(mesh, cfg, faults=faultlib.StaticFaults())
    key, fkey = jax.random.PRNGKey(2), jax.random.PRNGKey(9)
    wp, ws = serve(protos, q, state, key)

    dead = faultlib.inject(faultlib.healthy_for(cfg, 1), dead_rx=[0])
    up, _, _ = fserve(protos, q, state, key, dead, fkey)
    assert not np.array_equal(np.asarray(up), np.asarray(wp))  # unaware: wrong

    aware = faultlib.plan_failover(dead, cfg.n_rx_cores)
    assert int(aware.serve_rows[0]) != 0       # bank 0 served elsewhere
    ap, asim, _ = fserve(protos, q, state, key, aware, fkey)
    np.testing.assert_array_equal(np.asarray(ap), np.asarray(wp))
    np.testing.assert_array_equal(np.asarray(asim), np.asarray(ws))


def test_plan_failover_round_robin_and_shard_exhaustion():
    f = faultlib.healthy_state(8, 3, 16)
    # shard 0 (cores 0-3): cores 0,1 dead -> dealt over healthy 2,3;
    # shard 1 (cores 4-7): all dead -> rx_mask'd out, identity rows kept sane
    f = faultlib.inject(f, dead_rx=[0, 1, 4, 5, 6, 7])
    g = faultlib.plan_failover(f, 4)
    rows = np.asarray(g.serve_rows)
    assert rows[0] == 2 and rows[1] == 3       # round-robin over healthy
    assert rows[2] == 2 and rows[3] == 3       # healthy cores self-serve
    mask = np.asarray(g.rx_mask)
    assert not mask[:4].any() and mask[4:].all()
    with pytest.raises(AssertionError):
        faultlib.plan_failover(f, 3)           # n_rx % cores_per_shard != 0


# ---------------------------------------------------------------------------
# memory faults: stuck-at masks + samplers
# ---------------------------------------------------------------------------

def test_stuck_samplers_are_disjoint_and_sized():
    s0, s1 = faultlib.sample_stuck_cells(jax.random.PRNGKey(0), 4, 16, 0.1)
    assert s0.shape == (4, 16) and s0.dtype == jnp.uint32
    assert not bool(jnp.any(s0 & s1))          # one conductance per cell
    bits = int(np.unpackbits(np.asarray(s0).view(np.uint8)).sum()
               + np.unpackbits(np.asarray(s1).view(np.uint8)).sum())
    assert 0.05 < bits / (4 * 16 * 32) < 0.2   # ~10% total density
    drop = faultlib.sample_word_dropout(jax.random.PRNGKey(1), 4, 16, 0.5)
    vals = np.unique(np.asarray(drop))
    assert set(vals.tolist()) <= {0, 0xFFFFFFFF}  # whole words only
    assert (np.asarray(drop) == 0xFFFFFFFF).any()


def test_stuck_at_masks_degrade_and_zero_masks_are_identity():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = _cfg(representation="packed", permuted=True)
    state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
    _, protos, _, q = _book_and_queries(cfg)
    serve = scaleout.make_ota_serve(mesh, cfg)
    fserve = scaleout.make_ota_serve(mesh, cfg, faults=faultlib.StaticFaults())
    key, fkey = jax.random.PRNGKey(2), jax.random.PRNGKey(9)
    wp, _ = serve(protos, q, state, key)
    # every stored bit stuck at 1: similarity search runs on garbage
    f = faultlib.inject(
        faultlib.healthy_for(cfg, 1),
        stuck1=jnp.full((cfg.n_rx_cores, cfg.words), 0xFFFFFFFF, jnp.uint32))
    gp, _, _ = fserve(protos, q, state, key, f, fkey)
    assert not np.array_equal(np.asarray(gp), np.asarray(wp))


# ---------------------------------------------------------------------------
# wire faults: vote erasures
# ---------------------------------------------------------------------------

def test_vote_erasure_matches_m_active_oracle():
    """Erasing TX slots 1,2 leaves a single live voter — bit-identical to the
    fault-free serve built with m_active=1 (abstention is the same mechanism,
    the live-majority threshold re-biases identically)."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    for coll in ("psum", "psum_packed"):
        cfg = _cfg(permuted=True, collective=coll)
        state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx)
        _, protos, _, q = _book_and_queries(cfg)
        oracle = scaleout.make_ota_serve(mesh, _cfg(permuted=True,
                                                    collective=coll,
                                                    m_active=1))
        fserve = scaleout.make_ota_serve(mesh, cfg,
                                         faults=faultlib.StaticFaults())
        f = faultlib.inject(faultlib.healthy_for(cfg, 1), vote_drop=[1, 2])
        key = jax.random.PRNGKey(2)
        wp, ws = oracle(protos, q, state, key)
        gp, gs, _ = fserve(protos, q, state, key, f, jax.random.PRNGKey(9))
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp)), coll
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


def test_vote_erasure_agrees_across_collectives():
    """An even live-voter count (one erasure of three) must decode the same
    on all three vote collectives — the guard-bit re-bias by the traced
    live total keeps the packed tallies exact."""
    preds = []
    mesh = make_test_mesh((1, 1), ("data", "model"))
    for coll in ("psum", "psum_packed", "rs_ag"):
        cfg = _cfg(representation="packed", permuted=True, collective=coll)
        state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx)
        _, protos, _, q = _book_and_queries(cfg)
        fserve = scaleout.make_ota_serve(mesh, cfg,
                                         faults=faultlib.StaticFaults())
        f = faultlib.inject(faultlib.healthy_for(cfg, 1), vote_drop=[2])
        gp, gs, _ = fserve(protos, q, state, jax.random.PRNGKey(2), f,
                           jax.random.PRNGKey(9))
        preds.append((np.asarray(gp), np.asarray(gs)))
    for p, s in preds[1:]:
        np.testing.assert_array_equal(p, preds[0][0])
        np.testing.assert_array_equal(s, preds[0][1])


# ---------------------------------------------------------------------------
# combo wire (symbol tier): live sub-constellation + centroid refit
# ---------------------------------------------------------------------------

def test_live_combo_mask_and_majority_labels():
    none_dead = jnp.zeros((3,), bool)
    assert bool(faultlib.live_combo_mask(none_dead, 3).all())
    full = faultlib.live_majority_labels(none_dead, 3)
    np.testing.assert_array_equal(np.asarray(full),
                                  np.asarray(ota.majority_labels(3)))
    # TX 0 dead (stuck at bit 0): combos with bit 0 set never occur, and the
    # live majority counts only TXs 1,2 (even count: ties decode 0)
    dead0 = jnp.array([True, False, False])
    mask = np.asarray(faultlib.live_combo_mask(dead0, 3))
    combos = np.asarray(ota.bit_combos(3))
    np.testing.assert_array_equal(mask, combos[:, 0] == 0)
    labels = np.asarray(faultlib.live_majority_labels(dead0, 3))
    want = (2 * combos[:, 1:].sum(-1) > 2).astype(np.uint8)
    np.testing.assert_array_equal(labels, want)


def test_recenter_state_refits_live_subconstellation(sym_state):
    """With no erasures `recenter_state` reproduces the full-constellation
    majority centroids; with TX 0 erased it equals the masked refit over the
    occurring combos — the erasure analogue of `phy.recharacterize`."""
    maj = ota.majority_labels(sym_state.m_tx)
    c0, c1 = ota.majority_centroids(sym_state.symbols, maj)
    same = faultlib.recenter_state(sym_state, jnp.zeros((3,), bool))
    np.testing.assert_array_equal(np.asarray(same.c0), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(same.c1), np.asarray(c1))

    dead0 = jnp.array([True, False, False])
    refit = faultlib.recenter_state(sym_state, dead0)
    w0, w1 = ota.majority_centroids(
        sym_state.symbols, faultlib.live_majority_labels(dead0, 3),
        mask=faultlib.live_combo_mask(dead0, 3))
    np.testing.assert_array_equal(np.asarray(refit.c0), np.asarray(w0))
    np.testing.assert_array_equal(np.asarray(refit.c1), np.asarray(w1))
    assert not np.array_equal(np.asarray(refit.c0), np.asarray(c0))


# ---------------------------------------------------------------------------
# fault models: evolution laws
# ---------------------------------------------------------------------------

def test_transient_votes_redraw_only_the_wire():
    m = faultlib.TransientVoteFaults(p_drop=0.5)
    f = m.init(4, 8, 16)
    key = jax.random.PRNGKey(0)
    f1 = m.step(key, f)
    f2 = m.step(key, f1)
    assert int(f2.t) == 2
    # the t fold redraws the erasure pattern every step
    assert not np.array_equal(np.asarray(f1.vote_drop), np.asarray(f2.vote_drop))
    for name in ("dead_tx", "dead_rx", "stuck0", "stuck1", "serve_rows",
                 "rx_mask"):
        np.testing.assert_array_equal(np.asarray(getattr(f2, name)),
                                      np.asarray(getattr(f, name)))


def test_wearout_accumulates_monotonically():
    m = faultlib.WearoutFaults(p_die=0.3, stuck_rate=0.05)
    f = m.init(8, 3, 16)
    key = jax.random.PRNGKey(0)
    prev = f
    for _ in range(5):
        nxt = m.step(key, prev)
        # monotone: nothing ever heals
        assert bool(jnp.all(~prev.dead_rx | nxt.dead_rx))
        assert not bool(jnp.any(prev.stuck0 & ~nxt.stuck0))
        assert not bool(jnp.any(nxt.stuck0 & nxt.stuck1))  # rails disjoint
        prev = nxt
    assert int(prev.t) == 5
    assert bool(prev.dead_rx.any()) and bool(jnp.any(prev.stuck0))


# ---------------------------------------------------------------------------
# FaultController: quarantine -> remap promotion
# ---------------------------------------------------------------------------

def test_fault_controller_promotes_exactly_at_remap_after(sym_state):
    from repro.serving import FaultController, FaultControllerConfig

    cfg = _cfg(channel="symbol")
    p = phy.StaticProcess().init(sym_state)
    ctl = FaultController(FaultControllerConfig(remap_after=3,
                                                band_kwargs={"cap": 0.05}), p)
    f = faultlib.healthy_for(cfg, 1)
    ctl.quarantined[:] = [True, False, False, False]
    for _ in range(2):                         # below the threshold: no-op
        f = ctl.promote(f, cfg.n_rx_cores)
        assert not bool(f.dead_rx.any())
    f = ctl.promote(f, cfg.n_rx_cores)         # 3rd quarantined barrier
    assert np.asarray(f.dead_rx).tolist() == [True, False, False, False]
    assert int(f.serve_rows[0]) != 0           # bank 0 failed over
    remaps = [e for e in ctl.trace if e["action"] == "remap"]
    assert len(remaps) == 1 and remaps[0]["rows"] == [0]
    # promotion is one-way: staying quarantined never re-promotes
    f = ctl.promote(f, cfg.n_rx_cores)
    assert len([e for e in ctl.trace if e["action"] == "remap"]) == 1
    # a release resets the barrier count: re-quarantine starts over
    ctl.quarantined[:] = False
    ctl.promote(f, cfg.n_rx_cores)
    assert (ctl._q_barriers == 0).all()


def test_fault_tolerant_engine_zero_fault_identity():
    """FaultTolerantHDCEngine under StaticFaults + healthy state serves
    bit-identically to AdaptiveHDCEngine — fault tolerance costs nothing
    until faults exist, and the controller never remaps."""
    from repro.serving import (AdaptiveHDCEngine, FaultControllerConfig,
                               FaultTolerantHDCEngine, HDCScheduler,
                               LinkControllerConfig)

    cfg = _cfg(channel="symbol")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    state = scaleout.precharacterize_state(cfg)
    tcfg = classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim)
    books = classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, 2)
    engines = (
        AdaptiveHDCEngine(
            mesh, cfg, state, process=phy.StaticProcess(guard_dims=16),
            num_slots=2, max_tenants=2,
            controller=LinkControllerConfig(band_kwargs={"cap": 0.05})),
        FaultTolerantHDCEngine(
            mesh, cfg, state, process=phy.StaticProcess(guard_dims=16),
            fault_model=faultlib.StaticFaults(), num_slots=2, max_tenants=2,
            controller=FaultControllerConfig(band_kwargs={"cap": 0.05})),
    )
    results = []
    for eng in engines:
        sched = HDCScheduler(eng)
        for t in range(2):
            eng.registry.onboard(t, books[t])
        rids = []
        for r in range(4):
            _, q = scaleout.make_queries(jax.random.PRNGKey(50 + r), cfg,
                                         books[r % 2], 1)
            rids.append(sched.submit(r % 2, q, key=jax.random.PRNGKey(100 + r)))
        sched.run(timeout=600)
        results.append([sched.results[r].pred for r in rids])
    for a, b in zip(*results):
        np.testing.assert_array_equal(a, b)
    ft = engines[1]
    assert int(ft.fstate.t) == 2               # 4 requests / 2 slots = 2 steps
    assert not bool(ft.fstate.dead_rx.any())
    assert ft.controller.trace == []           # nothing tripped or remapped
