"""Parametric electromagnetic model of the in-package wireless channel.

The paper pre-characterizes the channel with CST Studio (full-wave EM simulation of
the Fig. 5 package: 30x30 mm interposer, metallic lid, vacuum fill, 60 GHz).  CST is
not available here, so we substitute a *deterministic parametric* model that captures
the properties the OTA scheme relies on:

* quasi-static, known-a-priori complex gains H[rx, tx] (amplitude + phase);
* strong per-RX variation of the received constellation (distance-dependent phase at
  lambda = 5 mm rotates symbols many full turns across the package);
* multipath from the metallic lid / side walls (first-order image sources with a
  reflection coefficient), which makes some RX constellations poorly separable —
  reproducing the heavy per-RX BER spread of Fig. 8.

Geometry follows Fig. 5: L1 = L2 = 30 mm package, 3 TX chiplets spaced s = 3.75 mm
on the left edge, N RX cores on a regular grid.  All distances in millimetres.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

C_MM_PER_S = 2.998e11  # speed of light in mm/s


@dataclasses.dataclass(frozen=True)
class PackageGeometry:
    """Fig. 5 parameters (mm)."""

    L1: float = 30.0          # package x extent
    L2: float = 29.7          # package y extent (effective cavity dim; the slight
    #   asymmetry vs the 30 mm die splits the (p,q)/(q,p) mode degeneracy that any
    #   real package exhibits — CST would capture this from seal-ring/wall detail)
    lid_height: float = 0.5   # cavity height under the metallic lid
    tx_spacing: float = 3.75  # s in Fig. 5
    tx_edge_offset: float = 1.5
    freq_hz: float = 59.96e9  # operating frequency: tuned onto the isolated (12,0)
    #   cavity mode (k0 = 12*pi/L1), the "engineer the channel" step of [45]
    path_loss_exp: float = 1.0   # (ray model) lateral spreading in the lid cavity
    wall_reflection: float = -0.7   # (ray model) wall/lid reflection coefficient
    n_reflections: int = 1    # (ray model) first-order image sources
    rx_keepout: float = 7.5   # l1: TX chiplet strip width — RX array starts after it
    cavity_q: float = 400.0   # quality factor of the lidded cavity (modal model)
    model: str = "cavity"     # "cavity" (modal Green's function) | "ray" (images)
    antinode_snap: bool = True  # nudge RX antennas off the dominant-mode nodal
    #   lines (x = 1.25 mm mod 2.5) — placement is known from pre-characterization;
    #   a <=0.5 mm nudge is trivial at chiplet scale ("engineer the channel" [45])

    @property
    def wavelength_mm(self) -> float:
        return C_MM_PER_S / self.freq_hz  # ~5 mm at 60 GHz


def tx_positions(geom: PackageGeometry, n_tx: int) -> jnp.ndarray:
    """TX antennas along the left edge, centered vertically, spacing s."""
    y0 = geom.L2 / 2 - (n_tx - 1) * geom.tx_spacing / 2
    ys = y0 + geom.tx_spacing * jnp.arange(n_tx)
    xs = jnp.full((n_tx,), geom.tx_edge_offset)
    return jnp.stack([xs, ys], axis=-1)  # [M, 2]


def rx_positions(geom: PackageGeometry, n_rx: int) -> jnp.ndarray:
    """RX antennas on a near-square grid over the IMC-core region (right of TXs)."""
    cols = int(math.ceil(math.sqrt(n_rx)))
    rows = int(math.ceil(n_rx / cols))
    x0 = geom.rx_keepout + 1.0
    xs = jnp.linspace(x0, geom.L1 - 1.0, cols)
    ys = jnp.linspace(1.0, geom.L2 - 1.0, rows)
    gx, gy = jnp.meshgrid(xs, ys, indexing="ij")
    if geom.antinode_snap:
        # distance from the nearest nodal line of the dominant (12,0) mode
        period = geom.L1 / 12.0  # = lambda/2 = 2.5 mm
        d = jnp.mod(gx, period) - period / 2.0  # node at period/2
        thr = 0.2  # keep >= 0.2 mm clear of nodal lines
        nudge = jnp.where(jnp.abs(d) < thr, jnp.sign(d + 1e-9) * (thr - jnp.abs(d)), 0.0)
        gx = gx + nudge
    pos = jnp.stack([gx.reshape(-1), gy.reshape(-1)], axis=-1)
    return pos[:n_rx]  # [N, 2]


def _ray_gain(dist: jnp.ndarray, geom: PackageGeometry) -> jnp.ndarray:
    """Complex gain of one ray: amplitude ~ (lambda / 4 pi d)^(gamma/2), phase 2 pi d/lambda."""
    lam = geom.wavelength_mm
    amp = (lam / (4.0 * jnp.pi * jnp.maximum(dist, 0.5))) ** (geom.path_loss_exp / 2.0)
    phase = -2.0 * jnp.pi * dist / lam
    return amp * jnp.exp(1j * phase)


def channel_matrix_cavity(geom: PackageGeometry, n_tx: int, n_rx: int) -> jnp.ndarray:
    """Modal (Green's function) channel of the lidded package — the CST substitute.

    The metallic lid turns the h1 = 0.1 mm air gap into a thin resonant cavity; at
    60 GHz the field between any two antennas is dominated by the rectangular-cavity
    eigenmodes with k_pq near k0 = 2*pi/lambda:

        H[r, t] = sum_pq  phi_pq(rx_r) * phi_pq(tx_t) / (k_pq^2 - k0^2 (1 + j/Q))
        phi_pq(x, y) = cos(p*pi*x/L1) * cos(q*pi*y/L2)      (PEC walls, TM-like)

    Only ~a handful of modes fall inside the 1/Q resonance band, so H is
    effectively *low-rank across receivers*: the relative TX phases seen by
    different RXs are strongly correlated. This is precisely the property that
    makes the paper's *joint* TX-phase optimization able to satisfy all 64 RX
    constellations at once (a purely ray-like channel with i.i.d. phases cannot).
    Deterministic given geometry — the "full electromagnetic knowledge of the chip
    package" that the paper pre-characterizes.
    """
    txp = tx_positions(geom, n_tx)  # [M, 2]
    rxp = rx_positions(geom, n_rx)  # [N, 2]
    lam = geom.wavelength_mm
    k0 = 2.0 * jnp.pi / lam
    p_max = int(2.0 * k0 * geom.L1 / jnp.pi) + 1
    q_max = int(2.0 * k0 * geom.L2 / jnp.pi) + 1
    p = jnp.arange(p_max + 1)
    q = jnp.arange(q_max + 1)
    kx = p * jnp.pi / geom.L1
    ky = q * jnp.pi / geom.L2
    k2 = kx[:, None] ** 2 + ky[None, :] ** 2                     # [P, Q]
    denom = k2 - k0 ** 2 * (1.0 + 1j / geom.cavity_q)            # Lorentzian pole

    def phi(pos):  # pos [K, 2] -> [K, P, Q]
        cx = jnp.cos(pos[:, 0:1] * kx[None, :])                   # [K, P]
        cy = jnp.cos(pos[:, 1:2] * ky[None, :])                   # [K, Q]
        return cx[:, :, None] * cy[:, None, :]

    phi_tx = phi(txp)   # [M, P, Q]
    phi_rx = phi(rxp)   # [N, P, Q]
    h = jnp.einsum("npq,mpq->nm", phi_rx / denom[None], phi_tx)
    # normalize to a sane link amplitude scale (absolute scale is calibrated away
    # by default_n0 anyway)
    return (h / (k0 ** 2 * geom.L1 * geom.L2)).astype(jnp.complex64) * 1e3


def channel_matrix_ray(geom: PackageGeometry, n_tx: int, n_rx: int) -> jnp.ndarray:
    """Ray/image-source channel (LOS + first-order wall images) — the non-resonant
    alternative model; kept for ablation (shows *why* the cavity matters)."""
    txp = tx_positions(geom, n_tx)  # [M, 2]
    rxp = rx_positions(geom, n_rx)  # [N, 2]

    def pair_gain(rx, tx):
        d_los = jnp.linalg.norm(rx - tx)
        g = _ray_gain(d_los, geom)
        if geom.n_reflections >= 1:
            # image sources in x=0, x=L1, y=0, y=L2 walls
            images = jnp.stack([
                jnp.array([-1.0, 1.0]) * tx,                                    # x=0
                jnp.array([2.0 * geom.L1, 0.0]) + jnp.array([-1.0, 1.0]) * tx,  # x=L1
                jnp.array([1.0, -1.0]) * tx,                                    # y=0
                jnp.array([0.0, 2.0 * geom.L2]) + jnp.array([1.0, -1.0]) * tx,  # y=L2
            ])
            d_img = jnp.linalg.norm(rx[None] - images, axis=-1)
            g = g + geom.wall_reflection * jnp.sum(_ray_gain(d_img, geom))
        return g

    return jax.vmap(lambda rx: jax.vmap(lambda tx: pair_gain(rx, tx))(txp))(rxp)


def channel_matrix(geom: PackageGeometry, n_tx: int, n_rx: int) -> jnp.ndarray:
    """Dispatch on geom.model: "cavity" (default, resonant package) or "ray"."""
    if geom.model == "cavity":
        return channel_matrix_cavity(geom, n_tx, n_rx)
    return channel_matrix_ray(geom, n_tx, n_rx)


def snr_per_rx(h: jnp.ndarray, n0) -> jnp.ndarray:
    """Per-receiver mean link SNR in dB: mean over TXs of |H[r, t]|^2 / N0.

    The per-RX counterpart of `ota.default_n0`'s mean-SNR calibration —
    diagnostic for the channel-fidelity sweeps (which RXs sit in deep fades of
    the cavity pattern and dominate the physical-vs-BSC accuracy gap).
    """
    p = jnp.mean(jnp.abs(h) ** 2, axis=-1)
    return 10.0 * jnp.log10(p / n0)


def analytic_ber_band(
    h: jnp.ndarray,
    n0,
    ber: jnp.ndarray,
    *,
    slack_db: float = 6.0,
    fade_slack: float = 0.5,
    floor: float = 0.02,
    cap: float = 0.5,
) -> jnp.ndarray:
    """Per-RX acceptance ceiling for the EMPIRICAL flip rate: [N] f32.

    The online monitor (`repro.phy.process`) estimates each receiver's live
    flip rate from guard-symbol decode disagreements; this is the analytic
    band it is judged against.  A receiver is "in band" while its estimate
    stays below

        hi[r] = max( ber[r] * 10^((slack_db + fade_slack*max(0, snr_mean -
                snr[r]))/10),  floor )

    i.e. the characterized Eq.-1 BER widened by a fixed multiplicative slack
    plus extra headroom for receivers sitting in deep fades of the cavity
    pattern (their `snr_per_rx` is below the mean, so the same physical
    perturbation moves their error rate proportionally more — judging them
    against the tight band would re-characterize them on every step).
    ``floor`` keeps near-error-free receivers (BER ~1e-5 is common, half the
    paper's 64) from tripping the band on shot noise of a short guard block;
    ``cap`` bounds the ceiling from above so receivers that were ALREADY
    noisy at characterization (large ber[r], hence a large multiplicative
    band) still get re-fit before their flip rate reaches vote-poisoning
    territory. Estimates above hi[r] trigger the EM re-fit of the decision
    regions (`phy.process.recharacterize`).
    """
    snr = snr_per_rx(h, n0)
    rel = jnp.maximum(jnp.mean(snr) - snr, 0.0)
    mult = 10.0 ** ((slack_db + fade_slack * rel) / 10.0)
    hi = jnp.minimum(jnp.maximum(ber * mult, floor), cap)
    return jnp.clip(hi, 0.0, 0.5).astype(jnp.float32)
