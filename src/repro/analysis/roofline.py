"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the assignment:

    compute    = HLO_FLOPs      / (chips * 197e12  FLOP/s bf16)
    memory     = HLO_bytes      / (chips * 819e9   B/s HBM)
    collective = coll_bytes     / (chips * 50e9    B/s/link ICI)

HLO_FLOPs / HLO_bytes come from XLA's cost analysis, read through
compat.normalized_cost_analysis (dict on every JAX version). Collective bytes are
NOT in cost_analysis: `collective_bytes` parses the optimized HLO text and sums
*operand* bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per-type breakdown kept for diagnosis).

Caveat recorded in EXPERIMENTS.md: XLA's cost analysis counts a while-loop body
once, so FLOPs of `lax.scan`d layer stacks are scaled by the trip count here
(we re-multiply using the scan metadata captured at lowering time is NOT
possible post-hoc; instead the dry-run lowers with scans unrolled=1 and we scale
by n_layers analytically via MODEL_FLOPS, reporting both raw and scaled).
"""
from __future__ import annotations

import dataclasses
import math
import re

from repro.compat import normalized_cost_analysis

HW = {"flops": 197e12, "hbm": 819e9, "link": 50e9}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\s*\("
)
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO text.

    Returns {op_type: bytes, ..., 'total': bytes, 'count': n}. Counts each
    start/done pair once (the -start op carries the operands).
    """
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:60]:
            continue
        op = m.group(1)
        # operands: shapes appearing inside the call parens
        paren = line[m.end() - 1 :]
        shapes = _SHAPE_RE.findall(paren)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if nbytes == 0:
            continue
        out[op] = out.get(op, 0) + nbytes
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["count"] = count
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float, chips: int) -> Roofline:
    return Roofline(
        compute_s=flops / (chips * HW["flops"]),
        memory_s=bytes_accessed / (chips * HW["hbm"]),
        collective_s=coll_bytes / (chips * HW["link"]),
    )


def roofline_from_compiled(compiled, chips: int) -> Roofline:
    """Roofline terms straight from a compiled program, using XLA's own
    (raw, scan-body-counted-once) cost numbers plus the HLO-text collective
    scan. For trip-count-corrected inputs use hlo_cost.analyze_compiled and
    feed roofline_terms directly."""
    cost = normalized_cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return roofline_terms(
        float(cost.get("flops", 0.0) or 0.0),
        float(cost.get("bytes accessed", 0.0) or 0.0),
        float(coll["total"]),
        chips=chips,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful FLOPs" yardstick)
# ---------------------------------------------------------------------------

def active_params(cfg, total_params: int) -> int:
    """Parameters touched per token (MoE: routed top-k + shared only)."""
    if cfg.moe is None:
        return total_params
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = cfg.n_layers * (m.n_experts - m.top_k) * per_expert
    return total_params - inactive


def model_flops(cfg, cell, total_params: int) -> float:
    """6·N·D (train), 2·N_active·D (prefill), 2·N_active·B (decode)."""
    n_act = active_params(cfg, total_params)
    if cell.kind == "train":
        return 6.0 * n_act * cell.batch * cell.seq  # N_active == N for dense
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.batch * cell.seq
    return 2.0 * n_act * cell.batch  # decode: one token per sequence
