"""Run every paper-table benchmark and print a summary CSV.

  PYTHONPATH=src python -m benchmarks.run [--fast]

One section per paper table/figure (table1, fig8-fig11), plus the two
framework-level analyses (ota_vs_wired, roofline) that read the dry-run
artifacts if present.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer Monte-Carlo trials")
    ap.add_argument("--no-kernels", action="store_true",
                    help="pure-jnp similarity instead of the Pallas kernels "
                         "(kernels run in interpret mode on CPU and are the "
                         "default so regressions show up in the figures)")
    ap.add_argument("--representation", default="unpacked",
                    choices=["unpacked", "packed"],
                    help="hypervector storage for the classifier trials")
    args = ap.parse_args()

    from benchmarks import fig8, fig9, fig10, fig11, ota_vs_wired, roofline, table1

    rows = []
    clf_kw = dict(use_kernels=not args.no_kernels, representation=args.representation)

    def section(name, fn, **kw):
        print(f"\n=== {name} ===")
        t0 = time.time()
        out = fn(**kw)
        rows.append((name, time.time() - t0, out))
        return out

    t1 = section("table1 (Table I)", table1.run,
                 n_trials=300 if args.fast else 1000, **clf_kw)
    f8 = section("fig8 (per-RX BER)", fig8.run)
    section("fig9 (BER vs N_rx)", fig9.run)
    section("fig10 (accuracy vs BER)", fig10.run,
            n_trials=200 if args.fast else 600, **clf_kw)
    section("fig11 (similarity profiles)", fig11.run)
    section("ota_vs_wired (interconnect)", ota_vs_wired.run)
    section("roofline (pod1)", roofline.run, quiet=True)

    print("\nname,seconds,derived")
    for name, dt, out in rows:
        derived = ""
        if name.startswith("table1"):
            derived = f"acc(M=3 wireless baseline)={out['baseline/wireless'][1]:.3f}"
        elif name.startswith("fig8"):
            derived = f"avg_ber={out['avg_eq1']:.4f};max={out['max_eq1']:.4f}"
        elif name.startswith("roofline"):
            ok = [r for r in out["rows"] if r["status"] == "ok"]
            derived = f"cells_ok={len(ok)}"
        print(f"{name.split()[0]},{dt:.1f},{derived}")


if __name__ == "__main__":
    main()
