"""End-to-end paper pipeline: EM channel -> joint phase search -> BER -> HDC
accuracy, plus the distributed serve path wired to the pre-characterized BER —
the full Fig. 5 methodology in one test module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_test_mesh

from repro.core import classifier, em, hypervector as hv, ota

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def pipeline():
    geom = em.PackageGeometry()
    h = em.channel_matrix(geom, 3, 64)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_exhaustive(h, n0)
    return geom, h, n0, res


def test_full_chain_reproduces_paper_claims(pipeline):
    """The headline claim: 3 TX + 64 RX, avg BER ~0.01, no accuracy impact with
    512-bit hypervectors and 100 classes (abstract + Table I)."""
    _, _, _, res = pipeline
    avg_ber = float(res.avg_ber)
    assert avg_ber <= 0.0105

    cfg = classifier.HDCTaskConfig(n_classes=100, dim=512, n_trials=400)
    acc_ideal = float(classifier.run_accuracy(KEY, cfg, 3, 0.0, "baseline"))
    acc_wireless = float(classifier.run_accuracy(KEY, cfg, 3, avg_ber, "baseline"))
    assert acc_ideal - acc_wireless <= 0.02  # "practically irrelevant"


def test_fig11_similarity_separation(pipeline):
    """Fig. 11: sent classes separate cleanly from the rest of the memory."""
    _, _, _, res = pipeline
    cfg = classifier.HDCTaskConfig(n_trials=1)
    protos = classifier.make_codebook(KEY, cfg)
    for m in (1, 3, 5):
        classes = jax.random.randint(jax.random.fold_in(KEY, m), (m,), 0, cfg.n_classes)
        q = hv.majority(protos[classes])
        q = hv.flip_bits(jax.random.fold_in(KEY, 99), q, float(res.avg_ber))
        sims = hv.hamming_similarity(q, protos)
        sent = np.asarray(sims[classes])
        rest = np.delete(np.asarray(sims), np.asarray(classes))
        assert sent.min() > rest.max(), m  # clean separation (no classification error)


def test_scaled_out_serve_with_measured_ber(pipeline):
    """Distributed scale-out on the single-device mesh with the measured channel
    state: classification accuracy unaffected (paper contribution (i)) — on the
    Eq. 1 BSC tier AND the full physical symbol tier from the SAME state."""
    import dataclasses

    _, h, _, res = pipeline
    from repro import phy
    from repro.core import scaleout

    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=128, dim=512, m_tx=3, n_rx_cores=64, batch=64, use_kernels=True
    )
    state = phy.state_from_ota(res, h)
    protos = hv.random_hv(KEY, cfg.n_classes, cfg.dim)
    classes, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 1)
    serve = scaleout.make_ota_serve(mesh, cfg)
    pred, _ = serve(protos, queries, state, jax.random.PRNGKey(2))
    # the top-1 must be one of the bundled classes (channel noise may re-order
    # the three near-equal bundled similarities — that is not an error)
    hit = float(jnp.mean(jnp.any(pred[:, None] == classes, axis=1).astype(jnp.float32)))
    assert hit >= 0.97, hit
    # the physical channel (constellation + AWGN + decision regions in-graph)
    # reproduces the paper's operating point end-to-end — the BER abstraction
    # verified rather than assumed
    serve_s = scaleout.make_ota_serve(mesh, dataclasses.replace(cfg, channel="symbol"))
    pred_s, _ = serve_s(protos, queries, state, jax.random.PRNGKey(2))
    hit_s = float(jnp.mean(jnp.any(pred_s[:, None] == classes, axis=1).astype(jnp.float32)))
    assert hit_s >= 0.97, hit_s
    # and with a clean channel the distributed path equals the oracle exactly
    state0 = phy.state_from_ber(jnp.zeros_like(res.ber_per_rx), cfg.m_tx)
    pred0, _ = serve(protos, queries, state0, jax.random.PRNGKey(2))
    ref, _ = scaleout.serve_reference(cfg, protos, queries)
    assert bool(jnp.all(pred0 == ref))


def test_packed_serve_matches_unpacked_with_measured_ber(pipeline):
    """The packed fast path on the measured per-RX BERs: identical predictions
    to the unpacked serve on the same RNG stream (exact noise masks), with the
    Pallas hamming kernel in the loop (interpret mode on CPU)."""
    import dataclasses

    _, _, _, res = pipeline
    from repro import phy
    from repro.core import scaleout

    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=128, dim=512, m_tx=3, n_rx_cores=8, batch=32, use_kernels=True
    )
    cfg_p = dataclasses.replace(cfg, representation="packed")
    protos = hv.random_hv(KEY, cfg.n_classes, cfg.dim)
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 1)
    _, queries_p = scaleout.make_queries(jax.random.PRNGKey(1), cfg_p, protos, 1)
    state = phy.state_from_ber(res.ber_per_rx[: cfg.n_rx_cores], cfg.m_tx)
    pred, sim = scaleout.make_ota_serve(mesh, cfg)(
        protos, queries, state, jax.random.PRNGKey(2))
    pred_p, sim_p = scaleout.make_ota_serve(mesh, cfg_p)(
        hv.pack(protos), queries_p, state, jax.random.PRNGKey(2))
    assert bool(jnp.all(pred == pred_p))
    np.testing.assert_array_equal(np.asarray(sim), np.asarray(sim_p))


def test_permuted_bundling_identifies_transmitter(pipeline):
    """Paper Sec. IV: permuted bundling recovers *which TX* sent each class."""
    _, _, _, res = pipeline
    cfg = classifier.HDCTaskConfig(n_classes=100, dim=512, n_trials=300)
    acc = float(classifier.run_accuracy(KEY, cfg, 5, float(res.avg_ber), "permuted"))
    assert acc >= 0.99
