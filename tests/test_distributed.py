"""Multi-device tests (8 fake CPU devices via subprocess — the main test process
must keep seeing 1 device, so each case runs in its own python with XLA_FLAGS).
Covers: rules engine resolution, OTA scale-out serve vs oracle, majority
all-reduce == kernel majority, sign-majority training convergence.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run8(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_rules_engine_resolution():
    # single-device, no subprocess needed
    from jax.sharding import PartitionSpec as P

    from conftest import make_test_mesh
    from repro.distributed.sharding import DEFAULT_RULES, spec_for_shape

    mesh = make_test_mesh((1, 1), ("data", "model"))
    # divisibility drop: 15 heads on a 1-wide model axis still resolves
    spec = spec_for_shape(("embed", "heads", "head_dim"), (960, 15, 64),
                          DEFAULT_RULES, mesh)
    assert spec == P(None, "model") or spec == P(None, "model", None) or spec == P()
    # each mesh axis used at most once
    spec2 = spec_for_shape(("batch", "seq", "embed"), (8, 128, 64),
                           dict(DEFAULT_RULES) | {"embed": "data"}, mesh)
    assert "data" not in (spec2[2:] if len(spec2) > 2 else ())


def test_scaleout_serve_matches_oracle():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    for permuted in (False, True):
        cfg = scaleout.ScaleOutConfig(n_classes=40, dim=512, m_tx=3, n_rx_cores=8,
                                      batch=8, permuted=permuted, use_kernels=True)
        protos = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)
        classes, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 4)
        state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
        pred, sim = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, jax.random.PRNGKey(2))
        rp, rs = scaleout.serve_reference(cfg, protos, queries)
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(rp))
        np.testing.assert_allclose(np.asarray(sim), np.asarray(rs), rtol=1e-6)
        if permuted:
            np.testing.assert_array_equal(np.asarray(pred), np.asarray(classes))
    wp, _ = scaleout.make_wired_serve(mesh, cfg if not cfg.permuted else
        scaleout.ScaleOutConfig(n_classes=40, dim=512, m_tx=3, n_rx_cores=8, batch=8))(
        protos, queries, state, jax.random.PRNGKey(2))
    print("OK")
    """)


def test_packed_serve_prediction_identical():
    """The bit-packed fast path must be prediction-identical (and maxsim-equal)
    to the unpacked dataflow on the SAME RNG stream with nonzero per-core BER —
    baseline and permuted bundling x psum, psum_packed and rs_ag collectives
    (the guard-bit packed vote all-reduce produces the identical tally, so every
    collective mode must land on identical predictions)."""
    run8("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    protos = hv.random_hv(jax.random.PRNGKey(0), 40, 512)
    state = phy.state_from_ber(jnp.full((8,), 0.05), 3)
    key = jax.random.PRNGKey(2)
    for permuted in (False, True):
        base = None
        for coll in ("psum", "psum_packed", "rs_ag"):
            cfg = scaleout.ScaleOutConfig(n_classes=40, dim=512, m_tx=3,
                                          n_rx_cores=8, batch=8, permuted=permuted,
                                          collective=coll, use_kernels=True)
            cfg_p = dataclasses.replace(cfg, representation="packed")
            classes, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 4)
            _, queries_p = scaleout.make_queries(jax.random.PRNGKey(1), cfg_p, protos, 4)
            pred, sim = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)
            pred_p, sim_p = scaleout.make_ota_serve(mesh, cfg_p)(
                hv.pack(protos), queries_p, state, key)
            np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_p))
            np.testing.assert_array_equal(np.asarray(sim), np.asarray(sim_p))
            if base is None:
                base = (np.asarray(pred), np.asarray(sim))
            else:  # identical across collective realizations too
                np.testing.assert_array_equal(np.asarray(pred), base[0])
                np.testing.assert_array_equal(np.asarray(sim), base[1])
    print("OK")
    """)


def test_packed_vote_allreduce_matches_int8_psum():
    """Property: the guard-bit packed vote all-reduce is bit-identical to the
    int8 psum tally across mesh sizes, e_per, random votes and the adversarial
    all-(+/-)e_per inputs that exercise the field-overflow guard; the packed
    reduce-scatter leg matches psum_scatter on every shard."""
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.distributed import collectives

    for s, e_per, d in [(8, 1, 512), (4, 2, 512), (4, 1, 100), (2, 5, 96),
                        (8, 3, 257), (1, 2, 64)]:
        mesh = make_mesh((s,), ("m",))
        key = jax.random.PRNGKey(s * 1000 + e_per * 10 + d)
        cases = [
            jax.random.randint(key, (s, 4, d), -e_per, e_per + 1).astype(jnp.int8),
            jnp.full((s, 4, d), e_per, jnp.int8),    # all votes saturate +
            jnp.full((s, 4, d), -e_per, jnp.int8),   # all votes saturate -
        ]
        for votes in cases:
            def body(v):
                ref = jax.lax.psum(v[0].astype(jnp.int32), "m")
                got = collectives.packed_vote_allreduce(
                    v[0], "m", group_size=s, e_per=e_per)
                return ref[None], got[None]
            fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("m"),
                                   out_specs=(P(), P()), axis_names={"m"},
                                   check_vma=False))
            ref, got = fn(votes)
            assert got.dtype == jnp.int32
            np.testing.assert_array_equal(
                np.asarray(ref), np.asarray(got), err_msg=str((s, e_per, d)))
            if d % s == 0:
                def body2(v):
                    ref = jax.lax.psum_scatter(v[0].astype(jnp.int32), "m",
                                               scatter_dimension=1, tiled=True)
                    got = collectives.packed_vote_psum_scatter(
                        v[0], "m", group_size=s, e_per=e_per)
                    return ref[None], got[None]
                fn2 = jax.jit(shard_map(body2, mesh=mesh, in_specs=P("m"),
                                        out_specs=(P("m"), P("m")),
                                        axis_names={"m"}, check_vma=False))
                ref, got = fn2(votes)
                np.testing.assert_array_equal(
                    np.asarray(ref), np.asarray(got), err_msg=str((s, e_per, d)))
    print("OK")
    """)


def test_packed_vote_allreduce_slot_aware_matches_int8_psum():
    """Property: ACTIVE-SLOT-AWARE guard bits (fields sized by the M live
    voters, per-column bias = that column's own live count) stay bit-identical
    to the int32 psum tally under the serve's abstaining-slot vote pattern —
    across mesh widths, e_per, M, random and saturating bits — and the
    slot-aware reduce-scatter leg matches psum_scatter on every shard."""
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.distributed import collectives

    for s, e_per, m_act, d in [(4, 1, 3, 512), (8, 1, 3, 512), (8, 2, 5, 512),
                               (4, 2, 7, 100), (2, 3, 4, 96), (8, 1, 8, 256)]:
        mesh = make_mesh((s,), ("m",))
        key = jax.random.PRNGKey(s * 1000 + e_per * 100 + m_act)
        cases = [
            jax.random.randint(key, (s, e_per, 4, d), 0, 2).astype(jnp.int8),
            jnp.ones((s, e_per, 4, d), jnp.int8),   # all live slots vote +1
            jnp.zeros((s, e_per, 4, d), jnp.int8),  # all live slots vote -1
        ]
        for bits in cases:
            def body(b):
                col = jax.lax.axis_index("m")
                gids = col * e_per + jnp.arange(e_per)
                active = (gids < m_act)[:, None, None]
                votes = jnp.sum(
                    jnp.where(active, 2 * b[0].astype(jnp.int8) - 1, 0), axis=0
                ).astype(jnp.int8)
                n_loc = jnp.clip(m_act - col * e_per, 0, e_per)
                ref = jax.lax.psum(votes.astype(jnp.int32), "m")
                got = collectives.packed_vote_allreduce(
                    votes, "m", group_size=s, e_per=e_per,
                    n_active=m_act, local_active=n_loc)
                outs = [ref[None], got[None]]
                fbits, k = collectives.vote_field_spec(
                    s, e_per, pow2_fields=True, n_active=m_act)
                if d % (k * s) == 0:
                    sref = jax.lax.psum_scatter(
                        votes.astype(jnp.int32), "m",
                        scatter_dimension=votes.ndim - 1, tiled=True)
                    sgot = collectives.packed_vote_psum_scatter(
                        votes, "m", group_size=s, e_per=e_per,
                        n_active=m_act, local_active=n_loc)
                    outs += [sref[None], sgot[None]]
                return tuple(outs)
            n_out = 4 if d % (collectives.vote_field_spec(
                s, e_per, pow2_fields=True, n_active=m_act)[1] * s) == 0 else 2
            fn = jax.jit(shard_map(
                body, mesh=mesh, in_specs=P("m"),
                out_specs=(P(), P()) if n_out == 2 else (P(), P(), P("m"), P("m")),
                axis_names={"m"}, check_vma=False))
            outs = fn(bits)
            assert outs[1].dtype == jnp.int32
            np.testing.assert_array_equal(
                np.asarray(outs[0]), np.asarray(outs[1]),
                err_msg=str((s, e_per, m_act, d)))
            if n_out == 4:
                np.testing.assert_array_equal(
                    np.asarray(outs[2]), np.asarray(outs[3]),
                    err_msg=str((s, e_per, m_act, d)))
    print("OK")
    """)


def test_symbol_serve_matches_host_oracle_on_mesh():
    """channel="symbol" on the 2x4 mesh: the sharded combo psum + per-core
    constellation/AWGN/decision decode equals a host re-derivation from the
    same ChannelState bit-for-bit (per data row r: fold_in(key, r), per global
    core g: fold_in(., g)) — the physical tier is mesh-layout invariant."""
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import em, hypervector as hv, ota, scaleout
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(n_classes=32, dim=512, m_tx=3, n_rx_cores=8,
                                  batch=8, channel="symbol", use_kernels=True)
    h = em.channel_matrix(em.PackageGeometry(), cfg.m_tx, cfg.n_rx_cores)
    n0 = ota.default_n0(h)
    state = phy.state_from_ota(ota.optimize_phases_exhaustive(h, n0), h)
    protos = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 4)
    key = jax.random.PRNGKey(2)
    pred, sim = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)

    q_act = queries.reshape(cfg.batch, -1, cfg.dim)[:, : cfg.m_tx]
    combo = phy.combo_index(q_act, axis=1)                       # [B, d]
    c_core = cfg.n_classes // cfg.n_rx_cores
    b_l = cfg.batch // 2
    rows = []
    for r in range(2):                                           # data rows
        kq = jax.random.fold_in(key, r)
        cb = combo[r * b_l:(r + 1) * b_l]
        sims = []
        for g in range(cfg.n_rx_cores):                          # global cores
            q_g = phy.awgn_decide(jax.random.fold_in(kq, g),
                                  state.symbols[g][cb], state.c0[g],
                                  state.c1[g], state.n0)
            p_g = protos[g * c_core:(g + 1) * c_core]
            sims.append(jnp.einsum("bd,cd->bc",
                                   2.0 * q_g.astype(jnp.float32) - 1,
                                   2.0 * p_g.astype(jnp.float32) - 1))
        rows.append(jnp.concatenate(sims, axis=1))               # [B_l, C]
    sims = jnp.concatenate(rows, axis=0)                         # [B, C]
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(jnp.argmax(sims, -1)))
    np.testing.assert_allclose(
        np.asarray(sim),
        np.asarray(jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5), rtol=1e-6)
    # packed symbol serve (decode bits -> pack -> fused top-1): identical
    import dataclasses
    cfg_p = dataclasses.replace(cfg, representation="packed")
    _, queries_p = scaleout.make_queries(jax.random.PRNGKey(1), cfg_p, protos, 4)
    pred_p, sim_p = scaleout.make_ota_serve(mesh, cfg_p)(
        hv.pack(protos), queries_p, state, key)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_p))
    np.testing.assert_array_equal(np.asarray(sim), np.asarray(sim_p))
    print("OK")
    """)


def test_packed_wired_and_train_match_unpacked():
    """Wired-baseline serve and one-shot HDC train agree across representations;
    the packed bitplane noise mode also runs and matches the oracle at BER 0."""
    run8("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(n_classes=40, dim=512, m_tx=3, n_rx_cores=8,
                                  batch=8, use_kernels=True)
    cfg_p = dataclasses.replace(cfg, representation="packed")
    protos = hv.random_hv(jax.random.PRNGKey(0), cfg.n_classes, cfg.dim)
    classes, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 4)
    _, queries_p = scaleout.make_queries(jax.random.PRNGKey(1), cfg_p, protos, 4)
    state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
    key = jax.random.PRNGKey(2)
    wp, ws = scaleout.make_wired_serve(mesh, cfg)(protos, queries, state, key)
    wpp, wsp = scaleout.make_wired_serve(mesh, cfg_p)(hv.pack(protos), queries_p, state, key)
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wpp))
    np.testing.assert_array_equal(np.asarray(ws), np.asarray(wsp))
    labels = jnp.arange(cfg.batch, dtype=jnp.int32) % cfg.n_classes
    tr = scaleout.make_hdc_train(mesh, cfg)(protos[labels], labels)
    tr_p = scaleout.make_hdc_train(mesh, cfg_p)(hv.pack(protos[labels]), labels)
    np.testing.assert_array_equal(np.asarray(tr), np.asarray(hv.unpack(tr_p, cfg.dim)))
    # bitplane noise mode: valid program; at BER 0 it matches the oracle exactly
    cfg_b = dataclasses.replace(cfg_p, noise="bitplane")
    pb, _ = scaleout.make_ota_serve(mesh, cfg_b)(hv.pack(protos), queries_p, state, key)
    rp, _ = scaleout.serve_reference(cfg_b, hv.pack(protos), queries_p)
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(rp))
    print("OK")
    """)


def test_vote_field_spec_values():
    # single-device, no subprocess needed
    from repro.distributed.collectives import vote_field_spec

    # paper operating point on pod1: S=4 model axis, e_per=1 -> span 8 ->
    # 4-bit fields, 8 per uint32 lane (the ~2x wire cut vs int8 votes)
    assert vote_field_spec(4, 1) == (4, 8)
    assert vote_field_spec(16, 1) == (6, 5)
    assert vote_field_spec(16, 1, pow2_fields=True) == (6, 4)
    assert vote_field_spec(1, 1) == (2, 16)
    assert vote_field_spec(8, 3) == (6, 5)
    # active-slot-aware: the tally span is 2*M regardless of the mesh width —
    # at S=16/M=3 that's 3-bit fields, 10 per lane (~2.5x vs int8 votes) where
    # slot-blind guards gave 6-bit/5 (1.25x) — ROADMAP's named next wire step
    assert vote_field_spec(16, 1, n_active=3) == (3, 10)
    assert vote_field_spec(16, 1, pow2_fields=True, n_active=3) == (3, 8)
    assert vote_field_spec(4, 1, n_active=3) == (3, 10)
    assert vote_field_spec(4, 2, n_active=3) == (3, 10)  # e_per-split slots
    assert vote_field_spec(16, 1, n_active=16) == vote_field_spec(16, 1)


def test_majority_allreduce_equals_kernel():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.distributed import collectives
    from repro.kernels.majority.ref import majority_bundle_ref
    mesh = make_mesh((8,), ("tx",))
    bits = jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (7, 64, 128)).astype(jnp.uint8)
    # 7 active senders on 8 slots: slot 7 abstains by majority_allreduce over
    # shards that carry one hv each -> emulate with shard over leading axis 8
    bits8 = jnp.concatenate([bits, jnp.zeros((1, 64, 128), jnp.uint8)])
    def body(shard):
        active = jax.lax.axis_index("tx") < 7
        votes = jnp.where(active, 2 * shard[0].astype(jnp.int8) - 1, 0)
        tally = jax.lax.psum(votes, "tx")
        return (tally > 0).astype(jnp.uint8)
    out = jax.jit(shard_map(body, mesh=mesh, in_specs=P("tx"), out_specs=P(),
                            axis_names={"tx"}, check_vma=False))(bits8)
    ref = majority_bundle_ref(bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    print("OK")
    """)


def test_ota_noise_per_rx_independent():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.distributed import collectives
    mesh = make_mesh((8,), ("rx",))
    bits = jnp.zeros((4096,), jnp.uint8)
    def body(key):
        return collectives.ota_noise(key, bits, 0.1, axis_name="rx")[None]
    out = jax.jit(shard_map(body, mesh=mesh, in_specs=P(), out_specs=P("rx"),
                            axis_names={"rx"}, check_vma=False))(jax.random.PRNGKey(0))
    rates = np.asarray(jnp.mean(out.astype(jnp.float32), axis=-1))
    assert ((rates > 0.07) & (rates < 0.13)).all(), rates
    # copies differ across receivers
    assert len({tuple(np.asarray(r)) for r in out}) == 8
    print("OK")
    """)


def test_sign_majority_training_converges():
    run8("""
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.compat import make_mesh
    from repro.models import get_model
    from repro.train.loop import build_train_fns
    from repro.train.optimizer import OptConfig
    from repro.data import SyntheticLM, DataConfig
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = configs.get_smoke("tinyllama_1_1b")
    model = get_model(cfg)
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=128, global_batch=8))
    key = jax.random.PRNGKey(0)
    opt = OptConfig(kind="sign_majority", lr=3e-4, warmup=5, total_steps=40)
    fns = build_train_fns(model, mesh, opt, ota_ber=0.01)
    params, opt_state = fns.init(key)
    params = jax.device_put(params, fns.param_shardings)
    opt_state = jax.device_put(opt_state, fns.opt_shardings)
    losses = []
    for step in range(20):
        params, opt_state, m = fns.step(params, opt_state, pipe.batch(step),
                                        jax.random.fold_in(key, step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses
    print("OK", losses[0], losses[-1])
    """)


def test_dense_dp_equals_single_device():
    """Same seeds: 8-device DP adamw training == 1-device training."""
    code_tpl = """
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.compat import make_mesh
    from repro.models import get_model
    from repro.train.loop import build_train_fns
    from repro.train.optimizer import OptConfig
    from repro.data import SyntheticLM, DataConfig
    mesh = make_mesh({mesh_shape}, ("data", "model"))
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=64, global_batch=8))
    key = jax.random.PRNGKey(0)
    fns = build_train_fns(model, mesh, OptConfig(lr=1e-3, warmup=2, total_steps=10))
    params, opt_state = fns.init(key)
    params = jax.device_put(params, fns.param_shardings)
    opt_state = jax.device_put(opt_state, fns.opt_shardings)
    for step in range(5):
        params, opt_state, m = fns.step(params, opt_state, pipe.batch(step), key)
    print(float(m["loss"]))
    """
    import textwrap as tw
    out8 = run8(code_tpl.format(mesh_shape="(4, 2)"))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r1 = subprocess.run(
        [sys.executable, "-c", tw.dedent(code_tpl.format(mesh_shape="(1, 1)"))],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r1.returncode == 0, r1.stderr[-2000:]
    l8, l1 = float(out8.strip().splitlines()[-1]), float(r1.stdout.strip().splitlines()[-1])
    assert abs(l8 - l1) < 5e-3, (l8, l1)
