"""Fig. 11: similarity-vs-class profiles for bundled queries (baseline vs
permuted bundling; ideal vs wireless channel), M in {1, 3, 5, 7}."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro.core import classifier, em, hypervector as hv, ota


def run(quiet: bool = False) -> dict:
    h = em.channel_matrix(em.PackageGeometry(), 3, 64)
    res = ota.optimize_phases_exhaustive(h, ota.default_n0(h))
    ber = float(res.avg_ber)
    cfg = classifier.HDCTaskConfig()
    key = jax.random.PRNGKey(0)
    protos = classifier.make_codebook(key, cfg)
    out = {"ber": ber}
    for m in (1, 3, 5, 7):
        classes = jax.random.randint(jax.random.fold_in(key, m), (m,), 0, cfg.n_classes)
        q = hv.majority(protos[classes])
        sims_ideal = hv.hamming_similarity(q, protos)
        qn = hv.flip_bits(jax.random.fold_in(key, 100 + m), q, ber)
        sims_wireless = hv.hamming_similarity(qn, protos)
        sent = np.asarray(sims_wireless)[np.asarray(classes)]
        rest = np.delete(np.asarray(sims_wireless), np.asarray(classes))
        out[f"m{m}"] = {
            "classes": np.asarray(classes).tolist(),
            "ideal": np.asarray(sims_ideal).round(4).tolist(),
            "wireless": np.asarray(sims_wireless).round(4).tolist(),
            "sent_min": float(sent.min()),
            "rest_max": float(rest.max()),
        }
        if not quiet:
            print(f"M={m}: sent-class sim >= {sent.min():.3f}, other classes <= "
                  f"{rest.max():.3f}  separated={sent.min() > rest.max()}")
    save("fig11", out)
    return out


if __name__ == "__main__":
    run()
