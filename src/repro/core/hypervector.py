"""Binary hyperdimensional-computing algebra.

Hypervectors (HVs) are d-dimensional pseudo-random binary vectors (d >= 512 in this
paper; classically d ~ 10,000). We keep two representations:

* **unpacked**: ``uint8`` arrays of {0, 1} — convenient for algebra and majority.
* **packed**: ``uint32`` arrays of d/32 words — used by the Pallas Hamming kernel,
  mirroring how an IMC macro would store a row.

All ops are pure jnp and jit-friendly. Bipolar view {-1,+1} = 2*hv-1 is used where a
matmul (MXU) formulation is preferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

WORD = 32


def random_hv(key: jax.Array, num: int, dim: int) -> jax.Array:
    """`num` i.i.d. random binary hypervectors of dimension `dim` (uint8 {0,1})."""
    return jax.random.bernoulli(key, 0.5, (num, dim)).astype(jnp.uint8)


def bind(a: jax.Array, b: jax.Array) -> jax.Array:
    """Binding = elementwise XOR. Involutive, similarity-preserving."""
    return jnp.bitwise_xor(a, b)


def permute(hv: jax.Array, shift: int | jax.Array) -> jax.Array:
    """Cyclic permutation rho^shift along the last (dimension) axis."""
    return jnp.roll(hv, shift, axis=-1)


def permute_batch(hvs: jax.Array, shifts: jax.Array) -> jax.Array:
    """Apply per-row cyclic shifts: hvs [M, d], shifts [M] -> [M, d].

    Used for the paper's *permuted bundling*: transmitter m applies rho^m so each
    TX has a distinguishable signature and the shared codebook decorrelates.
    """
    d = hvs.shape[-1]
    idx = (jnp.arange(d)[None, :] - shifts[:, None]) % d
    return jnp.take_along_axis(hvs, idx.astype(jnp.int32), axis=-1)


def majority(hvs: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """Bit-wise logical majority (the HDC *bundling* op) over axis 0.

    hvs: [M, ..., d] uint8 in {0,1}.  Even-M tie convention (repo-wide): ties
    resolve to 0, i.e. strict majority ``count*2 > M`` — the same rule as the
    scale-out ``tally > 0`` psum path, `kernels.majority`, and
    `majority_packed`.  Passing `key` opts into the classical randomized
    tie-break (a random hypervector decides ties); that variant never runs on
    the distributed serve path.
    """
    m = hvs.shape[0]
    counts = jnp.sum(hvs.astype(jnp.int32), axis=0)
    if m % 2 == 1 or key is None:
        return (counts * 2 > m).astype(jnp.uint8)
    tie = jax.random.bernoulli(key, 0.5, counts.shape)
    return jnp.where(counts * 2 == m, tie, counts * 2 > m).astype(jnp.uint8)


def hamming_similarity(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Normalized similarity in [0,1]: 1 - hamming/d.

    q: [..., d]; protos: [C, d] -> [..., C].
    Implemented as a bipolar dot product so that on TPU it maps to the MXU —
    the direct analogue of the IMC crossbar MVM of the paper (Fig. 2).
    """
    d = q.shape[-1]
    qb = (2.0 * q.astype(jnp.float32) - 1.0)
    pb = (2.0 * protos.astype(jnp.float32) - 1.0)
    dots = qb @ pb.T  # in [-d, d]; = d - 2*hamming
    return (dots + d) / (2.0 * d)


def flip_bits(key: jax.Array, hv: jax.Array, ber: jax.Array | float) -> jax.Array:
    """Binary symmetric channel: flip each bit independently w.p. `ber`.

    This is how the paper injects the wireless OTA error figures into the HDC
    chain ("errors ... are modeled as uncorrelated bit flips over the query
    hypervectors").
    """
    flips = jax.random.bernoulli(key, ber, hv.shape)
    return jnp.bitwise_xor(hv, flips.astype(jnp.uint8))


def flip_bits_per_rx(key: jax.Array, hv: jax.Array, ber_per_rx: jax.Array) -> jax.Array:
    """Per-receiver BSC: hv [..., d] broadcast against ber_per_rx [N] -> [N, ..., d]."""
    n = ber_per_rx.shape[0]
    p = ber_per_rx.reshape((n,) + (1,) * hv.ndim)
    flips = jax.random.bernoulli(key, p, (n,) + hv.shape)
    return jnp.bitwise_xor(hv[None], flips.astype(jnp.uint8))


# ---------------------------------------------------------------------------
# packed representation
# ---------------------------------------------------------------------------

def pack(hv: jax.Array) -> jax.Array:
    """Pack uint8 {0,1} [..., d] -> uint32 [..., d//32] (little-endian bit order)."""
    d = hv.shape[-1]
    assert d % WORD == 0, f"dim {d} must be a multiple of {WORD}"
    w = hv.reshape(hv.shape[:-1] + (d // WORD, WORD)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(w * weights, axis=-1).astype(jnp.uint32)


def unpack(packed: jax.Array, dim: int) -> jax.Array:
    """Inverse of `pack`."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(packed.shape[:-1] + (dim,)).astype(jnp.uint8)


def hamming_distance_packed(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Packed-word Hamming distance via XOR + popcount.

    q: [..., W] uint32, protos: [C, W] uint32 -> int32 [..., C].
    The pure-jnp oracle for kernels/hamming.
    """
    x = jnp.bitwise_xor(q[..., None, :], protos)  # [..., C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


# ---------------------------------------------------------------------------
# packed algebra — the production fast path
#
# Every op below is bit-exact against its unpacked counterpart on the same PRNG
# stream (property-tested in tests/test_hdc_core.py): the packed serve pipeline
# can therefore be verified prediction-identical to the unpacked one while
# moving d/8 bytes per hypervector instead of d (uint8) or 4d (fp32 bipolar).
# ---------------------------------------------------------------------------

_FULL = jnp.uint32(0xFFFFFFFF)


def random_hv_packed(key: jax.Array, num: int, dim: int) -> jax.Array:
    """`num` i.i.d. random hypervectors drawn directly as uint32 words.

    [num, dim//32] — each of the d bits is an independent fair coin, exactly as
    `random_hv`, but the PRNG emits 32 bits per word instead of one uint8 per
    bit (no unpacked intermediate; a *different* stream than pack(random_hv)).
    """
    assert dim % WORD == 0, f"dim {dim} must be a multiple of {WORD}"
    return jax.random.bits(key, (num, dim // WORD), dtype=jnp.uint32)


def bind_packed(a: jax.Array, b: jax.Array) -> jax.Array:
    """Packed binding: word-wise XOR (identical to `bind`; packing commutes)."""
    return jnp.bitwise_xor(a, b)


def permute_packed(hvp: jax.Array, shift: int | jax.Array) -> jax.Array:
    """Cyclic permutation rho^shift on packed words [..., W].

    Equals pack(permute(unpack(hvp))): a word-level roll by shift//32 plus a
    bit-level shift by shift%32 with cross-word carry from the previous word
    (little-endian bit order, so `<<` moves bits toward higher dim indices).
    Accepts traced shifts (the per-TX signatures inside shard_map bodies).
    """
    w = hvp.shape[-1]
    d = w * WORD
    s = jnp.asarray(shift) % d
    ws = (s // WORD).astype(jnp.int32)
    bs = (s % WORD).astype(jnp.uint32)
    rolled = jnp.roll(hvp, ws, axis=-1)
    prev = jnp.roll(rolled, 1, axis=-1)
    # (WORD - bs) % WORD keeps the shift amount in [0, 31] even at bs == 0
    # (a >> 32 is undefined); the where() discards the bogus bs == 0 lane.
    carry = jnp.where(bs == 0, jnp.uint32(0), prev >> ((WORD - bs) % WORD))
    return ((rolled << bs) | carry).astype(jnp.uint32)


def permute_batch_packed(hvps: jax.Array, shifts: jax.Array) -> jax.Array:
    """Per-row cyclic shifts on packed rows: hvps [M, W], shifts [M] -> [M, W]."""
    return jax.vmap(permute_packed)(hvps, shifts)


def _bitsliced_counts(hvs: jax.Array) -> list[jax.Array]:
    """Bit-planes (LSB first) of the per-bit-lane popcount over axis 0.

    hvs: [M, ..., W] uint32. A carry-save ripple adder: each transmitter's word
    is added into a bit-sliced binary counter, so counting M inputs costs
    O(M log M) word ops with no unpacking — every one of the 32 lanes of a word
    is counted in parallel.
    """
    planes: list[jax.Array] = []
    for k in range(hvs.shape[0]):
        carry = hvs[k]
        for i in range(len(planes)):
            planes[i], carry = planes[i] ^ carry, planes[i] & carry
        if len(planes) < (k + 1).bit_length():  # else carry is provably 0
            planes.append(carry)
    return planes


def _bitsliced_gt(planes: list[jax.Array], t: int) -> tuple[jax.Array, jax.Array]:
    """(count > t, count == t) per bit lane, from LSB-first count planes."""
    gt = jnp.zeros_like(planes[0])
    eq = jnp.full_like(planes[0], _FULL)
    for i in reversed(range(len(planes))):
        tb = _FULL if (t >> i) & 1 else jnp.uint32(0)
        gt = gt | (eq & planes[i] & ~tb)
        eq = eq & ~(planes[i] ^ tb)
    return gt, eq


def majority_packed(hvs: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """Packed majority bundling over axis 0: [M, ..., W] uint32 -> [..., W].

    Bit-sliced carry-save adder + bitwise comparator — no unpacking. Same tie
    convention as `majority` (even-M ties -> 0; `key` opts into the randomized
    tie-break, bit-exact against `majority(key=...)` on the same stream).
    """
    m = hvs.shape[0]
    planes = _bitsliced_counts(hvs)
    gt, eq = _bitsliced_gt(planes, m // 2)
    if m % 2 == 1 or key is None:
        return gt
    d = hvs.shape[-1] * WORD
    tie = pack(jax.random.bernoulli(key, 0.5, hvs.shape[1:-1] + (d,)).astype(jnp.uint8))
    return gt | (eq & tie)


def _bitsliced_gt_traced(planes: list[jax.Array], t: jax.Array) -> jax.Array:
    """(count > t) per bit lane for a TRACED uint32 threshold `t`.

    The comparator of `_bitsliced_gt` with the threshold bits materialized as
    0/all-ones lane masks (the `bernoulli_words` trick), so the threshold may
    depend on traced data — e.g. the live member count of a masked majority.
    `t` must broadcast against the planes and satisfy t < 2^len(planes).
    """
    gt = jnp.zeros_like(planes[0])
    eq = jnp.full_like(planes[0], _FULL)
    for i in reversed(range(len(planes))):
        tb = jnp.uint32(0) - ((t >> jnp.uint32(i)) & jnp.uint32(1))  # 0 or all-ones
        gt = gt | (eq & planes[i] & ~tb)
        eq = eq & ~(planes[i] ^ tb)
    return gt


def majority_packed_masked(hvs: jax.Array, mask: jax.Array) -> jax.Array:
    """Strict packed majority over the MASKED subset of axis 0.

    hvs: [M, ..., W] uint32; mask: [M] (or any prefix of hvs' leading dims)
    bool -> [..., W]. A masked-out member contributes exact zero words to the
    carry-save counter, and the strict-majority threshold compares against the
    *traced* live count n = sum(mask): ``count*2 > n  <=>  count > n//2`` for
    either parity, so even-n ties resolve to 0 exactly like `majority_packed`.
    An empty selection returns all-zero words. jit-safe for traced masks — the
    multi-centroid k-means update recomputes every centroid from its current
    assignment without a recompile per iteration.
    """
    m = hvs.shape[0]
    assert m >= 1 and mask.shape[0] == m, (hvs.shape, mask.shape)
    mask = jnp.broadcast_to(
        mask.reshape(mask.shape + (1,) * (hvs.ndim - mask.ndim)),
        hvs.shape[:-1] + (1,),
    )
    mw = jnp.uint32(0) - mask.astype(jnp.uint32)  # 0 or all-ones per member
    planes = _bitsliced_counts(hvs & mw)
    n = jnp.sum(mask.astype(jnp.int32), axis=0)   # [..., 1] live count
    # n//2 <= M//2 < 2^len(planes) == 2^bit_length(M): threshold always fits
    return _bitsliced_gt_traced(planes, (n // 2).astype(jnp.uint32))


def bernoulli_words(
    key: jax.Array, p: jax.Array | float, shape: tuple[int, ...], precision: int = 16
) -> jax.Array:
    """Bernoulli(p) bit masks drawn directly as packed uint32 words.

    Draws `precision` fair bit-planes and compares the per-lane `precision`-bit
    uniform against round(p * 2^precision) with a bit-sliced comparator: the
    whole mask costs `precision` random bits per output bit instead of the 32
    the unpacked bernoulli (uint32 -> f32 uniform -> compare) pays, and never
    materializes an unpacked intermediate. p is quantized to 2^-precision —
    the packed serve path's "bitplane" noise mode (NOT bit-exact against
    `flip_bits`; use `flip_bits_packed` when identity matters).
    """
    planes = jax.random.bits(key, (precision,) + tuple(shape), dtype=jnp.uint32)
    t = jnp.clip(
        jnp.round(jnp.asarray(p, jnp.float32) * (2**precision)), 0, 2**precision - 1
    ).astype(jnp.uint32)
    lt = jnp.zeros(shape, jnp.uint32)
    eq = jnp.full(shape, _FULL, jnp.uint32)
    for i in reversed(range(precision)):
        tb = jnp.uint32(0) - ((t >> jnp.uint32(i)) & jnp.uint32(1))  # 0 or all-ones
        lt = lt | (eq & ~planes[i] & tb)
        eq = eq & ~(planes[i] ^ tb)
    return lt


def flip_bits_packed(key: jax.Array, hvp: jax.Array, ber: jax.Array | float) -> jax.Array:
    """Packed BSC, bit-exact against `flip_bits` on the same key.

    The Bernoulli mask is generated per 32-lane block in the unpacked layout
    (the same draw `flip_bits` makes) and packed before the XOR, so
    unpack(flip_bits_packed(k, pack(x), p)) == flip_bits(k, x, p) exactly.
    """
    d = hvp.shape[-1] * WORD
    flips = jax.random.bernoulli(key, ber, hvp.shape[:-1] + (d,))
    return jnp.bitwise_xor(hvp, pack(flips.astype(jnp.uint8)))


def flip_bits_per_rx_packed(
    key: jax.Array, hvp: jax.Array, ber_per_rx: jax.Array
) -> jax.Array:
    """Per-receiver packed BSC: hvp [..., W] x ber [N] -> [N, ..., W].

    Bit-exact against `flip_bits_per_rx` on the same key (same mask draw,
    packed before the XOR).
    """
    n = ber_per_rx.shape[0]
    d = hvp.shape[-1] * WORD
    p = ber_per_rx.reshape((n,) + (1,) * hvp.ndim)
    flips = jax.random.bernoulli(key, p, (n,) + hvp.shape[:-1] + (d,))
    return jnp.bitwise_xor(hvp[None], pack(flips.astype(jnp.uint8)))
