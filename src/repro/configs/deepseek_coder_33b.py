"""DeepSeek-Coder 33B [arXiv:2401.14196] — llama-arch dense GQA.

62L d_model=7168 56H (GQA kv=8, head_dim 128) d_ff=19200 vocab=32256.
Sharding: 56 heads don't divide 16 -> FFN-TP (19200/16) + FSDP attention
(embed dim over "data").
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    rope_theta=100_000.0,
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=7, n_kv_heads=1, d_ff=384,
        vocab=512, loss_chunk=64, remat=False,
    )
