"""Training launcher.

Single-host usage (CPU CI / smoke):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 50 --batch 8 --seq 256

Multi-host production notes (TPU pods; simulated single-process here):
* Each host runs this entrypoint under a supervisor (GKE/Borg restart policy).
  jax.distributed.initialize() wires hosts; the mesh comes from
  launch.mesh.make_production_mesh(multi_pod=...).
* **Fault tolerance**: checkpoints are atomic + keep-k (checkpoint/ckpt.py);
  on restart every host calls latest_step() and resumes; the data pipeline
  skips ahead in O(1) (data/pipeline.py — batch is a pure function of step).
  A lost host therefore costs at most `ckpt_every` steps of recompute.
* **Elasticity**: restore re-shards against whatever mesh the restarted job
  has (checkpoint stores dtypes/shapes; placement uses the rules engine), so
  the job can come back on fewer/more pods.
* **Straggler mitigation**: the supervisor enforces a per-step deadline
  (expected step time × 3); a host that misses it is killed and restarted —
  with synchronous SPMD collectives this is detected at the NCCL/ICI timeout.
  The sign_majority mode additionally shrinks the DP payload 32×, which bounds
  the collective window in which a straggler can stall the step.
* **Gradient compression**: --opt sign_majority enables the paper's OTA
  majority collective on gradients (optionally --ota-ber to inject the
  measured wireless error rate; see DESIGN.md).
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "sign_majority"])
    ap.add_argument("--ota-ber", type=float, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    from repro import compat, configs
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models import get_model
    from repro.train.loop import Trainer, TrainerConfig, build_train_fns
    from repro.train.optimizer import OptConfig

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = get_model(cfg)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} devices={len(jax.devices())} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt = OptConfig(kind=args.opt, lr=args.lr, warmup=10, total_steps=args.steps)
    fns = build_train_fns(model, mesh, opt, microbatch=args.microbatch, ota_ber=args.ota_ber)
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch))
    trainer = Trainer(
        fns, pipe,
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir),
        mesh,
    )
    with compat.set_mesh(mesh):
        _, _, losses = trainer.run(jax.random.PRNGKey(0))
    if losses:
        print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    else:
        print(f"nothing to do: checkpoint already at step {args.steps}")


if __name__ == "__main__":
    main()
