"""Fault-tolerant checkpointing: atomic, keep-k, elastic re-shard on restore.

Layout: <dir>/step_<k>/  — one .npy per pytree leaf (path-flattened names) plus a
manifest.json holding the treedef, shapes, dtypes and the data-pipeline state.
Writes go to <dir>/.tmp_step_<k> and are os.replace'd into place, so a killed
writer never leaves a half-checkpoint that restore would pick up (restart
safety). `keep` prunes old steps after a successful commit.

Elastic restore: leaves are loaded host-side and re-placed with `jax.device_put`
against the *current* mesh's NamedShardings (computed from the same logical-axes
tree by the rules engine) — a checkpoint written on any mesh restores onto any
other mesh, including a different device count.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

from repro.compat import tree_flatten_with_path


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save_checkpoint(directory: str, step: int, tree: Any, extra: dict | None = None, keep: int = 3) -> str:
    leaves, paths, _ = _flatten(tree)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for leaf, path in zip(leaves, paths):
        arr = np.asarray(jax.device_get(leaf))
        fname = path.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"path": path, "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, shardings: Any | None = None):
    """Restore into the structure of `like` (a pytree of arrays/ShapeDtypeStructs).

    `shardings`: optional matching pytree of NamedShardings for elastic placement
    on the current mesh; None -> plain host arrays.
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    _, paths, treedef = _flatten(like)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(paths)
    )
    loaded = []
    for p, sh in zip(paths, shard_leaves):
        arr = np.load(os.path.join(path, by_path[p]["file"]))
        loaded.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, loaded), manifest["extra"]
