"""Distributed scale-out of IMC-based HDC similarity search (paper Fig. 3b).

Mapping of the paper's architecture onto the production TPU mesh:

* **encoders (TXs)** — the ``model`` mesh axis carries the encoder slots; encoder
  *g* lives co-located with model column ``g // e_per`` (``e_per = ceil(m_tx /
  model_size)`` encoders per column, so any M up to the paper's 11 TXs fits any
  mesh). Unoccupied slots abstain (vote 0).
* **OTA majority bundling** — one ``psum`` of int8 bipolar votes over the ``model``
  axis (`distributed.collectives.majority_allreduce`): the all-to-one reduction and
  one-to-all broadcast collapse into a single collective, exactly the paper's
  over-the-air computation. Payload is 1 byte/element (conceptually 1 bit);
  ``collective="psum_packed"`` shrinks it further with guard-bit field packing
  (`collectives.packed_vote_allreduce` — several votes per uint32 lane, ONE
  uint32 psum, bit-identical tally).
* **N IMC cores (RXs)** — the associative memory (C prototype hypervectors) is
  sharded over ``model``; each shard subdivides its classes among
  ``cores_per_shard`` IMC cores, and *each core decodes its own noisy copy* of the
  bundled query through the pluggable PHY tier (``repro.phy``): ``bsc`` flips at
  the pre-characterized BER of the EM + constellation pipeline (``core.em`` /
  ``core.ota`` — the paper's Eq. 1 abstraction, the default), ``symbol`` runs the
  actual constellation + AWGN + decision-region physics in-graph, ``ideal`` is
  error-free — "each RX receives a slightly different version of Q". The
  precharacterization travels as a ``phy.ChannelState`` pytree sharded with the
  cores.
* **similarity search** — local bipolar dot products (the IMC crossbar MVM;
  Pallas ``assoc_matmul`` on TPU) + a tiny all-gather of per-shard (value, index)
  pairs for the global top-1.
* trials are batched over the ``data`` (and ``pod``) axes.

``make_wired_serve`` implements the *wired-baseline* dataflow the paper argues
against: queries are all-gathered to every core (the NoC broadcast), then bundled
locally — same math, M·(model_size)× the collective bytes. The roofline benchmark
contrasts the two HLOs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat, phy
from repro.core import em, hypervector as hv, ota
from repro.distributed import collectives
from repro.kernels.assoc_matmul import assoc_matmul
from repro.kernels.hamming import hamming_search, hamming_topk_banked
from repro.kernels.majority import majority_bundle


@dataclasses.dataclass(frozen=True)
class ScaleOutConfig:
    n_classes: int = 6400        # total classes across all IMC cores
    dim: int = 512               # hypervector dimensionality
    m_tx: int = 3                # simultaneous transmitters (<= model mesh size)
    n_rx_cores: int = 64         # physical IMC cores (multiple of model mesh size)
    snr_db: float = 7.0          # OTA operating point (see ota.default_n0)
    permuted: bool = False       # permuted bundling (per-TX cyclic signature)
    use_kernels: bool = True     # Pallas fast path (interpret on CPU)
    batch: int = 256             # global trial batch
    collective: str = "psum"     # OTA realization: "psum" (paper-faithful single
    #   fused collective, int8 all-reduce) | "psum_packed" (same single
    #   all-reduce with guard-bit field packing: votes biased non-negative,
    #   k = 32 // ceil(log2(2*S*e_per + 1)) per uint32 lane, ONE uint32 psum —
    #   bit-identical tally, ~2x less wire traffic at M=3 on a 4-wide model
    #   axis) | "rs_ag" (beyond-paper: reduce-scatter the votes (guard-bit
    #   packed when d tiles into lanes), threshold the local d/S shard,
    #   bit-pack to uint8, all-gather d/8 bytes; see EXPERIMENTS.md §Perf)
    representation: str = "unpacked"  # HV storage on the serve path: "unpacked"
    #   (uint8 {0,1}, fp32 bipolar MXU similarity) | "packed" (uint32 words,
    #   XOR+popcount similarity — how the IMC macro actually stores a row; d/8
    #   bytes per HV, prediction-identical to unpacked on the same RNG stream)
    noise: str = "exact"         # packed-path BSC mask source: "exact" (pack the
    #   same Bernoulli draw as the unpacked path — bit-identical, used for the
    #   parity tests) | "bitplane" (draw uint32 mask words directly via a
    #   bit-sliced comparator — `noise_planes` random bits per mask bit instead
    #   of the 32 the unpacked Bernoulli pays). Unpacked representation always
    #   draws the plain Bernoulli mask.
    noise_planes: int = 16       # bitplane-mode mask precision: BER quantized to
    #   2^-planes. 8 is plenty for the paper's operating points (BER 1e-2..1e-1
    #   against an accuracy curve that is flat out to BER 0.26, Fig. 10) and
    #   halves the mask-generation traffic again; 16 is the conservative default.
    channel: str = "bsc"         # PHY fidelity tier (repro.phy): "ideal" (error-
    #   free link) | "bsc" (default: per-core BSC at the precharacterized Eq. 1
    #   BER — the paper's abstraction, bit-identical to the historical serve
    #   noise on the same RNG stream) | "symbol" (full physics in-graph: ONE
    #   int32 psum of the per-dimension TX bit-combo == the constellation
    #   superposition, then per-core AWGN + decision-region decode; requires a
    #   real ChannelState from `precharacterize_state` and collective="psum")

    @property
    def packed(self) -> bool:
        return self.representation == "packed"

    @property
    def words(self) -> int:
        assert self.dim % hv.WORD == 0, (self.dim, hv.WORD)
        return self.dim // hv.WORD


def precharacterize_state(
    cfg: ScaleOutConfig, geom: em.PackageGeometry | None = None
) -> phy.ChannelState:
    """Full channel precharacterization -> `phy.ChannelState` pytree.

    This is the paper's offline CST + MATLAB step: deterministic given the
    package geometry ("quasi-static and known a priori"). The returned state
    carries everything every PHY tier needs — Eq. 1 per-RX BER + validity for
    ``bsc``, the channel matrix / phase assignment / constellation / decision
    centroids / N0 for ``symbol``.
    """
    geom = geom or em.PackageGeometry()
    h = em.channel_matrix(geom, cfg.m_tx, cfg.n_rx_cores)
    n0 = ota.default_n0(h, cfg.snr_db)
    if cfg.m_tx <= 3:
        res = ota.optimize_phases_exhaustive(h, n0)
    else:
        res = ota.optimize_phases_coordinate(h, n0, jax.random.PRNGKey(0))
    return phy.state_from_ota(res, h)


def precharacterize(cfg: ScaleOutConfig) -> jnp.ndarray:
    """Per-IMC-core BER [n_rx_cores] — the Eq. 1 summary of
    `precharacterize_state` (kept for BER-only consumers; the serve steps take
    the full ChannelState)."""
    return precharacterize_state(cfg).ber


# ---------------------------------------------------------------------------
# mesh-level serve steps
# ---------------------------------------------------------------------------

def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _local_search(q: jax.Array, protos: jax.Array, use_kernels: bool) -> jax.Array:
    """Bipolar similarity dots [B_l, C_l] — the IMC crossbar MVM."""
    return assoc_matmul(q, protos, use_kernel=use_kernels, bm=8)


def make_ota_serve(
    mesh: Mesh, cfg: ScaleOutConfig
) -> Callable[[jax.Array, jax.Array, phy.ChannelState, jax.Array], tuple[jax.Array, jax.Array]]:
    """Build the jitted OTA serve step.

    fn(protos [C, dim] u8, queries [B, S_tx, e_per, dim] u8,
       state phy.ChannelState, key)
      -> (pred, maxsim); pred [B] int32 (baseline) or [B, m_tx] (permuted).
    S_tx = model mesh size; e_per = ceil(m_tx / S_tx) encoders per column; global
    encoder g = column * e_per + j; slots with g >= cfg.m_tx abstain.

    The OTA link itself is the pluggable PHY tier ``cfg.channel``
    (`repro.phy`): ``bsc`` (default) keeps the historical dataflow — vote
    tally over the model axis (psum / guard-bit psum_packed / rs_ag), then a
    per-core BSC at ``state.ber`` — bit-identical to pre-phy serves on the
    same RNG stream; ``ideal`` skips the noise; ``symbol`` replaces the
    psum+BSC pair with the physical channel: ONE int32 psum of the
    per-dimension TX bit-combo (== the constellation superposition, see
    `phy.channel`), then per-core constellation lookup + AWGN +
    decision-region decode from the same ChannelState the analytic BER came
    from. ``state`` is sharded with the cores (`phy.state_spec`).

    With ``cfg.representation == "packed"`` protos/queries are uint32 word arrays
    ([C, dim/32] / [B, S_tx, e_per, dim/32], see `hv.pack`); the bundled query,
    the per-core channel noise, the prototype shards and the local search all
    stay packed (the symbol tier decodes bits, then packs): the top-1 is the
    fused `hamming_topk_banked` Pallas kernel — one launch over all cores (and
    permuted banks) that reduces the class axis in VMEM, so the [G, B, C]
    distance tensor never reaches HBM. The vote tally itself shrinks with
    ``cfg.collective == "psum_packed"`` (guard-bit field packing sized by the
    cfg.m_tx ACTIVE voters, ONE uint32 psum, bit-identical to the int8 psum).
    Predictions and maxsim are bit-identical to the unpacked path on the same
    RNG stream (cfg.noise="exact") across all collective modes.
    """
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    assert cfg.n_rx_cores % model_size == 0, (cfg.n_rx_cores, model_size)
    cores_per_shard = cfg.n_rx_cores // model_size
    e_per = -(-cfg.m_tx // model_size)
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    packed = cfg.packed
    chan = phy.get_channel(cfg.channel)
    if chan.wire == "combo":
        if cfg.collective != "psum":
            raise ValueError(
                f"channel={cfg.channel!r} replaces the vote reduction with the "
                f"combo-index psum; collective={cfg.collective!r} does not "
                "apply (use collective='psum')"
            )
        assert cfg.m_tx <= 16, (cfg.m_tx, "constellation table is [N, 2^M]")

    def body(protos, queries, state, key):
        # protos: [C_l, d|W]; queries: [B_l, 1, e_per, d|W];
        # state: local ChannelState shard (RX-leading leaves [cores_per_shard])
        c_l = protos.shape[0]
        d = cfg.dim
        b_l = queries.shape[0]
        tx = jax.lax.axis_index("model")
        dpos = jax.lax.axis_index(dp[0]) if len(dp) == 1 else (
            jax.lax.axis_index(dp[0]) * mesh.axis_sizes[mesh.axis_names.index(dp[1])]
            + jax.lax.axis_index(dp[1])
        )
        q_mine = queries[:, 0]                      # [B_l, e_per, d|W]
        gids = tx * e_per + jnp.arange(e_per)       # global encoder ids
        if cfg.permuted:  # TX g transmits rho^g(q_g) — its signature
            rho = hv.permute_packed if packed else hv.permute
            q_mine = jax.vmap(lambda q, g: rho(q, g), in_axes=(1, 0), out_axes=1)(
                q_mine, gids
            )
        active = (gids < cfg.m_tx)[None, :, None]
        # this column's live-voter count (slot-aware guard bits + combo weights)
        n_act_local = jnp.clip(cfg.m_tx - tx * e_per, 0, e_per)
        # --- the OTA collective over the encoder/model axis ---
        q_bits = hv.unpack(q_mine, d) if packed else q_mine
        if chan.wire == "combo":
            # physical superposition: the summed combo index IS the received
            # field (phy.channel module docstring) — ONE psum, the same
            # single-collective shape as the paper's OTA reduction. Columns
            # contribute disjoint bit ranges, so the sum stays < 2^M and the
            # wire dtype is the smallest int that fits it: at the paper's
            # M <= 7 the combo psum costs the SAME bytes as the int8 votes.
            weights = jnp.where(
                gids < cfg.m_tx, jnp.int32(1) << jnp.minimum(gids, 30), 0
            )
            partial = jnp.sum(
                q_bits.astype(jnp.int32) * weights[None, :, None], axis=1
            )
            cdt = (jnp.int8 if cfg.m_tx <= 7
                   else jnp.int16 if cfg.m_tx <= 15 else jnp.int32)
            q_bundled = jax.lax.psum(partial.astype(cdt), "model").astype(
                jnp.int32)  # [B_l, d] combo index
        else:
            # bipolar majority votes; abstaining slots (g >= m_tx) vote exact 0
            votes = jnp.sum(
                jnp.where(active, 2 * q_bits.astype(jnp.int8) - 1, 0), axis=1
            ).astype(jnp.int8)
            if cfg.collective in ("psum", "psum_packed"):
                if cfg.collective == "psum":  # paper-faithful: ONE all-reduce
                    tally = jax.lax.psum(votes, "model")
                else:  # guard-bit packed votes sized by the M live voters:
                    # ONE uint32 psum, bit-identical tally
                    tally = collectives.packed_vote_allreduce(
                        votes, "model", group_size=model_size, e_per=e_per,
                        n_active=cfg.m_tx, local_active=n_act_local,
                    )
                bundled_bits = (tally > 0).astype(jnp.uint8)  # even-M ties -> 0
                q_bundled = hv.pack(bundled_bits) if packed else bundled_bits
            elif cfg.collective == "rs_ag":
                # reduce-scatter the votes (guard-bit packed lanes when d tiles
                # evenly — each core tallies a d/S shard), threshold locally,
                # bit-pack, all-gather d/8 packed bytes.
                if packed:
                    # the gathered uint32 words ARE the bundled packed query —
                    # no unpack/repack round-trip after the collective.
                    assert d % (model_size * hv.WORD) == 0, (d, model_size)
                    part = collectives.packed_vote_psum_scatter(
                        votes, "model", group_size=model_size, e_per=e_per,
                        n_active=cfg.m_tx, local_active=n_act_local,
                    )
                    words = hv.pack((part > 0).astype(jnp.uint8))  # [B_l, W/S]
                    q_bundled = jax.lax.all_gather(words, "model", axis=1, tiled=True)
                else:
                    assert d % (model_size * 8) == 0, (d, model_size)
                    part = collectives.packed_vote_psum_scatter(
                        votes, "model", group_size=model_size, e_per=e_per,
                        n_active=cfg.m_tx, local_active=n_act_local,
                    )
                    bits = (part > 0).astype(jnp.uint8)          # [B_l, d/S]
                    w = bits.reshape(b_l, -1, 8)
                    packed8 = jnp.sum(w << jnp.arange(8, dtype=jnp.uint8), axis=-1).astype(jnp.uint8)
                    allbytes = jax.lax.all_gather(packed8, "model", axis=1, tiled=True)
                    q_bundled = (
                        (allbytes[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
                    ).reshape(b_l, d).astype(jnp.uint8)
            else:
                raise ValueError(cfg.collective)
        # --- per-core decode through the PHY tier ---
        kq = jax.random.fold_in(key, dpos)
        q_rx = chan.rx_copies(
            kq, q_bundled, state, rx_base=tx * cores_per_shard,
            n_cores=cores_per_shard, packed=packed, dim=d, noise=cfg.noise,
            planes=cfg.noise_planes,
        )
        # [n_core, B_l, d|W] -> each core searches its class sub-shard
        assert c_l % cores_per_shard == 0
        c_core = c_l // cores_per_shard
        protos_c = protos.reshape(cores_per_shard, c_core, protos.shape[-1])

        if cfg.permuted:
            # expand each core's memory with the M permuted banks (paper Sec. IV)
            if packed:
                # fused top-1 over all (core, bank) pairs: the grid reduces the
                # class axis in VMEM (and spans the M bank axis too) — the
                # [G, B_l, c_core] distances never reach HBM; the in-memory
                # argmax of the IMC macro. argmin == first-max of sims exactly.
                banks = jnp.stack(
                    [hv.permute_packed(protos_c, m) for m in range(cfg.m_tx)], 1
                )  # [n_core, M, c_core, W]
                g = cores_per_shard * cfg.m_tx
                q_rep = jnp.broadcast_to(
                    q_rx[:, None], (cores_per_shard, cfg.m_tx) + q_rx.shape[1:]
                ).reshape(g, b_l, -1)
                dmin, amin = hamming_topk_banked(
                    q_rep, banks.reshape(g, c_core, -1), use_kernel=cfg.use_kernels
                )  # each [g, B_l]
                dmin = jnp.moveaxis(
                    dmin.reshape(cores_per_shard, cfg.m_tx, b_l), 2, 0
                )  # [B_l, n_core, M]
                amin = jnp.moveaxis(
                    amin.reshape(cores_per_shard, cfg.m_tx, b_l), 2, 0
                )
                val = d - 2 * jnp.min(dmin, 1)                # [B_l, M]
                core_star = jnp.argmin(dmin, 1)               # [B_l, M]
                idx_in_core = jnp.take_along_axis(amin, core_star[:, None, :], 1)[:, 0, :]
            else:
                banks = jnp.stack([hv.permute(protos_c, m) for m in range(cfg.m_tx)], 1)
                # banks: [n_core, M, c_core, d]
                sims = jax.vmap(
                    lambda qc, pc: jax.vmap(
                        lambda bank: _local_search(qc, bank, cfg.use_kernels)
                    )(pc)
                )(q_rx, banks)  # [n_core, M, B_l, c_core]
                sims = jnp.moveaxis(sims, 2, 0)  # [B_l, n_core, M, c_core]
                val_c = jnp.max(sims, -1)
                idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
                val = jnp.max(val_c, 1)                       # [B_l, M]
                core_star = jnp.argmax(val_c, 1)              # [B_l, M]
                idx_in_core = jnp.take_along_axis(idx_c, core_star[:, None, :], 1)[:, 0, :]
            idx = (tx * c_l + core_star * c_core + idx_in_core).astype(jnp.int32)
        else:
            if packed:
                dmin, amin = hamming_topk_banked(
                    q_rx, protos_c, use_kernel=cfg.use_kernels
                )  # each [n_core, B_l] — distances reduced in VMEM, not HBM
                dmin = jnp.moveaxis(dmin, 1, 0)               # [B_l, n_core]
                amin = jnp.moveaxis(amin, 1, 0)
                val = d - 2 * jnp.min(dmin, -1)               # [B_l]
                core_star = jnp.argmin(dmin, -1)
                idx_in_core = jnp.take_along_axis(amin, core_star[:, None], 1)[:, 0]
            else:
                sims = jax.vmap(
                    lambda qc, pc: _local_search(qc, pc, cfg.use_kernels)
                )(q_rx, protos_c)  # [n_core, B_l, c_core]
                sims = jnp.moveaxis(sims, 1, 0)  # [B_l, n_core, c_core]
                val_c = jnp.max(sims, -1)
                idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
                val = jnp.max(val_c, -1)                      # [B_l]
                core_star = jnp.argmax(val_c, -1)
                idx_in_core = jnp.take_along_axis(idx_c, core_star[:, None], 1)[:, 0]
            idx = (tx * c_l + core_star * c_core + idx_in_core).astype(jnp.int32)

        # --- global top-1: tiny (value, index) all-gather over the cores ---
        vals = jax.lax.all_gather(val, "model")           # [S_tx, ...]
        idxs = jax.lax.all_gather(idx, "model")
        shard_star = jnp.argmax(vals, 0)
        pred = jnp.take_along_axis(idxs, shard_star[None], 0)[0]
        maxsim = jnp.max(vals, 0) / (2.0 * cfg.dim) + 0.5  # normalize to [0,1]
        return pred, maxsim

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P("model", None),                 # prototype shards (the IMC cores)
            P(dp_spec, "model", None, None),  # per-encoder queries
            phy.state_spec("model"),          # per-core channel state
            P(),                              # key
        ),
        out_specs=(P(dp_spec), P(dp_spec)),
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


def make_wired_serve(
    mesh: Mesh, cfg: ScaleOutConfig
) -> Callable[[jax.Array, jax.Array, phy.ChannelState, jax.Array], tuple[jax.Array, jax.Array]]:
    """Wired-baseline dataflow: queries all-gathered over the NoC, bundled at every
    core (broadcast M·d bytes/trial instead of the OTA psum). Error-free wires —
    the ChannelState rides along for signature parity with `make_ota_serve`
    (matched-physics wired-vs-OTA comparisons thread the same state through
    both) but no PHY noise applies on the NoC.
    Same outputs as `make_ota_serve` (baseline bundling only). Packed
    representation: the NoC broadcast moves d/8 bytes per HV, bundling runs the
    bit-sliced carry-save majority, similarity is XOR+popcount."""
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    cores_per_shard = cfg.n_rx_cores // model_size
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    packed = cfg.packed

    e_per = -(-cfg.m_tx // model_size)

    def body(protos, queries, state, key):
        c_l = protos.shape[0]
        d = cfg.dim
        last = queries.shape[-1]
        tx = jax.lax.axis_index("model")
        # --- wired pattern: explicit all-gather (the NoC broadcast bottleneck) ---
        q_all = jax.lax.all_gather(queries[:, 0], "model", axis=0)  # [S_tx, B_l, e, d|W]
        q_act = jnp.moveaxis(q_all, 2, 1).reshape(-1, q_all.shape[1], last)[: cfg.m_tx]
        if packed:
            q_bundled = hv.majority_packed(q_act)
            sims = d - 2 * hamming_search(q_bundled, protos, use_kernel=cfg.use_kernels)
        else:
            q_bundled = majority_bundle(q_act, use_kernel=cfg.use_kernels)
            sims = _local_search(q_bundled, protos, cfg.use_kernels)  # [B_l, C_l]
        val = jnp.max(sims, -1)
        idx = (jnp.argmax(sims, -1) + tx * c_l).astype(jnp.int32)
        vals = jax.lax.all_gather(val, "model")
        idxs = jax.lax.all_gather(idx, "model")
        shard_star = jnp.argmax(vals, 0)
        pred = jnp.take_along_axis(idxs, shard_star[None], 0)[0]
        maxsim = jnp.max(vals, 0) / (2.0 * cfg.dim) + 0.5
        return pred, maxsim

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P("model", None), P(dp_spec, "model", None, None),
                  phy.state_spec("model"), P()),
        out_specs=(P(dp_spec), P(dp_spec)),
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


def make_hdc_train(
    mesh: Mesh, cfg: ScaleOutConfig
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """One-shot HDC 'training': bundle every class's examples into its prototype.

    fn(examples [B, dim] u8, labels [B] i32) -> protos [C, dim] u8 (sharded over
    model). Bipolar per-class sums are psum'd over the data axes (the learning
    analogue of the OTA reduction), then thresholded — majority bundling of all
    examples of a class. Packed representation: examples/protos are uint32 word
    arrays [.., dim/32]; the per-bit tally unpacks transiently, the learned
    prototype shards are stored packed (what the IMC macro would write).
    """
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    assert cfg.n_classes % model_size == 0
    c_l = cfg.n_classes // model_size
    packed = cfg.packed

    def body(examples, labels):
        tx = jax.lax.axis_index("model")
        lo = tx * c_l
        onehot = (labels[:, None] == (lo + jnp.arange(c_l))[None, :]).astype(jnp.int32)
        ex = hv.unpack(examples, cfg.dim) if packed else examples
        bipolar = 2 * ex.astype(jnp.int32) - 1              # [B_l, d]
        sums = jnp.einsum("bc,bd->cd", onehot, bipolar)     # [C_l, d]
        for ax in dp:
            sums = jax.lax.psum(sums, ax)
        protos = (sums > 0).astype(jnp.uint8)
        return hv.pack(protos) if packed else protos

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp_spec, None), P(dp_spec)),
        out_specs=P("model", None),
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-level helpers (inputs + single-device oracle)
# ---------------------------------------------------------------------------

def make_queries(
    key: jax.Array, cfg: ScaleOutConfig, protos: jax.Array, model_size: int
) -> tuple[jax.Array, jax.Array]:
    """Random trial queries: classes [B, m_tx], queries [B, S_tx, e_per, dim].

    `protos` is the unpacked [C, dim] codebook; with a packed cfg the returned
    queries are bit-packed to [B, S_tx, e_per, dim/32] uint32 (pack the protos
    with `hv.pack` before feeding the packed serve fn).
    """
    k1 = jax.random.fold_in(key, 1)
    e_per = -(-cfg.m_tx // model_size)
    classes = jax.random.randint(k1, (cfg.batch, cfg.m_tx), 0, cfg.n_classes)
    q = protos[classes]  # [B, M, d]
    pad = jnp.zeros((cfg.batch, model_size * e_per - cfg.m_tx, cfg.dim), jnp.uint8)
    q = jnp.concatenate([q, pad], axis=1)
    q = q.reshape(cfg.batch, model_size, e_per, cfg.dim)
    return classes, (hv.pack(q) if cfg.packed else q)


def serve_reference(
    cfg: ScaleOutConfig, protos: jax.Array, queries: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single-device noise-free oracle for the distributed serve step.

    Always computes in the unpacked representation; packed (uint32) protos or
    queries are unpacked first, so the same oracle serves both dataflows.
    """
    if queries.dtype == jnp.uint32:
        queries = hv.unpack(queries, cfg.dim)
    if protos.dtype == jnp.uint32:
        protos = hv.unpack(protos, cfg.dim)
    b = queries.shape[0]
    q_act = queries.reshape(b, -1, cfg.dim)[:, : cfg.m_tx, :]
    if cfg.permuted:
        shifts = jnp.arange(cfg.m_tx)
        q_act = jax.vmap(lambda qs: hv.permute_batch(qs, shifts))(q_act)
        q = jnp.moveaxis(q_act, 1, 0)
        counts = jnp.sum(q.astype(jnp.int32), 0)
        bundled = (counts * 2 > cfg.m_tx).astype(jnp.uint8)
        banks = jnp.stack([hv.permute(protos, m) for m in range(cfg.m_tx)], 0)
        sims = jnp.einsum(
            "bd,mcd->bmc",
            2.0 * bundled.astype(jnp.float32) - 1,
            2.0 * banks.astype(jnp.float32) - 1,
        )
        pred = jnp.argmax(sims, -1).astype(jnp.int32)
        maxsim = jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5
        return pred, maxsim
    q = jnp.moveaxis(q_act, 1, 0)
    counts = jnp.sum(q.astype(jnp.int32), 0)
    bundled = (counts * 2 > cfg.m_tx).astype(jnp.uint8)
    sims = jnp.einsum(
        "bd,cd->bc",
        2.0 * bundled.astype(jnp.float32) - 1,
        2.0 * protos.astype(jnp.float32) - 1,
    )
    pred = jnp.argmax(sims, -1).astype(jnp.int32)
    maxsim = jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5
    return pred, maxsim
