"""Fault-injection subsystem: hard faults the PHY re-fit can't recover.

See `repro.faults.model` for the `FaultState` pytree (memory / node / wire
fault surfaces), the evolution-law registry (`FAULTS`, mirroring
`phy.PROCESSES`), the host-side failover planner, and the combo-wire erasure
helpers. `core.scaleout.make_ota_serve` / `make_mt_ota_serve` thread the
state through the serve step when built with a ``faults=`` model; the
serving layer's `FaultController` promotes persistently-dead rows from PHY
quarantine to a failover remap at the step barrier.
"""
from repro.faults.model import (
    FAULTS,
    FaultModel,
    FaultState,
    StaticFaults,
    TransientVoteFaults,
    WearoutFaults,
    fstate_shape_structs,
    fstate_spec,
    get_fault_model,
    healthy_for,
    healthy_state,
    inject,
    live_combo_mask,
    live_majority_labels,
    plan_failover,
    recenter_state,
    register_fault_model,
    sample_stuck_cells,
    sample_word_dropout,
)

__all__ = [
    "FAULTS",
    "FaultModel",
    "FaultState",
    "StaticFaults",
    "TransientVoteFaults",
    "WearoutFaults",
    "fstate_shape_structs",
    "fstate_spec",
    "get_fault_model",
    "healthy_for",
    "healthy_state",
    "inject",
    "live_combo_mask",
    "live_majority_labels",
    "plan_failover",
    "recenter_state",
    "register_fault_model",
    "sample_stuck_cells",
    "sample_word_dropout",
]
