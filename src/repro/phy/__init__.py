"""PHY channel subsystem: the pluggable over-the-air link of the serve path.

See `repro.phy.channel` for the `Channel` interface, the three fidelity tiers
(``ideal`` / ``bsc`` / ``symbol``) and the `ChannelState` precharacterization
pytree that `core.scaleout` threads through the serve steps.
"""
from repro.phy.channel import (
    CHANNELS,
    BSCChannel,
    Channel,
    ChannelState,
    IdealChannel,
    SymbolChannel,
    awgn_decide,
    combo_index,
    get_channel,
    state_from_ber,
    state_from_ota,
    state_shape_structs,
    state_spec,
)

__all__ = [
    "CHANNELS",
    "BSCChannel",
    "Channel",
    "ChannelState",
    "IdealChannel",
    "SymbolChannel",
    "awgn_decide",
    "combo_index",
    "get_channel",
    "state_from_ber",
    "state_from_ota",
    "state_shape_structs",
    "state_spec",
]
