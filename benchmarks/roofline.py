"""§Roofline table: three terms per (arch × shape) from the dry-run artifacts.

    compute    = per-device FLOPs / 197e12      (bf16 peak, v5e)
    memory     = per-device HBM bytes / 819e9
    collective = per-device collective bytes / 50e9

(The HLO is post-SPMD, i.e. already per-device, so no division by chip count.)
Also reports MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
Run after `python -m repro.launch.dryrun --all`.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ARTIFACTS, save

DRYRUN = os.path.join(ARTIFACTS, "dryrun")
CELL_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_all(mesh: str = "pod1") -> list[dict]:
    d = os.path.join(DRYRUN, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name)) as f:
            recs.append(json.load(f))
    return recs


def run(mesh: str = "pod1", quiet: bool = False) -> dict:
    recs = [r for r in load_all(mesh) if r["arch"] != "hdc-scaleout"]
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "cell": r["cell"], "status": "skipped",
                         "why": r["why"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "cell": r["cell"], "status": r["status"]})
            continue
        rl = r["roofline_s"]
        rows.append({
            "arch": r["arch"], "cell": r["cell"], "status": "ok",
            "params": r["params"],
            "compute_s": rl["compute"], "memory_s": rl["memory"],
            "collective_s": rl["collective"], "dominant": rl["dominant"],
            "model_flops": r["model_flops_global"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": rl["compute"] / max(
                rl["compute"], rl["memory"], rl["collective"]),
        })
    if not quiet:
        hdr = f"{'arch':22s} {'cell':12s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>9s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}"
        print(hdr)
        key = {c: i for i, c in enumerate(CELL_ORDER)}
        for row in sorted(rows, key=lambda x: (x["arch"], key.get(x["cell"], 9))):
            if row["status"] == "skipped":
                print(f"{row['arch']:22s} {row['cell']:12s} {'— skipped: ' + row['why'][:60]}")
            elif row["status"] != "ok":
                print(f"{row['arch']:22s} {row['cell']:12s} ERROR")
            else:
                print(f"{row['arch']:22s} {row['cell']:12s} {row['compute_s']:10.4f} "
                      f"{row['memory_s']:10.4f} {row['collective_s']:9.4f} "
                      f"{row['dominant']:>10s} {row['useful_ratio']:7.3f} "
                      f"{100*row['roofline_fraction']:6.1f}%")
    out = {"mesh": mesh, "rows": rows}
    save(f"roofline_{mesh}", out)
    return out


if __name__ == "__main__":
    run()
