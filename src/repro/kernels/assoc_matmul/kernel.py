"""Pallas TPU kernel: bipolar associative-memory matmul (the MXU IMC analogue).

Computes dots[b, c] = sum_k (2 q[b,k]-1)(2 p[c,k]-1) with uint8 {0,1} inputs
converted to bipolar bf16 *inside* the kernel (so HBM traffic stays 1 byte/element)
and accumulation in an f32 VMEM scratch across the k grid dimension.

Tiling: classic (bm, bn, bk) matmul; MXU-aligned blocks (multiples of 128 on the
lane dim, 8 on sublanes). The k-axis padding is masked in-kernel: a zero-padded
{0,1} input would otherwise turn into bipolar -1 and bias every dot by +1 per pad.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assoc_kernel(q_ref, p_ref, o_ref, acc_ref, *, nk: int, bk: int, k_actual: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # bipolar conversion with k-padding mask (pads contribute 0, not (-1)·(-1)=+1)
    kpos = k * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = (kpos < k_actual).astype(jnp.bfloat16)                      # [1, bk]
    qb = (2.0 * q_ref[...].astype(jnp.bfloat16) - 1.0) * mask          # [bm, bk]
    pb = (2.0 * p_ref[...].astype(jnp.bfloat16) - 1.0) * mask          # [bn, bk]
    acc_ref[...] += jax.lax.dot_general(
        qb, pb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "k_actual", "interpret"))
def assoc_matmul_pallas(
    q: jax.Array,
    protos: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    k_actual: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q [B, K] uint8, protos [C, K] uint8 -> [B, C] f32; dims divisible by blocks."""
    b, kdim = q.shape
    c, k2 = protos.shape
    assert kdim == k2, (kdim, k2)
    assert b % bm == 0 and c % bn == 0 and kdim % bk == 0, (b, bm, c, bn, kdim, bk)
    if k_actual is None:
        k_actual = kdim
    nk = kdim // bk
    grid = (b // bm, c // bn, nk)
    return pl.pallas_call(
        functools.partial(_assoc_kernel, nk=nk, bk=bk, k_actual=k_actual),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        scratch_shapes=[_vmem_scratch(bm, bn)],
        interpret=interpret,
    )(q, protos)


def _vmem_scratch(bm: int, bn: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.float32)
