"""Version-portable pytree helpers.

``jax.tree.flatten_with_path`` only exists on newer JAX; 0.4.x spells it
``jax.tree_util.tree_flatten_with_path``. Same return shape on both:
``([(path, leaf), ...], treedef)``.
"""
from __future__ import annotations

from typing import Any

import jax


def tree_flatten_with_path(tree: Any):
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)
