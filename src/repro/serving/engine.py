"""Batched serving engine: prefill + jitted multi-token decode loop.

Static-batch engine (the serving analogue of the dry-run decode cells): a batch
of prompts is prefilled in one pass (KV cache padded to prompt + max_new), then
`lax.scan` drives `max_new` decode steps entirely on device — one compiled
program for the whole generation, no host round-trips. Greedy or temperature
sampling; per-sequence EOS freezing.

Production notes (multi-host): requests are bucketed by prompt length to bound
recompilation; the cache lives sharded (batch over data axes, kv_heads/kv_seq
over model per arch rules); continuous batching would swap finished rows via
`dynamic_update_slice` on the cache — out of scope for the single-process
simulation but the cache layout (batch-major, slot ring) is chosen for it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new: int = 32
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int | None = None


class Engine:
    def __init__(self, model, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self._gen = None

    def _build(self, prompt_len: int, extra_batch: dict):
        model, cfg = self.model, self.cfg
        pad_to = prompt_len + cfg.max_new + 1

        def generate(params, batch, key):
            logits, cache = model.prefill_fn(params, batch, pad_to=pad_to)
            b = logits.shape[0]
            pos0 = prompt_len + (
                batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0
            )

            def sample(logits, key):
                if cfg.temperature <= 0.0:
                    return jnp.argmax(logits, -1).astype(jnp.int32)
                return jax.random.categorical(key, logits / cfg.temperature, -1).astype(jnp.int32)

            tok0 = sample(logits, key)
            done0 = jnp.zeros((b,), bool)

            def step(carry, i):
                cache, tok, done, key = carry
                key, k1 = jax.random.split(key)
                logits, cache = model.decode_fn(params, cache, tok, pos0 + i)
                nxt = sample(logits, k1)
                if cfg.eos_id is not None:
                    done = done | (tok == cfg.eos_id)
                    nxt = jnp.where(done, cfg.eos_id or 0, nxt)
                return (cache, nxt, done, key), tok

            (_, _, _, _), toks = jax.lax.scan(
                step, (cache, tok0, done0, key), jnp.arange(cfg.max_new)
            )
            return jnp.moveaxis(toks, 0, 1)  # [B, max_new]

        return jax.jit(generate)

    def generate(self, params, batch: dict, key: jax.Array | None = None) -> jax.Array:
        """batch: model inputs incl. 'tokens' [B, S_prompt]. Returns [B, max_new]."""
        prompt_len = batch["tokens"].shape[1]
        if self._gen is None:
            self._gen = self._build(prompt_len, batch)
        return self._gen(params, batch, key if key is not None else jax.random.PRNGKey(0))
