"""Generic HDC-based classifier + the paper's bundled-query retrieval experiment.

Setup of Sec. IV/V: every receiver's associative memory stores C = 100 prototype
hypervectors (one per class, d = 512).  M encoders each pick a class from the shared
codebook and transmit its query hypervector; the OTA channel computes the bit-wise
majority (bundling) and every receiver similarity-searches the composite query.

* **baseline bundling**: Q = maj(q_1..q_M); the receiver returns the top-M most
  similar classes; the trial succeeds iff the retrieved set equals the sent set
  (duplicate classes collapse under bundling and cannot be told apart -> analytically
  the ideal-channel accuracy is ~= P(all M draws distinct) = prod_i (1 - i/C), which
  matches Table I's baseline row: 0.97, 0.90, 0.81, 0.69, 0.57 for M = 3..11).
* **permuted bundling**: encoder m transmits rho^m(q_m) (m-step cyclic shift); the
  receiver expands its memory with the M permuted prototype banks and recovers, per
  TX signature, the top-1 class.  Duplicates are now distinguishable and the shared
  codebook decorrelates -> accuracy stays ~1 up to M ~ 11 (Table I bottom).

Channel errors are injected as uncorrelated bit flips at the measured OTA BER
(exactly the paper's methodology).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import phy
from repro.core import hypervector as hv, sparse
from repro.kernels.assoc_matmul import assoc_matmul
from repro.kernels.hamming import hamming_search, hamming_topk_banked
from repro.kernels.sparse import sparse_search


@dataclasses.dataclass(frozen=True)
class HDCTaskConfig:
    n_classes: int = 100
    dim: int = 512
    n_trials: int = 2000


def make_codebook(key: jax.Array, cfg: HDCTaskConfig,
                  density: float | None = None) -> jax.Array:
    """The shared item/prototype memory: [C, d] random atomic hypervectors.

    ``density`` draws each bit i.i.d. at that rate instead of 1/2 — the
    ultra-sparse codebook every representation shares (same key -> same
    bits), so sparse-vs-packed accuracy comparisons differ only in the
    representation, never in the codebook."""
    if density is None:
        return hv.random_hv(key, cfg.n_classes, cfg.dim)
    return jax.random.bernoulli(
        key, density, (cfg.n_classes, cfg.dim)).astype(jnp.uint8)


def make_tenant_codebooks(key: jax.Array, cfg: HDCTaskConfig,
                          n_tenants: int) -> jax.Array:
    """Per-tenant prototype memories [T, C, d]: tenant t's codebook is
    ``make_codebook(fold_in(key, t), cfg)`` — the exact codebook a standalone
    single-tenant serve would build from that folded key, which is what lets
    the multi-tenant lifecycle tests compare against fresh standalone serves
    tenant by tenant."""
    return jnp.stack([
        make_codebook(jax.random.fold_in(key, t), cfg) for t in range(n_tenants)
    ])


def expanded_prototypes(protos: jax.Array, m: int) -> jax.Array:
    """Permuted prototype banks for TX signatures 0..M-1: [M, C, d]."""
    return jnp.stack([hv.permute(protos, s) for s in range(m)], axis=0)


def expanded_prototypes_packed(protos_p: jax.Array, m: int) -> jax.Array:
    """Packed permuted banks: protos_p [C, W] uint32 -> [M, C, W].

    Precomputed once per memory (not per trial) — the packed trial path reads
    d/8 bytes per bank row instead of d.
    """
    return jnp.stack([hv.permute_packed(protos_p, s) for s in range(m)], axis=0)


# ---------------------------------------------------------------------------
# single-trial logic (vmapped over trials)
# ---------------------------------------------------------------------------

def _bundle_queries(queries: jax.Array) -> jax.Array:
    return hv.majority(queries)


def _trial_baseline(key, protos, m, ber):
    c, d = protos.shape
    k_cls, k_flip = jax.random.split(key)
    classes = jax.random.randint(k_cls, (m,), 0, c)
    q = _bundle_queries(protos[classes])
    q = hv.flip_bits(k_flip, q, ber)
    sims = hv.hamming_similarity(q, protos)  # [C]
    topm = jax.lax.top_k(sims, m)[1]
    # exact set match: every sent class retrieved and vice versa
    sent_onehot = jnp.zeros((c,), jnp.int32).at[classes].set(1)
    got_onehot = jnp.zeros((c,), jnp.int32).at[topm].set(1)
    ok = jnp.all(sent_onehot == got_onehot)
    return ok, sims


def _trial_permuted(key, protos, m, ber):
    c, d = protos.shape
    k_cls, k_flip = jax.random.split(key)
    classes = jax.random.randint(k_cls, (m,), 0, c)
    shifts = jnp.arange(m)
    q_tx = hv.permute_batch(protos[classes], shifts)  # each TX applies its signature
    q = _bundle_queries(q_tx)
    q = hv.flip_bits(k_flip, q, ber)
    banks = expanded_prototypes(protos, m)  # [M, C, d]
    sims = jax.vmap(lambda bank: hv.hamming_similarity(q, bank))(banks)  # [M, C]
    pred = jnp.argmax(sims, axis=-1)  # top-1 per TX signature
    ok = jnp.all(pred == classes)
    return ok, sims.reshape(-1)


def _similarity(qs: jax.Array, protos: jax.Array, d: int, packed: bool,
                use_kernels: bool) -> jax.Array:
    """Batched similarity [T, C] in [0, 1], identical floats across all 4 modes.

    All four dispatches produce the exact integer bipolar dot (d - 2*hamming,
    exactly representable in f32 for any d here), then apply the same
    (dot + d) / 2d normalization — so accuracies are bit-identical whether the
    similarity ran on the fp32 MXU path, the XOR+popcount path, or a Pallas
    kernel (which is what lets the benchmark entry points run use_kernels=True
    without moving the reproduced numbers).
    """
    if packed:
        # the op layer chunks the jnp fallback over C (cache cliff past ~8 MiB)
        dist = hamming_search(qs, protos, use_kernel=use_kernels)
        dots = (d - 2 * dist).astype(jnp.float32)
    elif use_kernels:
        dots = assoc_matmul(qs, protos, use_kernel=True)
    else:
        return hv.hamming_similarity(qs, protos)
    return (dots + d) / (2.0 * d)


@functools.partial(
    jax.jit, static_argnames=("m", "bundling", "representation", "use_kernels",
                              "channel", "k_max")
)
def _run_trials(
    keys: jax.Array,
    protos: jax.Array,
    m: int,
    ber: jax.Array,
    bundling: str,
    representation: str,
    use_kernels: bool,
    channel: str = "bsc",
    state: phy.ChannelState | None = None,
    k_max: int = 0,
) -> jax.Array:
    """Per-trial success flags [T] for T = keys.shape[0] trials.

    Three phases: (1) vmapped per-trial query construction (draw classes,
    permute, bundle, channel) — bit-exact across representations on the same
    per-trial keys; (2) ONE batched similarity launch over all trials (and all
    permuted banks); (3) vmapped per-trial decision. Phase 2 is what makes the
    Pallas-kernel path a single grid launch instead of n_trials tiny calls.

    ``channel="symbol"`` replaces the majority+BSC abstraction with the
    physical link from a `phy.ChannelState`: trial t decodes at RX core
    ``t % N`` (the system-level view — accuracy averaged over every
    receiver's own constellation + AWGN decode); `ber` is then unused.

    ``representation="sparse"`` (baseline bundling + bsc/ideal only) runs the
    per-trial algebra on k_max-capacity index lists — the SAME classes draw,
    the O(k log k) sparse bundle, the O(k) drop+insert BSC — and ONE batched
    `sparse_search` launch against the packed codebook; at ber=0 with no
    saturation the distances (hence accuracies) match "packed" exactly.
    """
    c, d = protos.shape
    sparse_rep = representation == "sparse"
    if sparse_rep and (bundling != "baseline" or channel == "symbol"):
        raise ValueError(
            "representation='sparse' supports baseline bundling on the "
            f"bsc/ideal channels only (got bundling={bundling!r}, "
            f"channel={channel!r})"
        )
    packed = representation == "packed"
    protos_r = hv.pack(protos) if packed or sparse_rep else protos
    codes = sparse.sparsify(protos, k_max) if sparse_rep else None
    shifts = jnp.arange(m)

    def build(k, rx):
        k_cls, k_chan = jax.random.split(k)
        classes = jax.random.randint(k_cls, (m,), 0, c)
        if sparse_rep:
            q = sparse.bundle(codes[classes])
            return classes, sparse.flip_bits_sparse(k_chan, q, ber, d)
        qs = protos_r[classes]
        if bundling == "permuted":  # each TX applies its signature
            qs = (hv.permute_batch_packed(qs, shifts) if packed
                  else hv.permute_batch(qs, shifts))
        if channel == "symbol":
            # bundling and noise happen jointly IN the channel: superpose the
            # M phase-encoded bits, AWGN, decode via RX rx's decision regions
            bits = hv.unpack(qs, d) if packed else qs          # [m, d]
            combo = phy.combo_index(bits, axis=0)              # [d]
            sym = jnp.take(state.symbols, rx, axis=0)[combo]
            q = phy.awgn_decide(k_chan, sym, state.c0[rx], state.c1[rx],
                                state.n0)
            q = hv.pack(q) if packed else q
        else:
            q = hv.majority_packed(qs) if packed else hv.majority(qs)
            q = (hv.flip_bits_packed(k_chan, q, ber) if packed
                 else hv.flip_bits(k_chan, q, ber))
        return classes, q

    t = keys.shape[0]
    rxs = (jnp.arange(t) % state.n_rx) if channel == "symbol" else jnp.zeros(
        (t,), jnp.int32)
    classes, qs = jax.vmap(build)(keys, rxs)  # [T, m], [T, d|W|k_max]
    if bundling == "baseline":
        if sparse_rep:
            # gather-overlap search on the index lists; same integer dots and
            # the same normalization as _similarity's packed dispatch
            dist = sparse_search(qs, protos_r, use_kernel=use_kernels)
            sims = ((d - 2 * dist).astype(jnp.float32) + d) / (2.0 * d)
        else:
            sims = _similarity(qs, protos_r, d, packed, use_kernels)  # [T, C]

        def decide(sims_t, classes_t):
            topm = jax.lax.top_k(sims_t, m)[1]
            # exact set match: every sent class retrieved and vice versa
            sent = jnp.zeros((c,), jnp.int32).at[classes_t].set(1)
            got = jnp.zeros((c,), jnp.int32).at[topm].set(1)
            return jnp.all(sent == got)

        return jax.vmap(decide)(sims, classes)
    banks = (expanded_prototypes_packed(protos_r, m) if packed
             else expanded_prototypes(protos, m))  # [M, C, d|W]
    if packed:
        # fused top-1 per permuted bank: every TX signature is a bank of the
        # SAME banked launch (G = M, all T trials as the query batch), and the
        # class axis reduces in VMEM — the [T, M, C] similarity tensor never
        # materializes. argmin-of-distance == first-max argmax-of-sims exactly,
        # so accuracy is bit-identical to the unpacked dispatches.
        q_rep = jnp.broadcast_to(qs[None], (m,) + qs.shape)  # [M, T, W]
        _, amin = hamming_topk_banked(q_rep, banks, use_kernel=use_kernels)
        pred = amin.T  # [T, M] top-1 per TX signature
        return jnp.all(pred == classes, axis=-1)
    sims = _similarity(
        qs, banks.reshape(m * c, banks.shape[-1]), d, packed, use_kernels
    ).reshape(-1, m, c)
    pred = jnp.argmax(sims, axis=-1)  # top-1 per TX signature
    return jnp.all(pred == classes, axis=-1)


def run_accuracy(
    key: jax.Array,
    cfg: HDCTaskConfig,
    m: int,
    ber: float,
    bundling: str = "baseline",
    *,
    representation: str = "unpacked",
    use_kernels: bool = False,
    channel: str = "bsc",
    state: phy.ChannelState | None = None,
    density: float | None = None,
    k_max: int = 0,
) -> jnp.ndarray:
    """Trial-exact classification accuracy for M bundled hypervectors at a given BER.

    `representation` "packed" runs the whole trial on uint32 words (packed
    codebook gathers, packed permute/majority/BSC, popcount similarity; the
    permuted top-1 uses the fused `hamming_topk_banked` reduction, so the
    [T, M, C] similarity tensor never materializes); `use_kernels` dispatches
    the similarity to the Pallas kernels (interpret mode on CPU). All four
    combinations return the identical accuracy for the same key — asserted in
    tests/test_hdc_core.py.

    `channel="symbol"` (with a `phy.ChannelState` from
    `scaleout.precharacterize_state`) swaps the BER abstraction for the
    physical constellation + AWGN + decision-region link, cycling trials over
    the state's RX cores — the EXPERIMENTS.md §Channel-fidelity comparison.
    `ber` is ignored on that tier; the per-trial class draws stay on the same
    stream, so bsc-vs-symbol accuracy gaps are channel effects, not sampling.

    `representation="sparse"` (needs ``k_max``; ``density`` draws the shared
    low-density codebook every representation can reuse) runs trials on
    k_max-capacity index lists — baseline bundling only, BSC noise via the
    sparse drop+insert channel. At ber=0 with codebook rows and bundles
    inside the k_max capacity the accuracy is bit-identical to "packed" on
    the same key (asserted in tests/test_sparse.py).
    """
    if channel == "symbol" and state is None:
        raise ValueError("channel='symbol' needs a phy.ChannelState "
                         "(scaleout.precharacterize_state)")
    if channel == "symbol" and not bool(jnp.any(state.valid)):
        raise ValueError(
            "channel='symbol' needs characterized decision regions, but "
            "state.valid is all-False (e.g. a state_from_ber synthesis with "
            "zero physics) — build one with scaleout.precharacterize_state"
        )
    if representation == "sparse":
        if k_max <= 0:
            raise ValueError(
                "representation='sparse' needs k_max > 0 (the index-list "
                f"capacity); got k_max={k_max}")
        if bundling != "baseline":
            raise ValueError(
                "representation='sparse' supports baseline bundling only "
                "(permuted TX signatures would need per-bank sparse "
                f"searches); got bundling={bundling!r}")
        if channel == "symbol":
            raise ValueError(
                "representation='sparse' has no symbol tier (the "
                "constellation decodes dense per-dimension fields); use "
                "channel='bsc' or 'ideal'")
    k_code, k_trials = jax.random.split(key)
    protos = make_codebook(k_code, cfg, density)
    keys = jax.random.split(k_trials, cfg.n_trials)
    ok = _run_trials(keys, protos, m, ber, bundling, representation, use_kernels,
                     channel, state, k_max)
    return jnp.mean(ok)


def similarity_profile(
    key: jax.Array, cfg: HDCTaskConfig, m: int, ber: float, bundling: str = "baseline"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One trial's similarity-vs-class profile (Fig. 11): returns (classes, sims)."""
    protos = make_codebook(jax.random.split(key)[0], cfg)
    trial = _trial_baseline if bundling == "baseline" else _trial_permuted
    k_cls = jax.random.split(key, 3)[1]
    classes = jax.random.randint(jax.random.split(k_cls)[0], (m,), 0, cfg.n_classes)
    _, sims = trial(key, protos, m, ber)
    return classes, sims


def accuracy_vs_ber(
    key: jax.Array,
    cfg: HDCTaskConfig,
    m: int,
    bers: jnp.ndarray,
    bundling: str = "baseline",
    *,
    representation: str = "unpacked",
    use_kernels: bool = False,
) -> jnp.ndarray:
    """Fig. 10 sweep: accuracy as a function of the interconnect error rate."""
    return jnp.stack([
        run_accuracy(key, cfg, m, float(b), bundling,
                     representation=representation, use_kernels=use_kernels)
        for b in bers
    ])


def serve_accuracy(pred, classes) -> dict:
    """Accuracy of distributed serve predictions against the sent classes.

    ``pred``/``classes`` are the `scaleout.make_ota_serve` /
    `scaleout.make_queries` pair: [B] for baseline bundling, [B, M] for
    permuted (one top-1 per TX signature). Returns both granularities the
    fault-tolerance experiments report:

    * ``draw_acc`` — fraction of individual class draws answered correctly
      (the natural unit for degradation curves: k dead cores out of N
      un-serve k/N of the class space, which this metric shows linearly);
    * ``trial_acc`` — fraction of trials with EVERY draw correct (the
      paper's Table-I success criterion).
    """
    p = np.asarray(pred)
    c = np.asarray(classes)
    assert p.shape == c.shape, (p.shape, c.shape)
    hit = p == c
    return {
        "draw_acc": float(hit.mean()),
        "trial_acc": float(hit.reshape(hit.shape[0], -1).all(axis=-1).mean()),
    }


def run_drift_sweep(
    key: jax.Array,
    cfg: HDCTaskConfig,
    m: int,
    state: phy.ChannelState,
    process,
    n_steps: int,
    *,
    bundling: str = "permuted",
    representation: str = "unpacked",
    use_kernels: bool = False,
    adaptive: bool = False,
    patience: int = 2,
    band_kwargs: dict | None = None,
) -> dict:
    """Accuracy-per-step over a LIVING channel — the closed-loop robustness
    sweep behind EXPERIMENTS.md §Living-channels.

    Rolls ``state`` forward ``n_steps`` under `process`
    (`phy.process.rollout`, or `adaptive_rollout` with the banded EM re-fit
    when ``adaptive=True``) and evaluates the symbol-tier trial accuracy at
    every step's `ChannelState`. The SAME trial key is reused each step, so
    per-step accuracy differences are channel effects, not sampling; the
    evolving states share one pytree structure, so all T evaluations reuse
    ONE `_run_trials` compile.

    Returns a dict with per-step ``acc`` [T], true-BER stats, the monitor
    estimate, and (adaptive) the re-fit action trace [T, N].
    """
    from repro.phy import process as phy_process

    k_proc, k_trials = jax.random.split(key)
    k_code, k_tr = jax.random.split(k_trials)
    protos = make_codebook(k_code, cfg)
    keys = jax.random.split(k_tr, cfg.n_trials)

    p0 = process.init(state)
    if adaptive:
        _, traj, trips = phy_process.adaptive_rollout(
            process, p0, k_proc, n_steps, patience=patience,
            band_kwargs=band_kwargs)
    else:
        _, traj = phy_process.rollout(process, p0, k_proc, n_steps)
        trips = jnp.zeros((n_steps, state.n_rx), bool)

    accs, ber_avg, ber_max, est_avg = [], [], [], []
    for t in range(n_steps):
        pt = jax.tree_util.tree_map(lambda x: x[t], traj)
        ok = _run_trials(keys, protos, m, jnp.zeros(()), bundling,
                         representation, use_kernels, "symbol", pt.chan)
        accs.append(float(jnp.mean(ok)))
        ber_avg.append(float(jnp.mean(pt.chan.ber)))
        ber_max.append(float(jnp.max(pt.chan.ber)))
        est_avg.append(float(jnp.mean(pt.est)))
    return {
        "acc": accs,
        "ber_avg": ber_avg,
        "ber_max": ber_max,
        "est_avg": est_avg,
        "refits": trips,
        "n_refits": int(jnp.sum(trips)),
    }


# ---------------------------------------------------------------------------
# multi-centroid associative memory (MEMHD-style, arXiv 2502.07834)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("k_c", "samples_per_class", "n_iters")
)
def train_multicentroid(
    key: jax.Array,
    protos: jax.Array,
    k_c: int,
    *,
    samples_per_class: int = 32,
    ber: jax.Array | float = 0.08,
    n_iters: int = 4,
) -> jax.Array:
    """Majority-based k-means in PACKED space: each class's single prototype
    becomes ``k_c`` centroids covering its noisy query distribution.

    protos: [C, d] uint8 or [C, W] uint32 -> [C, k_c, W] uint32 centroid banks.

    Per class, `samples_per_class` BSC-noised copies of the class HV are drawn
    at `ber` (the operating point the associative memory actually sees over
    the OTA channel); k_c distinct samples seed the centroids, then the loop
    alternates (1) nearest-centroid assignment under packed Hamming distance
    and (2) the masked carry-save-adder majority update
    (`hv.majority_packed_masked` — a traced-count strict majority, so the
    whole k-means is ONE jitted program, no recompile per iteration). Empty
    clusters keep their previous centroid. Centroid rows are class-major, so
    prediction maps centroid-argmin -> class by integer division
    (`multicentroid_predict`).
    """
    protos_p = protos if protos.dtype == jnp.uint32 else hv.pack(protos)
    c, w = protos_p.shape
    assert 1 <= k_c <= samples_per_class, (k_c, samples_per_class)

    def one_class(class_key, proto_row):
        k_noise, k_init = jax.random.split(class_key)
        samples = hv.flip_bits_packed(
            k_noise, jnp.broadcast_to(proto_row, (samples_per_class, w)), ber
        )
        init = jax.random.choice(
            k_init, samples_per_class, (k_c,), replace=False
        )
        cent = samples[init]                                   # [k_c, W]
        for _ in range(n_iters):
            dist = hv.hamming_distance_packed(samples, cent)   # [S, k_c]
            assign = jnp.argmin(dist, axis=-1)                 # first-min ties
            masks = assign[None, :] == jnp.arange(k_c)[:, None]  # [k_c, S]
            new = jax.vmap(
                lambda msk: hv.majority_packed_masked(samples, msk)
            )(masks)
            nonempty = jnp.any(masks, axis=1)[:, None]
            cent = jnp.where(nonempty, new, cent)
        return cent

    return jax.vmap(one_class)(jax.random.split(key, c), protos_p)


def multicentroid_predict(
    queries: jax.Array, centroids: jax.Array, *, use_kernels: bool = True
) -> jax.Array:
    """Top-1 class over a multi-centroid memory.

    queries [T, d] uint8 or [T, W] uint32, centroids [C, k_c, W] uint32 ->
    [T] int32 class ids. ONE fused top-1 launch over the flattened [C*k_c]
    centroid rows; the row layout is class-major, so centroid-argmin -> class
    is integer division by k_c (ties therefore break toward the lowest class,
    matching the single-prototype path).
    """
    c, k_c, w = centroids.shape
    qp = queries if queries.dtype == jnp.uint32 else hv.pack(queries)
    _, amin = hamming_topk_banked(
        qp[None], centroids.reshape(1, c * k_c, w), use_kernel=use_kernels
    )
    return (amin[0] // k_c).astype(jnp.int32)


def table1(
    key: jax.Array,
    cfg: HDCTaskConfig,
    wireless_ber: float,
    ms: Tuple[int, ...] = (1, 3, 5, 7, 9, 11),
    *,
    representation: str = "unpacked",
    use_kernels: bool = False,
) -> dict:
    """Reproduces Table I: accuracy for {baseline, permuted} x {ideal, wireless}."""
    out = {}
    for bundling in ("baseline", "permuted"):
        for channel, ber in (("ideal", 0.0), ("wireless", wireless_ber)):
            accs = [
                float(run_accuracy(key, cfg, m, ber, bundling,
                                   representation=representation,
                                   use_kernels=use_kernels))
                for m in ms
            ]
            out[(bundling, channel)] = accs
    return out
