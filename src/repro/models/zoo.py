"""Model zoo: one `Model` facade per architecture family.

`get_model(cfg)` returns a `Model` whose members are pure functions suitable for
`jax.jit` / `.lower()`:

* loss_fn(params, batch)            -> (loss, metrics)      [train_* cells]
* prefill_fn(params, batch)         -> (last logits, cache) [prefill_* cells]
* decode_fn(params, cache, tok, pos)-> (logits, cache)      [decode_* / long_* cells]
* cache_specs_fn(batch, seq)        -> (ShapeDtypeStructs, logical axes)
* init_cache_fn(batch, seq)         -> zeroed cache arrays

Families: dense/MoE decoder (smollm, gemma3, tinyllama, deepseek, mixtral, kimi),
VLM (qwen2-vl), enc-dec (whisper), SSM (falcon-mamba), hybrid (zamba2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, mamba as mamba_lib, transformer as tfm, vlm
from repro.models.base import ParamSpec
from repro.models.config import ModelConfig
from repro.train.loss import chunked_cross_entropy


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    specs: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    cache_specs_fn: Callable
    init_cache_fn: Callable
    has_decode: bool = True
    # (params, cache, tokens [B, cs], start: static int) -> (logits [B, V], cache)
    # One prefill chunk against a full-capacity cache; dense decoders only
    # (None for MoE — routing over the token axis makes chunk boundaries change
    # expert drops — and for VLM/SSM/hybrid/enc-dec families).
    prefill_chunk_fn: Callable | None = None


def _head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _final_loss(params, cfg, h, targets, aux, mask=None):
    from repro.models.layers import REDUCE_BF16, bf16_grad, rmsnorm

    if REDUCE_BF16:  # cast the loss cotangent once -> bf16 backward collectives
        h = bf16_grad(h)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    ce = chunked_cross_entropy(
        h, _head_weight(params, cfg), targets, mask=mask, chunk=cfg.loss_chunk
    )
    return ce + aux, {"ce": ce, "aux": aux}


def _last_logits(params, cfg, h_last):
    """h_last [B, 1, d] -> logits [B, V] (f32)."""
    return tfm.logits_head(params, cfg, h_last)[:, 0]


# ---------------------------------------------------------------------------
# dense / MoE decoder (+ VLM via positions & vision prefix)
# ---------------------------------------------------------------------------

def _decoder_model(cfg: ModelConfig) -> Model:
    is_vlm = cfg.kind == "vlm"
    specs = vlm.vlm_specs(cfg) if is_vlm else tfm.decoder_specs(cfg)

    def positions_for(tokens, batch):
        b, s = tokens.shape
        if cfg.mrope_sections is not None:
            raise AssertionError("vlm positions must come from the batch")
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def loss_fn(params, batch):
        if is_vlm:
            h, aux, _ = vlm.run_vlm_train(
                params, cfg, batch["tokens"], batch.get("patch_embeds"), batch["positions"]
            )
        else:
            x = tfm.embed_tokens(params, cfg, batch["tokens"])
            h, aux, _ = tfm.run_stack_train(
                params, cfg, x, positions_for(batch["tokens"], None)
            )
        return _final_loss(params, cfg, h, batch["targets"], aux)

    def prefill_fn(params, batch, pad_to=None):
        if is_vlm:
            h, _, kv = vlm.run_vlm_train(
                params, cfg, batch["tokens"], batch.get("patch_embeds"),
                batch["positions"], return_kv=True,
            )
            seq = batch["positions"].shape[1]
        else:
            x = tfm.embed_tokens(params, cfg, batch["tokens"])
            h, _, kv = tfm.run_stack_train(
                params, cfg, x, positions_for(batch["tokens"], None), return_kv=True
            )
            seq = batch["tokens"].shape[1]
        cache = tfm.cache_from_kv(cfg, kv, seq, pad_to)
        return _last_logits(params, cfg, h[:, -1:]), cache

    def decode_fn(params, cache, token, pos):
        x = tfm.embed_tokens(params, cfg, token[:, None])
        h, cache = tfm.run_stack_decode(params, cfg, x, pos, cache)
        return _last_logits(params, cfg, h), cache

    def cache_specs_fn(batch, seq):
        return tfm.cache_specs(cfg, batch, seq)

    def init_cache_fn(batch, seq):
        c = tfm.init_cache(cfg, batch, seq)
        return c

    prefill_chunk_fn = None
    if not is_vlm and cfg.moe is None:
        def prefill_chunk_fn(params, cache, tokens, start):
            x = tfm.embed_tokens(params, cfg, tokens)
            b, s = tokens.shape
            positions = jnp.broadcast_to(
                start + jnp.arange(s, dtype=jnp.int32)[None], (b, s)
            )
            h, cache = tfm.run_stack_chunk(params, cfg, x, positions, cache, start)
            return _last_logits(params, cfg, h[:, -1:]), cache

    return Model(cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs_fn,
                 init_cache_fn, prefill_chunk_fn=prefill_chunk_fn)


# ---------------------------------------------------------------------------
# SSM (falcon-mamba)
# ---------------------------------------------------------------------------

def _ssm_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "normal", 0.02, cfg.dtype),
        "blocks": mamba_lib.mamba1_specs(cfg),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros", dtype=cfg.dtype),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"), "fan_in", dtype=cfg.dtype),
    }


def _ssm_model(cfg: ModelConfig) -> Model:
    specs = _ssm_specs(cfg)
    s = cfg.ssm
    din = s.expand * cfg.d_model

    def run_train(params, x, return_state=False):
        def body(x, blk):
            x, state = mamba_lib.mamba1_block(blk, cfg, x)
            return x, (state if return_state else None)

        body_fn = jax.checkpoint(body) if cfg.remat and not return_state else body
        return jax.lax.scan(body_fn, x, params["blocks"])

    def loss_fn(params, batch):
        x = tfm.embed_tokens(params, cfg, batch["tokens"])
        h, _ = run_train(params, x)
        return _final_loss(params, cfg, h, batch["targets"], 0.0)

    def prefill_fn(params, batch, pad_to=None):
        del pad_to  # SSM state is O(1); no cache capacity
        x = tfm.embed_tokens(params, cfg, batch["tokens"])
        h, (conv, ssm) = run_train(params, x, return_state=True)
        cache = {"conv": conv, "ssm": ssm}
        return _last_logits(params, cfg, h[:, -1:]), cache

    def decode_fn(params, cache, token, pos):
        x = tfm.embed_tokens(params, cfg, token[:, None])

        def body(x, xs):
            blk, cst, sst = xs
            x, cst, sst = mamba_lib.mamba1_decode(blk, cfg, x, cst, sst)
            return x, (cst, sst)

        x, (conv, ssm) = jax.lax.scan(body, x, (params["blocks"], cache["conv"], cache["ssm"]))
        return _last_logits(params, cfg, x), dict(cache, conv=conv, ssm=ssm)

    def cache_specs_fn(batch, seq):
        l = cfg.n_layers
        shapes = {
            "conv": jax.ShapeDtypeStruct((l, batch, s.d_conv - 1, din), cfg.dtype),
            "ssm": jax.ShapeDtypeStruct((l, batch, din, s.d_state), jnp.float32),
        }
        axes = {
            "conv": (None, "batch", None, "inner"),
            "ssm": (None, "batch", "inner", "state"),
        }
        return shapes, axes

    def init_cache_fn(batch, seq):
        shapes, _ = cache_specs_fn(batch, seq)
        return {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}

    return Model(cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs_fn, init_cache_fn)


# ---------------------------------------------------------------------------
# hybrid (zamba2)
# ---------------------------------------------------------------------------

def _hybrid_model(cfg: ModelConfig) -> Model:
    specs = hybrid.hybrid_specs(cfg)

    def positions_for(b, s):
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def loss_fn(params, batch):
        x = tfm.embed_tokens(params, cfg, batch["tokens"])
        b, s = batch["tokens"].shape
        h, aux, _ = hybrid.run_hybrid_train(params, cfg, x, positions_for(b, s))
        return _final_loss(params, cfg, h, batch["targets"], aux)

    def prefill_fn(params, batch, pad_to=None):
        x = tfm.embed_tokens(params, cfg, batch["tokens"])
        b, s = batch["tokens"].shape
        h, _, (kv, states) = hybrid.run_hybrid_train(
            params, cfg, x, positions_for(b, s), return_kv=True
        )
        conv, ssm = states
        k, v = kv
        cache = tfm.pad_kv_cache(
            {"k": k, "v": v, "slot_pos": jnp.arange(s, dtype=jnp.int32)}, pad_to
        )
        cache.update(conv=conv, ssm=ssm)
        return _last_logits(params, cfg, h[:, -1:]), cache

    def decode_fn(params, cache, token, pos):
        x = tfm.embed_tokens(params, cfg, token[:, None])
        h, cache = hybrid.run_hybrid_decode(params, cfg, x, pos, cache)
        return _last_logits(params, cfg, h), cache

    def cache_specs_fn(batch, seq):
        return hybrid.hybrid_cache_specs(cfg, batch, seq)

    return Model(
        cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs_fn,
        lambda b, s: hybrid.hybrid_init_cache(cfg, b, s),
    )


# ---------------------------------------------------------------------------
# enc-dec (whisper)
# ---------------------------------------------------------------------------

def _encdec_model(cfg: ModelConfig) -> Model:
    specs = encdec.encdec_specs(cfg)

    def loss_fn(params, batch):
        enc = encdec.run_encoder(params, cfg, batch["frames"])
        h, _ = encdec.run_decoder_train(params, cfg, batch["tokens"], enc)
        return _final_loss(params, cfg, h, batch["targets"], 0.0)

    def prefill_fn(params, batch, pad_to=None):
        enc = encdec.run_encoder(params, cfg, batch["frames"])
        h, kv = encdec.run_decoder_train(params, cfg, batch["tokens"], enc, return_kv=True)
        k, v, ck, cv = kv
        s = batch["tokens"].shape[1]
        cache = tfm.pad_kv_cache(
            {"k": k, "v": v, "slot_pos": jnp.arange(s, dtype=jnp.int32)}, pad_to
        )
        cache.update(ck=ck, cv=cv)
        return _last_logits(params, cfg, h[:, -1:]), cache

    def decode_fn(params, cache, token, pos):
        h, cache = encdec.run_decoder_step(params, cfg, token, pos, cache)
        return _last_logits(params, cfg, h), cache

    def cache_specs_fn(batch, seq):
        return encdec.encdec_cache_specs(cfg, batch, seq)

    def init_cache_fn(batch, seq):
        shapes, _ = encdec.encdec_cache_specs(cfg, batch, seq)
        c = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
        c["slot_pos"] = jnp.full(shapes["slot_pos"].shape, -1, jnp.int32)
        return c

    return Model(cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs_fn, init_cache_fn)


def get_model(cfg: ModelConfig) -> Model:
    if cfg.kind == "encdec":
        return _encdec_model(cfg)
    if cfg.shared_attn_every:
        return _hybrid_model(cfg)
    if cfg.ssm is not None:
        return _ssm_model(cfg)
    return _decoder_model(cfg)  # dense / MoE / VLM
