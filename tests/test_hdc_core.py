"""Paper-core tests: HDC algebra, EM channel, OTA constellation search, classifier.

Includes hypothesis property tests on the HDC invariants and the end-to-end
reproduction checks against the paper's own numbers (Fig. 8 operating point,
Table I accuracy bands).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # prefer the real engine when installed
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic seeded fallback
    from _propcheck import given, settings, strategies as st

from repro.core import classifier, em, hypervector as hv, ota

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# hypervector algebra (hypothesis properties)
# ---------------------------------------------------------------------------

dims = st.integers(min_value=32, max_value=256).map(lambda d: d * 2)
dims32 = st.integers(min_value=1, max_value=12).map(lambda k: k * 32)  # packable
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=20, deadline=None)
@given(seeds, dims)
def test_bind_involutive(seed, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = hv.random_hv(k1, 1, d)[0]
    b = hv.random_hv(k2, 1, d)[0]
    assert np.array_equal(np.asarray(hv.bind(hv.bind(a, b), b)), np.asarray(a))


@settings(max_examples=20, deadline=None)
@given(seeds, dims, st.integers(min_value=-300, max_value=300))
def test_permute_roundtrip_and_distance_preserving(seed, d, shift):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = hv.random_hv(k1, 1, d)[0]
    b = hv.random_hv(k2, 1, d)[0]
    assert np.array_equal(
        np.asarray(hv.permute(hv.permute(a, shift), -shift)), np.asarray(a)
    )
    s_ab = hv.hamming_similarity(a, b[None])[0]
    s_pp = hv.hamming_similarity(hv.permute(a, shift), hv.permute(b, shift)[None])[0]
    assert float(abs(s_ab - s_pp)) < 1e-6


@settings(max_examples=20, deadline=None)
@given(seeds, dims, st.integers(min_value=1, max_value=5).map(lambda m: 2 * m + 1))
def test_majority_contains_inputs(seed, d, m):
    """Bundling preserves similarity: maj(q1..qm) closer to the inputs than chance.

    Tested on the mean over inputs, not the per-input min: the expected
    advantage is delta = C(m-1,(m-1)/2)/2^m per input (0.25 at m=3, ~0.12 at
    m=11), and the mean similarity concentrates with std ~0.1/sqrt(d), so
    mean > 0.5 + delta/2 holds at >5 sigma for every (d, m) this draws. A
    per-input min > 0.5 is NOT sound here — at m>=9, d~128 a single input
    dips below chance with ~1% probability per draw, i.e. the old assertion
    only ever passed by seed luck.
    """
    import math

    qs = hv.random_hv(jax.random.PRNGKey(seed), m, d)
    q = hv.majority(qs)
    sims = hv.hamming_similarity(q, qs)
    delta = math.comb(m - 1, (m - 1) // 2) / 2.0**m
    assert float(jnp.mean(sims)) > 0.5 + delta / 2, (m, d, sims)


@settings(max_examples=20, deadline=None)
@given(seeds, dims32)
def test_pack_unpack_roundtrip(seed, d):
    x = hv.random_hv(jax.random.PRNGKey(seed), 3, d)
    assert np.array_equal(np.asarray(hv.unpack(hv.pack(x), d)), np.asarray(x))


@settings(max_examples=10, deadline=None)
@given(seeds, dims32)
def test_packed_hamming_matches_unpacked(seed, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = hv.random_hv(k1, 2, d)
    p = hv.random_hv(k2, 5, d)
    dist = hv.hamming_distance_packed(hv.pack(q), hv.pack(p))
    sims = hv.hamming_similarity(q, p)
    np.testing.assert_allclose(np.asarray(1.0 - dist / d), np.asarray(sims), atol=1e-6)


def test_flip_bits_rate():
    x = jnp.zeros((200, 512), jnp.uint8)
    y = hv.flip_bits(KEY, x, 0.1)
    rate = float(jnp.mean(y))
    assert 0.08 < rate < 0.12


def test_majority_random_tiebreak_even_m():
    qs = hv.random_hv(KEY, 4, 4096)
    out = hv.majority(qs, key=jax.random.PRNGKey(7))
    counts = jnp.sum(qs.astype(jnp.int32), axis=0)
    ties = counts == 2
    # non-tie positions follow strict majority
    maj = (counts * 2 > 4).astype(jnp.uint8)
    assert np.array_equal(np.asarray(out[~ties]), np.asarray(maj[~ties]))
    # tie positions are ~Bernoulli(0.5)
    frac = float(jnp.mean(out[ties]))
    assert 0.4 < frac < 0.6


def test_even_m_tiebreak_convention_unified():
    """Repo-wide even-M convention: ties -> 0 (strict majority), identically in
    hv.majority (no key), hv.majority_packed, the kernel oracle, and the
    scale-out psum tally path."""
    from repro.kernels.majority.ref import majority_bundle_ref

    m = 4
    qs = hv.random_hv(KEY, m, 2048)
    want = (jnp.sum(qs.astype(jnp.int32), 0) * 2 > m).astype(jnp.uint8)
    assert np.array_equal(np.asarray(hv.majority(qs)), np.asarray(want))
    assert np.array_equal(
        np.asarray(hv.unpack(hv.majority_packed(hv.pack(qs)), 2048)), np.asarray(want)
    )
    assert np.array_equal(np.asarray(majority_bundle_ref(qs[:, None])[0]), np.asarray(want))
    # the serve path's vote emulation: int8 bipolar tally > 0
    tally = jnp.sum(2 * qs.astype(jnp.int8) - 1, axis=0)
    assert np.array_equal(np.asarray((tally > 0).astype(jnp.uint8)), np.asarray(want))


# ---------------------------------------------------------------------------
# packed algebra — bit-exactness against the unpacked ops
# ---------------------------------------------------------------------------

ms_any = st.integers(min_value=2, max_value=11)


@settings(max_examples=20, deadline=None)
@given(seeds, dims32, st.integers(min_value=-600, max_value=600))
def test_permute_packed_bit_exact(seed, d, shift):
    x = hv.random_hv(jax.random.PRNGKey(seed), 2, d)
    got = hv.unpack(hv.permute_packed(hv.pack(x), shift), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.permute(x, shift)))


@settings(max_examples=10, deadline=None)
@given(seeds, dims32, st.integers(min_value=2, max_value=8))
def test_permute_batch_packed_bit_exact(seed, d, m):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = hv.random_hv(k1, m, d)
    shifts = jax.random.randint(k2, (m,), -2 * d, 2 * d)
    got = hv.unpack(hv.permute_batch_packed(hv.pack(x), shifts), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.permute_batch(x, shifts)))


@settings(max_examples=15, deadline=None)
@given(seeds, dims32, ms_any)
def test_majority_packed_bit_exact(seed, d, m):
    qs = hv.random_hv(jax.random.PRNGKey(seed), m, d)
    got = hv.unpack(hv.majority_packed(hv.pack(qs)), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.majority(qs)))
    if m % 2 == 0:  # randomized tie-break also bit-exact on the same key
        k = jax.random.PRNGKey(seed ^ 0x5EED)
        got = hv.unpack(hv.majority_packed(hv.pack(qs), key=k), d)
        assert np.array_equal(np.asarray(got), np.asarray(hv.majority(qs, key=k)))


@settings(max_examples=15, deadline=None)
@given(seeds, dims32)
def test_bind_and_flip_packed_bit_exact(seed, d):
    k1, k2, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b = hv.random_hv(k1, 3, d), hv.random_hv(k2, 3, d)
    got = hv.unpack(hv.bind_packed(hv.pack(a), hv.pack(b)), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.bind(a, b)))
    got = hv.unpack(hv.flip_bits_packed(kf, hv.pack(a), 0.1), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.flip_bits(kf, a, 0.1)))


@settings(max_examples=10, deadline=None)
@given(seeds, dims32)
def test_flip_bits_per_rx_packed_bit_exact(seed, d):
    k1, kf = jax.random.split(jax.random.PRNGKey(seed))
    x = hv.random_hv(k1, 2, d)
    ber = jnp.array([0.0, 0.03, 0.25, 0.5])
    got = hv.unpack(hv.flip_bits_per_rx_packed(kf, hv.pack(x), ber), d)
    assert np.array_equal(np.asarray(got), np.asarray(hv.flip_bits_per_rx(kf, x, ber)))


def test_random_hv_packed_is_fair():
    r = hv.random_hv_packed(KEY, 200, 512)
    assert r.shape == (200, 16) and r.dtype == jnp.uint32
    rate = float(jnp.sum(jax.lax.population_count(r))) / (200 * 512)
    assert 0.48 < rate < 0.52, rate


@pytest.mark.parametrize("p", [0.0, 0.01, 0.1, 0.5])
def test_bernoulli_words_rate(p):
    mask = hv.bernoulli_words(jax.random.PRNGKey(3), p, (2000, 16))
    rate = float(jnp.sum(jax.lax.population_count(mask))) / (2000 * 16 * 32)
    assert abs(rate - p) < 0.01 + 0.05 * p, (p, rate)


# ---------------------------------------------------------------------------
# EM channel
# ---------------------------------------------------------------------------

def test_channel_deterministic_and_shapes():
    geom = em.PackageGeometry()
    h1 = em.channel_matrix(geom, 3, 64)
    h2 = em.channel_matrix(geom, 3, 64)
    assert h1.shape == (64, 3)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))  # quasi-static


def test_channel_rx_diversity():
    """Different receivers must see different superpositions (paper Fig. 6)."""
    h = em.channel_matrix(em.PackageGeometry(), 3, 16)
    phases = jnp.angle(h)
    spread = float(jnp.std(phases))
    assert spread > 0.3


# ---------------------------------------------------------------------------
# OTA constellation search
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ota_result():
    h = em.channel_matrix(em.PackageGeometry(), 3, 64)
    n0 = ota.default_n0(h)
    return ota.optimize_phases_exhaustive(h, n0), h, n0


def test_ota_operating_point(ota_result):
    """Paper Fig. 8: avg BER < 0.01 (dashed line), worst-case ~0.1, 64 RXs."""
    res, _, _ = ota_result
    assert float(res.avg_ber) <= 0.0105
    assert float(res.max_ber) <= 0.1
    assert bool(jnp.all(res.valid_per_rx))  # every RX has valid majority regions


def test_ota_phase_independence(ota_result):
    res, _, _ = ota_result
    # each TX uses two distinct phases from the 8-phase codebook
    assert res.phase_idx.shape == (3, 2)
    assert bool(jnp.all(res.phase_idx[:, 0] != res.phase_idx[:, 1]))


def test_ota_empirical_ber_matches_analytic(ota_result):
    """Monte-Carlo OTA transmission tracks the *per-symbol* analytic BER.

    The paper's Eq. (1) evaluates the erfc at the centroid distance, which
    UNDERESTIMATES the true error of asymmetric majority constellations (some
    symbols sit closer to the boundary than their centroid). The per-symbol
    refinement (`decision_metrics(method="symbol")`) is the tight prediction;
    the Monte-Carlo channel must match it. The gap between the two analytic
    models is reported in EXPERIMENTS.md §Reproduction-notes.
    """
    res, h, n0 = ota_result
    m, d = 3, 4096
    maj = ota.majority_labels(m)
    ber_sym, _ = ota.decision_metrics(res.symbols, maj, n0, method="symbol")
    queries = hv.random_hv(KEY, m, d)
    majq = hv.majority(queries)
    decoded = ota.simulate_ota_bundle(jax.random.PRNGKey(1), queries, h, res.phase_idx, n0)
    emp = np.asarray(jnp.mean(decoded != majq[None], axis=1))
    ana = np.asarray(ber_sym)
    assert abs(emp.mean() - ana.mean()) < 0.01, (emp.mean(), ana.mean())
    worst = ana.argmax()
    assert abs(emp[worst] - ana[worst]) < 0.05
    # Eq. (1) (centroid) is the optimistic bound the paper reports
    assert float(res.ber_per_rx.mean()) <= ana.mean() + 1e-6


def test_coordinate_search_scorer_jitted_once():
    """The M > 3 coordinate descent must reuse ONE traced scorer across its
    sweeps x TX Python loop (and across calls) instead of re-tracing
    _score_assignments per iteration."""
    h = em.channel_matrix(em.PackageGeometry(), 5, 4)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_coordinate(h, n0, jax.random.PRNGKey(0), sweeps=2)
    assert res.phase_idx.shape == (5, 2)
    assert bool(jnp.all(res.phase_idx[:, 0] != res.phase_idx[:, 1]))
    assert float(res.avg_ber) < 0.5
    cache_size = getattr(ota._score_assignments, "_cache_size", None)
    if cache_size is not None:  # jit cache introspection (present on all pins)
        n = cache_size()
        ota.optimize_phases_coordinate(h, n0, jax.random.PRNGKey(1), sweeps=2)
        assert cache_size() == n, "coordinate search re-traced the scorer"


def test_ber_scaling_with_rx_count():
    """Paper Fig. 9: average BER grows (weakly) with the number of RXs."""
    geom = em.PackageGeometry()
    bers = []
    for n_rx in (8, 64):
        h = em.channel_matrix(geom, 3, n_rx)
        res = ota.optimize_phases_exhaustive(h, ota.default_n0(h))
        bers.append(float(res.avg_ber))
    assert bers[1] >= bers[0] * 0.5  # joint optimization is harder at 64 RX


# ---------------------------------------------------------------------------
# classifier (Table I / Fig. 10 / Fig. 11)
# ---------------------------------------------------------------------------

CFG = classifier.HDCTaskConfig(n_trials=400)


def test_accuracy_vs_ber_robustness():
    """Paper Fig. 10: accuracy stays ~1 for BER <= 0.26 at M=1."""
    acc = classifier.run_accuracy(KEY, CFG, m=1, ber=0.26, bundling="baseline")
    assert float(acc) > 0.98


@pytest.mark.parametrize("m,lo,hi", [(1, 0.99, 1.0), (3, 0.93, 0.99), (5, 0.85, 0.95)])
def test_table1_baseline_bands(m, lo, hi):
    acc = float(classifier.run_accuracy(KEY, CFG, m=m, ber=0.01, bundling="baseline"))
    assert lo <= acc <= hi, (m, acc)


@pytest.mark.parametrize("m", [3, 5, 7])
def test_table1_permuted_near_perfect(m):
    acc = float(classifier.run_accuracy(KEY, CFG, m=m, ber=0.01, bundling="permuted"))
    assert acc >= 0.99, (m, acc)


def test_wireless_vs_ideal_gap_negligible():
    """Table I: the wireless channel costs <2% accuracy at any M <= 5."""
    for m in (1, 3, 5):
        ideal = float(classifier.run_accuracy(KEY, CFG, m=m, ber=0.0, bundling="baseline"))
        wirel = float(classifier.run_accuracy(KEY, CFG, m=m, ber=0.01, bundling="baseline"))
        assert ideal - wirel < 0.02, (m, ideal, wirel)


@pytest.mark.parametrize("bundling", ["baseline", "permuted"])
def test_classifier_modes_identical(bundling):
    """Packed trials and Pallas-kernel similarity return the BIT-identical
    accuracy as the unpacked jnp path on the same key — every dispatch computes
    the same integer bipolar dot before the same normalization."""
    cfg = classifier.HDCTaskConfig(n_trials=120)
    accs = {
        (rep, uk): float(classifier.run_accuracy(
            KEY, cfg, 5, 0.02, bundling, representation=rep, use_kernels=uk))
        for rep in ("unpacked", "packed") for uk in (False, True)
    }
    assert len(set(accs.values())) == 1, accs
