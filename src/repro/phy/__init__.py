"""PHY channel subsystem: the pluggable over-the-air link of the serve path.

See `repro.phy.channel` for the `Channel` interface, the three fidelity tiers
(``ideal`` / ``bsc`` / ``symbol``) and the `ChannelState` precharacterization
pytree that `core.scaleout` threads through the serve steps; both lookups go
through open registries (`register_channel` / `register_process`) so
out-of-tree tiers plug in without editing this package.

`repro.phy.process` upgrades the static snapshot to a time-varying
`ChannelProcess` (phase drift / block fading / off-mesh interferer) with an
online guard-symbol flip-rate monitor and the banded EM re-characterization
that closes the adaptation loop.
"""
from repro.phy.channel import (
    CHANNELS,
    BSCChannel,
    Channel,
    ChannelState,
    IdealChannel,
    SymbolChannel,
    awgn_decide,
    combo_index,
    get_channel,
    register_channel,
    state_from_ber,
    state_from_ota,
    state_shape_structs,
    state_spec,
)
from repro.phy.process import (
    PROCESSES,
    BlockFadingProcess,
    ChannelProcess,
    InterfererProcess,
    PhaseDriftProcess,
    ProcessState,
    StaticProcess,
    adaptive_rollout,
    get_process,
    monitor_band,
    pstate_shape_structs,
    pstate_spec,
    recharacterize,
    register_process,
    rollout,
    row_keys,
    set_quarantine,
)

__all__ = [
    "CHANNELS",
    "PROCESSES",
    "BSCChannel",
    "BlockFadingProcess",
    "Channel",
    "ChannelProcess",
    "ChannelState",
    "IdealChannel",
    "InterfererProcess",
    "PhaseDriftProcess",
    "ProcessState",
    "StaticProcess",
    "SymbolChannel",
    "adaptive_rollout",
    "awgn_decide",
    "combo_index",
    "get_channel",
    "get_process",
    "monitor_band",
    "pstate_shape_structs",
    "pstate_spec",
    "recharacterize",
    "register_channel",
    "register_process",
    "rollout",
    "row_keys",
    "set_quarantine",
    "state_from_ber",
    "state_from_ota",
    "state_shape_structs",
    "state_spec",
]
