"""Version-portable ambient-mesh lookup and shard_map.

* ``current_mesh()`` — the mesh installed by ``compat.set_mesh`` (or any mesh
  context manager), or None when there is none. Prefers
  ``jax.sharding.get_abstract_mesh``; on 0.4.x reads the thread-local physical
  mesh that the ``Mesh`` context manager sets.

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=...,
  check_vma=...)`` — the new-style (>= 0.6) ``jax.shard_map`` signature. On
  0.4.x it translates to ``jax.experimental.shard_map.shard_map``:
  ``axis_names`` (the *manual* axes) becomes its complement ``auto=``, and
  ``check_vma`` maps to the old name ``check_rep``.
"""
from __future__ import annotations

from typing import Callable, Set

import jax

from repro.compat import version as _v


def current_mesh():
    """The ambient (context-installed) mesh, or None if none is active.

    Never raises on empty/absent meshes — callers treat None as "no mesh":
    sharding constraints become no-ops.
    """
    if _v.has_get_abstract_mesh():
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return None
        return mesh
    from jax._src import mesh as _mesh_lib  # 0.4.x thread-local mesh context

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


def current_mesh_axis_sizes() -> dict | None:
    """{axis_name: size} of the ambient mesh, or None outside any mesh."""
    mesh = current_mesh()
    if mesh is None:
        return None
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    axis_names: Set[str] | None = None,
    check_vma: bool = False,
) -> Callable:
    """New-style shard_map on every supported JAX version.

    axis_names: the mesh axes that are *manual* inside `f` (default: all).
    check_vma: varying-mesh-axes checking (old name: check_rep).
    """
    names = frozenset(mesh.axis_names if axis_names is None else axis_names)
    unknown = names - frozenset(mesh.axis_names)
    if unknown:
        raise ValueError(f"axis_names {sorted(unknown)} not in mesh axes {mesh.axis_names}")
    if _v.has_top_level_shard_map():
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=set(names),
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
        auto=frozenset(mesh.axis_names) - names,
    )
