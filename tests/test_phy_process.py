"""Living channels: time-varying PHY processes + closed-loop adaptation.

Pins the tentpole contracts: StaticProcess through the process-threading serve
is BIT-identical to the process-free serve on every tier x representation x
collective; process evolution is a pytree-stable `lax.scan` (one serve
compile for N steps); the per-row `fold_in(fold_in(key, t), rx)` schedule
makes evolution mesh-placement-invariant ((1,1) == (2,4)); the guard-symbol
monitor + analytic band + EM re-fit close the loop (drift that costs the
open-loop serve >= 3 accuracy points is recovered to within 1 point); and
quarantine / M-drop link-level actions are value-correct.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_test_mesh
from repro import phy
from repro.core import classifier, hypervector as hv, scaleout

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _cfg(**kw):
    base = dict(n_classes=40, dim=512, m_tx=3, n_rx_cores=4, batch=8,
                use_kernels=False, noise="exact")
    base.update(kw)
    return scaleout.ScaleOutConfig(**base)


@pytest.fixture(scope="module")
def sym_state():
    return scaleout.precharacterize_state(_cfg(channel="symbol"))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_process_registry():
    assert sorted(phy.PROCESSES) == [
        "block_fading", "interferer", "phase_drift", "static"
    ]
    proc = phy.get_process("phase_drift", sigma=0.2)
    assert isinstance(proc, phy.PhaseDriftProcess) and proc.sigma == 0.2
    with pytest.raises(ValueError, match="unknown channel process"):
        phy.get_process("solar_flare")
    with pytest.raises(ValueError, match="already registered"):
        phy.register_process(phy.StaticProcess)

    @dataclasses.dataclass(frozen=True)
    class Burst(phy.StaticProcess):
        name = "burst"

    try:
        phy.register_process(Burst)
        assert isinstance(phy.get_process("burst"), Burst)
    finally:
        del phy.PROCESSES["burst"]


def test_register_channel_rejects_duplicates():
    assert sorted(phy.CHANNELS) == ["bsc", "ideal", "symbol"]
    with pytest.raises(ValueError, match="already registered"):
        phy.register_channel(phy.get_channel("bsc"))


# ---------------------------------------------------------------------------
# ProcessState pytree + StaticProcess identity
# ---------------------------------------------------------------------------

def test_pstate_shape_structs_match_init(sym_state):
    p0 = phy.StaticProcess().init(sym_state)
    structs = phy.pstate_shape_structs(sym_state.n_rx, sym_state.m_tx)
    ref = jax.tree_util.tree_structure(p0)
    assert jax.tree_util.tree_structure(structs) == ref
    for leaf, struct in zip(jax.tree_util.tree_leaves(p0),
                            jax.tree_util.tree_leaves(structs)):
        assert leaf.shape == struct.shape, (leaf.shape, struct.shape)
        assert leaf.dtype == struct.dtype, (leaf.dtype, struct.dtype)
    assert p0.n_rx == sym_state.n_rx and p0.m_tx == sym_state.m_tx


def test_static_process_serve_bit_identity(sym_state):
    """The process-threading serve under StaticProcess == the process-free
    serve, bitwise, across every channel x collective x representation that
    tier admits — the 'channels that do not move cost nothing' guarantee."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    grid = ([("bsc", c) for c in ("psum", "psum_packed", "rs_ag")]
            + [("symbol", "psum")])
    for channel, coll in grid:
        for rep in ("unpacked", "packed"):
            cfg = _cfg(channel=channel, collective=coll, representation=rep,
                       permuted=True)
            state = (sym_state if channel == "symbol"
                     else phy.state_from_ber(
                         jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx))
            book = classifier.make_codebook(
                jax.random.PRNGKey(0),
                classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
            protos = hv.pack(book) if cfg.packed else book
            _, q = scaleout.make_queries(jax.random.PRNGKey(1), cfg, book, 1)
            serve = scaleout.make_ota_serve(mesh, cfg)
            pserve = scaleout.make_ota_serve(mesh, cfg,
                                             process=phy.StaticProcess())
            pstate = phy.StaticProcess().init(state)
            pkey = jax.random.PRNGKey(9)
            for step in range(3):
                key = jax.random.PRNGKey(100 + step)
                wp, ws = serve(protos, q, state, key)
                gp, gs, pstate = pserve(protos, q, pstate, key, pkey)
                np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp)), \
                    (channel, coll, rep)
                np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
            assert int(pstate.t) == 3
            # the channel itself must not have moved
            for a, b in zip(jax.tree_util.tree_leaves(pstate.chan),
                            jax.tree_util.tree_leaves(state)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# evolution: scan stability, one compile, mesh invariance
# ---------------------------------------------------------------------------

def test_rollout_is_pytree_stable_scan(sym_state):
    proc = phy.PhaseDriftProcess(sigma=0.2, guard_dims=16)
    p0 = proc.init(sym_state)
    final, traj = phy.rollout(proc, p0, jax.random.PRNGKey(3), 5)
    assert int(final.t) == 5
    assert (jax.tree_util.tree_structure(final)
            == jax.tree_util.tree_structure(p0))
    for leaf0, leafT in zip(jax.tree_util.tree_leaves(p0),
                            jax.tree_util.tree_leaves(traj)):
        assert leafT.shape == (5,) + leaf0.shape
    # drift really moved the channel: true BER departs from characterization
    assert float(jnp.max(jnp.abs(traj.chan.ber[-1] - sym_state.ber))) > 0.0


def test_process_serve_compiles_once_across_steps(sym_state):
    """N serve steps over an EVOLVING pstate reuse one compiled program —
    the pytree (shapes, dtypes, structure) is step-invariant by design."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = _cfg(channel="symbol")
    proc = phy.PhaseDriftProcess(sigma=0.2, guard_dims=16)
    book = classifier.make_codebook(
        jax.random.PRNGKey(0),
        classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
    protos = book
    _, q = scaleout.make_queries(jax.random.PRNGKey(1), cfg, book, 1)
    pserve = scaleout.make_ota_serve(mesh, cfg, process=proc)
    pstate = proc.init(sym_state)
    pkey = jax.random.PRNGKey(9)
    # first call places the freshly-built pstate (host arrays), second sees
    # the serve's own output sharding — from there the program is cached
    for step in range(2):
        _, _, pstate = pserve(protos, q, pstate, jax.random.PRNGKey(step), pkey)
    warm = pserve._cache_size()
    assert warm <= 2
    for step in range(2, 6):
        _, _, pstate = pserve(protos, q, pstate, jax.random.PRNGKey(step), pkey)
    assert int(pstate.t) == 6
    assert pserve._cache_size() == warm


def test_evolution_mesh_placement_invariant():
    """The per-row fold_in(fold_in(process_key, t), rx) schedule depends only
    on GLOBAL row ids and the step count — so a (1,1) mesh and a (2,4) mesh
    (RX state sharded 2-per-device, batch sharded over data) must evolve
    bit-identical process state: same phases, same true BERs, same guard
    estimates. (Per-query decode noise folds the DATA shard position, so
    predictions are per-mesh streams by design — the serve RNG contract.)"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, classifier
    cfg = scaleout.ScaleOutConfig(
        n_classes=40, dim=512, m_tx=3, n_rx_cores=8, batch=8,
        use_kernels=False, noise="exact", channel="symbol")
    state = scaleout.precharacterize_state(cfg)
    book = classifier.make_codebook(
        jax.random.PRNGKey(0),
        classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
    protos = book
    proc = phy.PhaseDriftProcess(sigma=0.3, guard_dims=16)
    outs = []
    for shape in ((1, 1), (2, 4)):
        mesh = make_mesh(shape, ("data", "model"))
        # same class draws either way — only the TX-slot layout differs
        _, q = scaleout.make_queries(jax.random.PRNGKey(1), cfg, book, shape[1])
        pserve = scaleout.make_ota_serve(mesh, cfg, process=proc)
        pstate = proc.init(state)
        for step in range(3):
            _, _, pstate = pserve(protos, q, pstate,
                                  jax.random.PRNGKey(100 + step),
                                  jax.random.PRNGKey(9))
        outs.append((np.asarray(pstate.phase), np.asarray(pstate.chan.ber),
                     np.asarray(pstate.est)))
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(a, b)
    print("OK")
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"


# ---------------------------------------------------------------------------
# valid=False audit
# ---------------------------------------------------------------------------

def test_state_from_ber_is_marked_synthetic(sym_state):
    synth = phy.state_from_ber(jnp.zeros((4,)), 3)
    assert not bool(jnp.any(synth.valid))
    assert bool(jnp.all(sym_state.valid))
    with pytest.raises(ValueError, match="all-False"):
        classifier.run_accuracy(
            jax.random.PRNGKey(0),
            classifier.HDCTaskConfig(n_classes=8, dim=128, n_trials=4),
            3, 0.0, "permuted", channel="symbol", state=synth)


def test_invalid_rows_keep_analytic_ber_under_evolution():
    """Synthetic (valid=False) rows must NOT have their BER overwritten by
    the per-symbol re-estimate — there are no physics to estimate from."""
    synth = phy.state_from_ber(jnp.full((4,), 0.07), 3)
    proc = phy.PhaseDriftProcess(sigma=0.5, guard_dims=8)
    p0 = proc.init(synth)
    final, _ = phy.rollout(proc, p0, jax.random.PRNGKey(0), 3)
    np.testing.assert_array_equal(np.asarray(final.chan.ber),
                                  np.full((4,), 0.07, np.float32))
    np.testing.assert_array_equal(np.asarray(final.est), np.asarray(p0.est))


# ---------------------------------------------------------------------------
# monitor + band + re-fit: the closed loop
# ---------------------------------------------------------------------------

def test_recharacterize_recovers_common_phase_drift(sym_state):
    """Per-RX common phase rotation distorts nothing the EM re-fit cannot
    relearn: after recharacterize, the refreshed decision BER returns to the
    characterized level even though the constellations have rotated."""
    proc = phy.PhaseDriftProcess(sigma=0.3, guard_dims=32)
    p0 = proc.init(sym_state)
    drifted, _ = phy.rollout(proc, p0, jax.random.PRNGKey(1), 8)
    assert float(jnp.max(drifted.chan.ber)) > float(jnp.max(sym_state.ber)) + 0.02
    refit = phy.recharacterize(drifted)
    assert bool(jnp.all(refit.chan.valid))
    # back to (near) characterized quality: the re-estimated decision BER
    # lands at the symbol-method noise floor, far below the drifted level
    assert float(jnp.max(refit.chan.ber)) < 0.01
    assert float(jnp.max(refit.chan.ber)) < 0.2 * float(jnp.max(drifted.chan.ber))
    # masked refit touches only the masked rows
    mask = jnp.arange(sym_state.n_rx) == 0
    part = phy.recharacterize(drifted, mask)
    assert float(part.chan.ber[0]) < 0.01
    np.testing.assert_array_equal(np.asarray(part.chan.ber[1:]),
                                  np.asarray(drifted.chan.ber[1:]))


def test_monitor_band_envelope(sym_state):
    p0 = phy.StaticProcess().init(sym_state)
    band = phy.monitor_band(p0, cap=0.05)
    assert band.shape == (sym_state.n_rx,)
    b = np.asarray(band)
    assert (b >= np.asarray(sym_state.ber) - 1e-6).all()  # band sits above BER
    assert (b <= 0.05 + 1e-6).all()                        # cap binds
    assert (b >= 0.02 - 1e-6).all()                        # floor binds


def test_closed_loop_recovers_drift_accuracy(sym_state):
    """The acceptance demo, scaled to test time: phase drift costs the
    open-loop symbol serve >= 3 accuracy points in the tail window; the
    banded monitor + EM re-fit recovers to within 1 point of no-drift."""
    cfg16 = _cfg(n_classes=64, n_rx_cores=16, channel="symbol")
    state = scaleout.precharacterize_state(cfg16)
    tcfg = classifier.HDCTaskConfig(n_classes=64, dim=512, n_trials=128)
    key = jax.random.PRNGKey(7)
    proc = phy.PhaseDriftProcess(sigma=0.15, alpha=0.5, guard_dims=128)
    n_steps, tail = 25, 8
    base = classifier.run_drift_sweep(key, tcfg, 3, state,
                                      phy.StaticProcess(), 1)
    static = classifier.run_drift_sweep(key, tcfg, 3, state, proc, n_steps)
    adapt = classifier.run_drift_sweep(key, tcfg, 3, state, proc, n_steps,
                                       adaptive=True, patience=1,
                                       band_kwargs={"cap": 0.05})
    baseline = base["acc"][0]
    drop = 100.0 * (baseline - np.mean(static["acc"][-tail:]))
    gap = 100.0 * (baseline - np.mean(adapt["acc"][-tail:]))
    assert drop >= 3.0, (drop, static["acc"])
    assert gap <= 1.0, (gap, adapt["acc"])
    assert adapt["n_refits"] > 0


# ---------------------------------------------------------------------------
# link-level actions: quarantine + M-drop
# ---------------------------------------------------------------------------

def test_quarantine_excludes_core_classes(sym_state):
    """A quarantined core's class sub-shard must never win the top-1: with
    core 0 quarantined, no prediction lands in its class range; with an
    all-False mask the serve is value-identical to no mask."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = _cfg(channel="symbol")
    book = classifier.make_codebook(
        jax.random.PRNGKey(0),
        classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
    protos = book
    _, q = scaleout.make_queries(jax.random.PRNGKey(1), cfg, book, 1)
    proc = phy.StaticProcess()
    pserve = scaleout.make_ota_serve(mesh, cfg, process=proc)
    key, pkey = jax.random.PRNGKey(5), jax.random.PRNGKey(9)

    p_open = proc.init(sym_state)
    serve = scaleout.make_ota_serve(mesh, cfg)
    wp, _ = serve(protos, q, sym_state, key)
    gp, _, _ = pserve(protos, q, p_open, key, pkey)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))

    qmask = jnp.arange(cfg.n_rx_cores) == 0
    p_quar = phy.set_quarantine(p_open, qmask)
    qp, _, _ = pserve(protos, q, p_quar, key, pkey)
    per_core = cfg.n_classes // cfg.n_rx_cores
    assert (np.asarray(qp) >= per_core).all(), np.asarray(qp)


def test_m_active_validation_and_oracle():
    cfg = _cfg(m_active=2)
    with pytest.raises(ValueError, match="odd"):
        scaleout.make_ota_serve(make_test_mesh((1, 1), ("data", "model")), cfg)
    with pytest.raises(ValueError, match="vote-wire"):
        scaleout.make_ota_serve(make_test_mesh((1, 1), ("data", "model")),
                                _cfg(channel="symbol", m_active=1))
    # M-drop to 1 on a clean link == the m_act=1 oracle, and the bundle is
    # exactly TX0's query (no other voters)
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = _cfg(m_active=1, permuted=True)
    state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
    book = classifier.make_codebook(
        jax.random.PRNGKey(0),
        classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim))
    protos = book
    _, q = scaleout.make_queries(jax.random.PRNGKey(1), cfg, book, 1)
    serve = scaleout.make_ota_serve(mesh, cfg)
    pred, sim = serve(protos, q, state, jax.random.PRNGKey(2))
    want_p, want_s = scaleout.serve_reference(cfg, protos, q)
    np.testing.assert_array_equal(np.asarray(pred)[:, :1],
                                  np.asarray(want_p)[:, :1])
    np.testing.assert_allclose(np.asarray(sim)[:, :1],
                               np.asarray(want_s)[:, :1], atol=1e-5)


def test_adaptive_rollout_trips_only_out_of_band_rows(sym_state):
    """adaptive_rollout's trip log is per-row: rows whose estimate stays in
    band never re-fit, and every re-fit resets its row's patience counter
    (no trip on consecutive steps unless the band is exceeded again)."""
    proc = phy.PhaseDriftProcess(sigma=0.15, alpha=0.5, guard_dims=64)
    p0 = proc.init(sym_state)
    _, _, trips = phy.adaptive_rollout(
        proc, p0, jax.random.PRNGKey(2), 12, patience=2,
        band_kwargs={"cap": 0.05})
    t = np.asarray(trips)
    assert t.shape == (12, sym_state.n_rx)
    assert t.any()
    # patience=2: a row can trip at most every other step
    assert not (t[1:] & t[:-1]).any()
