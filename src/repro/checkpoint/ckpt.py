"""Fault-tolerant checkpointing: atomic, checksummed, keep-k, elastic re-shard.

Layout: <dir>/step_<k>/  — one .npy per pytree leaf (path-flattened names) plus a
manifest.json holding the treedef, shapes, dtypes, per-leaf CRC32 checksums and
the data-pipeline state. Writes go to <dir>/.tmp_step_<k> and are os.replace'd
into place, so a killed writer never leaves a half-checkpoint that restore would
pick up (restart safety). `keep` prunes old steps after a successful commit.

Restore is defensive: a missing/corrupt manifest, a leaf file that is absent,
truncated or bit-flipped (checksum mismatch), or a shape/dtype drift against the
manifest all raise `CheckpointError` with the offending step and leaf named —
never a deep pytree-mismatch traceback from inside `jax.tree` — so a crashed
restore says WHAT is broken and the caller can fall back to an earlier step
(`all_steps` lists only directories with a committed manifest).

Elastic restore: leaves are loaded host-side and re-placed with `jax.device_put`
against the *current* mesh's NamedShardings (computed from the same logical-axes
tree by the rules engine) — a checkpoint written on any mesh restores onto any
other mesh, including a different device count.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any

import jax
import numpy as np

from repro.compat import tree_flatten_with_path


class CheckpointError(RuntimeError):
    """A checkpoint is unreadable: missing, truncated, corrupt or mismatched.

    Carries a human-actionable message naming the step and leaf; callers that
    keep multiple steps catch this and fall back to `latest_step` minus one.
    """


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save_checkpoint(directory: str, step: int, tree: Any, extra: dict | None = None, keep: int = 3) -> str:
    leaves, paths, _ = _flatten(tree)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):  # leftover from a killed writer — never committed
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for leaf, path in zip(leaves, paths):
        arr = np.asarray(jax.device_get(leaf))
        fname = path.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"].append({"path": path, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "crc32": crc})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return max(steps) if steps else None


def _load_manifest(path: str, step: int) -> dict:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.isdir(path) or not os.path.exists(mpath):
        raise CheckpointError(f"no committed checkpoint at step {step}: {path}")
    try:
        with open(mpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(
            f"checkpoint step {step}: manifest.json unreadable ({e})"
        ) from e


def _load_leaf(path: str, step: int, entry: dict) -> np.ndarray:
    """One leaf, verified against its manifest record before it is trusted."""
    fpath = os.path.join(path, entry["file"])
    if not os.path.exists(fpath):
        raise CheckpointError(
            f"checkpoint step {step}: leaf {entry['path']!r} file missing "
            f"({entry['file']})"
        )
    with open(fpath, "rb") as f:
        data = f.read()
    crc = entry.get("crc32")  # pre-checksum checkpoints: skip the CRC gate
    if crc is not None and zlib.crc32(data) != crc:
        raise CheckpointError(
            f"checkpoint step {step}: leaf {entry['path']!r} is corrupt "
            f"(CRC mismatch — truncated or bit-flipped {entry['file']})"
        )
    try:
        arr = np.load(os.path.join(path, entry["file"]))
    except Exception as e:
        raise CheckpointError(
            f"checkpoint step {step}: leaf {entry['path']!r} failed to "
            f"parse ({e})"
        ) from e
    if list(arr.shape) != entry["shape"] or str(arr.dtype) != entry["dtype"]:
        raise CheckpointError(
            f"checkpoint step {step}: leaf {entry['path']!r} is "
            f"{arr.shape} {arr.dtype}, manifest says "
            f"{tuple(entry['shape'])} {entry['dtype']}"
        )
    return arr


def restore_checkpoint(directory: str, step: int, like: Any, shardings: Any | None = None):
    """Restore into the structure of `like` (a pytree of arrays/ShapeDtypeStructs).

    `shardings`: optional matching pytree of NamedShardings for elastic placement
    on the current mesh; None -> plain host arrays. Raises `CheckpointError`
    (never a raw pytree/IO traceback) when the checkpoint is missing, truncated,
    corrupt, or does not cover `like`'s leaves.
    """
    path = os.path.join(directory, f"step_{step}")
    manifest = _load_manifest(path, step)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    _, paths, treedef = _flatten(like)
    missing = [p for p in paths if p not in by_path]
    if missing:
        raise CheckpointError(
            f"checkpoint step {step} does not cover the requested structure; "
            f"missing leaves: {missing}"
        )
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(paths)
    )
    loaded = []
    for p, sh in zip(paths, shard_leaves):
        arr = _load_leaf(path, step, by_path[p])
        loaded.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, loaded), manifest["extra"]
