"""Serving throughput: static batch-of-one engine vs continuous batching.

  PYTHONPATH=src python -m benchmarks.serving [--fast]

Offered load is a fixed set of mixed-length requests, all queued at t=0, so
request latency includes queueing — the quantity continuous batching improves.
The static baseline is the one-compile-per-prompt-shape ``Engine`` serving one
request per generate (mixed lengths defeat whole-batch prefill); continuous is
the slot-ring ``ContinuousEngine`` behind the ``Scheduler``. Both paths are
warmed first so the numbers measure execution, not compiles, and the greedy
outputs are cross-checked token-identical before timing is reported.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save, timed


def _pcts(lat: list[float]) -> dict:
    a = np.asarray(lat)
    return {"p50_ms": float(np.percentile(a, 50) * 1e3),
            "p95_ms": float(np.percentile(a, 95) * 1e3),
            "mean_ms": float(a.mean() * 1e3)}


def run(arch: str = "tinyllama-1.1b", n_requests: int = 24, slots: int = 4,
        max_new: int = 16, lengths: tuple = (16, 32, 64), seed: int = 0,
        quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import get_model, init_params
    from repro.serving import ContinuousEngine, Engine, Scheduler, ServeConfig

    cfg = configs.get_smoke(arch)
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    rng = np.random.default_rng(seed)
    req_lens = [int(lengths[i % len(lengths)]) for i in range(n_requests)]
    rng.shuffle(req_lens)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, (L,)), jnp.int32)
               for L in req_lens]
    scfg = ServeConfig(max_new=max_new, temperature=0.0)

    # -- static baseline: sequential batch-of-one generates -------------------
    static = Engine(model, scfg)
    for L in sorted(set(req_lens)):                       # warm compiles
        p = prompts[req_lens.index(L)]
        jax.block_until_ready(static.generate(params, {"tokens": p[None]}))
    static_out, static_lat = [], []
    t0 = time.monotonic()
    for p in prompts:
        toks, _ = timed(static.generate, params, {"tokens": p[None]})
        static_out.append(np.asarray(toks)[0])
        static_lat.append(time.monotonic() - t0)          # incl. queueing behind earlier reqs
    static_wall = time.monotonic() - t0

    # -- continuous: slot ring behind the scheduler ---------------------------
    eng = ContinuousEngine(model, scfg, num_slots=slots,
                           max_prompt_len=max(req_lens))
    warm = Scheduler(eng, params)                         # throwaway: compile everything
    for L in sorted(set(req_lens)):
        warm.submit(jnp.zeros((L,), jnp.int32), max_new=min(2, max_new))
    warm.run(timeout=600)

    sched = Scheduler(eng, params)
    t0 = time.monotonic()
    rids = [sched.submit(p) for p in prompts]
    sched.run(timeout=600)
    cont_wall = time.monotonic() - t0
    cont = [sched.results[r] for r in rids]
    cont_lat = [c.latency for c in cont]

    identical = all(
        np.array_equal(np.asarray(c.tokens), s) for c, s in zip(cont, static_out)
    )
    n_tok = n_requests * max_new
    out = {
        "arch": arch, "n_requests": n_requests, "slots": slots,
        "max_new": max_new, "lengths": sorted(set(req_lens)),
        "token_identical": identical,
        "static": {"wall_s": static_wall, "tok_per_s": n_tok / static_wall,
                   "latency": _pcts(static_lat)},
        "continuous": {"wall_s": cont_wall, "tok_per_s": n_tok / cont_wall,
                       "decode_steps": sched.steps,
                       "latency": _pcts(cont_lat)},
        "speedup": static_wall / cont_wall,
    }
    if not quiet:
        print(f"{n_requests} reqs x {max_new} new (lens {out['lengths']}, "
              f"{slots} slots), token-identical={identical}")
        for name in ("static", "continuous"):
            r = out[name]
            print(f"  {name:>10}: {r['wall_s']:.2f}s  {r['tok_per_s']:.1f} tok/s  "
                  f"p50 {r['latency']['p50_ms']:.0f}ms  p95 {r['latency']['p95_ms']:.0f}ms")
        print(f"  speedup: {out['speedup']:.2f}x")
    save("serving", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--fast", action="store_true", help="fewer/shorter requests")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.fast:
        run(args.arch, n_requests=8, slots=args.slots, max_new=8,
            lengths=(16, 32), seed=args.seed)
    else:
        run(args.arch, slots=args.slots, seed=args.seed)


if __name__ == "__main__":
    main()
