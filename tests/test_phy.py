"""The pluggable PHY channel subsystem (`repro.phy`).

Pins the three guarantees the serve refactor rests on:

* ``bsc`` is bit-identical to the historical inline serve noise — same RNG
  fold schedule (`fold_in(key, dpos)` then `fold_in(., rx_base + i)`), same
  `ota_noise` flips — so swapping the channel layer in changed NOTHING for
  the default tier (the "parity vs current main" acceptance criterion).
* ``symbol`` is the real physics: its serve decode equals a host-level
  re-derivation from the ChannelState bit-for-bit, and its Monte-Carlo per-RX
  bit-flip rates match the analytic predictions of `ota.decision_metrics`
  (tight per-symbol method; Eq. 1 as the reported approximation).
* the ChannelState pytree is structurally consistent across its three
  constructors (`state_from_ota` / `state_from_ber` / `state_shape_structs`)
  and its sharding spec, so the same compiled serve accepts any of them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_test_mesh

from repro import phy
from repro.core import em, hypervector as hv, ota, scaleout
from repro.distributed import collectives

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_state():
    """Real precharacterization of a reduced 3-TX / 16-RX system (exhaustive
    phase search, same pipeline as `scaleout.precharacterize_state`)."""
    geom = em.PackageGeometry()
    h = em.channel_matrix(geom, 3, 16)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_exhaustive(h, n0)
    return phy.state_from_ota(res, h), res, h, n0


# ---------------------------------------------------------------------------
# ChannelState pytree + registry
# ---------------------------------------------------------------------------

def test_channel_state_constructors_agree(small_state):
    state, res, h, n0 = small_state
    synth = phy.state_from_ber(jnp.zeros((16,)), 3)
    structs = phy.state_shape_structs(16, 3)
    ref = jax.tree_util.tree_structure(state)
    assert jax.tree_util.tree_structure(synth) == ref
    assert jax.tree_util.tree_structure(structs) == ref
    for leaf, struct in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(structs)):
        assert leaf.shape == struct.shape, (leaf.shape, struct.shape)
        assert leaf.dtype == struct.dtype, (leaf.dtype, struct.dtype)
    assert state.n_rx == 16 and state.m_tx == 3
    # the state's centroids are exactly the shared ota helper's
    maj = ota.majority_labels(3)
    c0, c1 = ota.majority_centroids(res.symbols, maj)
    np.testing.assert_array_equal(np.asarray(state.c0), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(state.c1), np.asarray(c1))


def test_get_channel_registry():
    assert sorted(phy.CHANNELS) == ["bsc", "ideal", "symbol"]
    assert phy.get_channel("bsc").wire == "votes"
    assert phy.get_channel("symbol").wire == "combo"
    with pytest.raises(ValueError, match="unknown channel tier"):
        phy.get_channel("fading")


def test_symbol_rejects_vote_collectives():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=32, dim=512, m_tx=3, n_rx_cores=4, batch=4,
        channel="symbol", collective="psum_packed",
    )
    with pytest.raises(ValueError, match="combo-index psum"):
        scaleout.make_ota_serve(mesh, cfg)


def test_combo_index_is_the_constellation_column(small_state):
    """`symbols[:, combo_index(bits)]` == the per-TX complex field sum — the
    lossless re-hosting of the analog superposition the combo psum relies on."""
    state, res, h, _ = small_state
    bits = hv.random_hv(KEY, 3, 256)                      # [M, d]
    combo = phy.combo_index(bits, axis=0)                 # [d]
    np.testing.assert_array_equal(
        np.asarray(combo),
        np.asarray(jnp.sum(bits.astype(jnp.int32) * (2 ** jnp.arange(3))[:, None], 0)),
    )
    phases = ota.phase_codebook()[res.phase_idx]          # [M, 2]
    sel = jnp.where(bits.astype(bool), phases[:, 1:], phases[:, :1])  # [M, d]
    manual = jnp.einsum("nm,md->nd", h, jnp.exp(1j * sel))            # [N, d]
    np.testing.assert_allclose(
        np.asarray(state.symbols[:, combo]), np.asarray(manual), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# bsc tier: bit-identical to the pre-phy inline serve noise
# ---------------------------------------------------------------------------

def test_bsc_tier_pins_historical_rng_schedule():
    """The refactored serve's default tier must reproduce the OLD inline
    dataflow exactly: bundle by majority vote, then core i flips the bundle
    with `ota_noise(fold_in(fold_in(key, dpos), rx_base + i), ., ber[i])` and
    searches its class sub-shard. This oracle IS that old code path — bitwise
    parity here is the `channel="bsc"` vs current-main acceptance criterion."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=32, dim=512, m_tx=3, n_rx_cores=4, batch=16, use_kernels=True
    )
    protos = hv.random_hv(KEY, cfg.n_classes, cfg.dim)
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 1)
    ber = jnp.array([0.01, 0.08, 0.0, 0.2], jnp.float32)
    state = phy.state_from_ber(ber, cfg.m_tx)
    key = jax.random.PRNGKey(2)
    pred, sim = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)

    q_act = queries.reshape(cfg.batch, -1, cfg.dim)[:, : cfg.m_tx]
    bundled = (2 * jnp.sum(q_act.astype(jnp.int32), 1) > cfg.m_tx).astype(jnp.uint8)
    kq = jax.random.fold_in(key, 0)  # dpos = 0 on the 1-wide data axis
    c_core = cfg.n_classes // cfg.n_rx_cores
    sims = []
    for i in range(cfg.n_rx_cores):
        q_i = collectives.ota_noise(jax.random.fold_in(kq, i), bundled, ber[i])
        p_i = protos[i * c_core:(i + 1) * c_core]
        sims.append(jnp.einsum("bd,cd->bc",
                               2.0 * q_i.astype(jnp.float32) - 1,
                               2.0 * p_i.astype(jnp.float32) - 1))
    sims = jnp.concatenate(sims, axis=1)  # [B, C]
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(jnp.argmax(sims, -1)))
    np.testing.assert_allclose(
        np.asarray(sim),
        np.asarray(jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5), rtol=1e-6)


def test_ideal_tier_matches_noise_free_reference():
    """`channel="ideal"` ignores a nonzero-BER state entirely."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=32, dim=512, m_tx=3, n_rx_cores=4, batch=8,
        channel="ideal", use_kernels=True,
    )
    protos = hv.random_hv(KEY, cfg.n_classes, cfg.dim)
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 1)
    state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.4), cfg.m_tx)
    pred, sim = scaleout.make_ota_serve(mesh, cfg)(
        protos, queries, state, jax.random.PRNGKey(2))
    rp, rs = scaleout.serve_reference(cfg, protos, queries)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(sim), np.asarray(rs), rtol=1e-6)


# ---------------------------------------------------------------------------
# symbol tier: serve decode == host physics, Monte-Carlo BER == analytic
# ---------------------------------------------------------------------------

def test_symbol_serve_matches_host_oracle(small_state):
    """The in-graph symbol tier (combo psum + constellation + AWGN + decision)
    equals a host re-derivation from the same ChannelState bit-for-bit, and
    the packed representation (decode bits, then pack) matches unpacked."""
    state, _, _, _ = small_state
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=32, dim=512, m_tx=3, n_rx_cores=16, batch=8,
        channel="symbol", use_kernels=True,
    )
    protos = hv.random_hv(KEY, cfg.n_classes, cfg.dim)
    _, queries = scaleout.make_queries(jax.random.PRNGKey(1), cfg, protos, 1)
    key = jax.random.PRNGKey(2)
    pred, sim = scaleout.make_ota_serve(mesh, cfg)(protos, queries, state, key)

    q_act = queries.reshape(cfg.batch, -1, cfg.dim)[:, : cfg.m_tx]
    combo = phy.combo_index(q_act, axis=1)                # [B, d]
    kq = jax.random.fold_in(key, 0)
    c_core = cfg.n_classes // cfg.n_rx_cores
    sims = []
    for i in range(cfg.n_rx_cores):
        q_i = phy.awgn_decide(jax.random.fold_in(kq, i), state.symbols[i][combo],
                              state.c0[i], state.c1[i], state.n0)
        p_i = protos[i * c_core:(i + 1) * c_core]
        sims.append(jnp.einsum("bd,cd->bc",
                               2.0 * q_i.astype(jnp.float32) - 1,
                               2.0 * p_i.astype(jnp.float32) - 1))
    sims = jnp.concatenate(sims, axis=1)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(jnp.argmax(sims, -1)))
    np.testing.assert_allclose(
        np.asarray(sim),
        np.asarray(jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5), rtol=1e-6)

    cfg_p = dataclasses.replace(cfg, representation="packed")
    _, queries_p = scaleout.make_queries(jax.random.PRNGKey(1), cfg_p, protos, 1)
    pred_p, sim_p = scaleout.make_ota_serve(mesh, cfg_p)(
        hv.pack(protos), queries_p, state, key)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_p))
    np.testing.assert_array_equal(np.asarray(sim), np.asarray(sim_p))


def test_symbol_empirical_ber_matches_analytic(small_state):
    """Monte-Carlo per-RX bit-flip rates of the phy symbol decode vs the
    analytic predictions the state was characterized with: within binomial
    tolerance of the tight per-symbol analytic everywhere the validity flag
    holds, and averaging to Eq. 1's `ber_per_rx` at the reported precision —
    the empirical-vs-analytic cross-check of the BER abstraction itself."""
    state, res, _, n0 = small_state
    m, d = 3, 16384
    maj = ota.majority_labels(m)
    ber_sym, _ = ota.decision_metrics(res.symbols, maj, n0, method="symbol")
    queries = hv.random_hv(KEY, m, d)
    majq = hv.majority(queries)
    combo = phy.combo_index(queries, axis=0)              # [d]

    def one(i):
        return phy.awgn_decide(jax.random.fold_in(jax.random.PRNGKey(7), i),
                               state.symbols[i][combo], state.c0[i],
                               state.c1[i], state.n0)

    decoded = jax.vmap(one)(jnp.arange(state.n_rx))       # [N, d]
    emp = np.asarray(jnp.mean((decoded != majq[None]).astype(jnp.float32), 1))
    ana = np.asarray(ber_sym)
    valid = np.asarray(res.valid_per_rx)
    assert valid.any()
    # per-RX: 5-sigma binomial band around the tight analytic, valid RXs only
    tol = 5.0 * np.sqrt(np.maximum(ana * (1 - ana), 1e-9) / d) + 5e-4
    bad = valid & (np.abs(emp - ana) > tol)
    assert not bad.any(), list(zip(np.where(bad)[0], emp[bad], ana[bad]))
    # in aggregate the empirical channel matches the tight per-symbol analytic
    # and is bounded below by Eq. 1 — the centroid erfc evaluates at the
    # centroid distance, so Eq. 1 is the OPTIMISTIC approximation (the
    # documented beyond-paper refinement; see EXPERIMENTS.md §Channel-fidelity)
    assert abs(emp[valid].mean() - ana[valid].mean()) < 0.01, (
        emp[valid].mean(), ana[valid].mean())
    eq1 = float(np.asarray(res.ber_per_rx)[valid].mean())
    assert eq1 <= ana[valid].mean() + 1e-6
    assert eq1 <= emp[valid].mean() + 0.005, (eq1, emp[valid].mean())


def test_classifier_symbol_channel_tracks_bsc(small_state):
    """`classifier.run_accuracy(channel="symbol")` — physical link in the
    trial loop — matches the BSC abstraction within Monte-Carlo noise at the
    paper's operating point (the Fig. 10 claim, verified not assumed)."""
    from repro.core import classifier

    state, res, _, _ = small_state
    cfg = classifier.HDCTaskConfig(n_classes=64, dim=512, n_trials=200)
    acc_bsc = float(classifier.run_accuracy(
        KEY, cfg, 3, float(res.avg_ber), "baseline"))
    acc_sym = float(classifier.run_accuracy(
        KEY, cfg, 3, 0.0, "baseline", channel="symbol", state=state))
    assert abs(acc_bsc - acc_sym) <= 0.03, (acc_bsc, acc_sym)
    with pytest.raises(ValueError, match="ChannelState"):
        classifier.run_accuracy(KEY, cfg, 3, 0.0, "baseline", channel="symbol")


def test_snr_per_rx_diagnostic(small_state):
    _, _, h, n0 = small_state
    snr = np.asarray(em.snr_per_rx(h, n0))
    assert snr.shape == (16,)
    assert np.isfinite(snr).all()
    # default_n0 calibrates the MEAN link SNR to cfg.snr_db (7 dB): per-RX
    # values straddle it
    assert snr.min() < 7.0 + 3.0 and snr.max() > 7.0 - 3.0
