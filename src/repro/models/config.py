"""Unified model configuration for the assigned architecture families.

One dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM via optional
sections; per-architecture files in ``repro.configs`` instantiate it with the
exact published numbers and may override sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # always-on shared experts (Kimi-style)
    group_size: int = 1024       # tokens per dispatch group (GShard-style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMSettings:
    kind: str                    # "mamba1" | "mamba2"
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64           # mamba2 only
    n_groups: int = 1            # mamba2 only
    dt_rank: int | None = None   # mamba1; default d_model // 16
    chunk: int = 128             # selective-scan chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    kind: str = "decoder"        # decoder | encdec | vlm
    head_dim: int | None = None  # default d_model // n_heads
    rope_theta: float = 10_000.0
    local_rope_theta: float | None = None   # gemma3 dual-theta (local layers)
    window_pattern: tuple[int, ...] | None = None  # per-layer window, -1 = global
    qk_norm: bool = False
    sandwich_norm: bool = False  # gemma3 pre+post block norms
    tie_embeddings: bool = False
    emb_scale: bool = False      # gemma-style sqrt(d) embedding scaling
    act: str = "silu"            # silu | gelu
    norm_eps: float = 1e-6
    moe: MoESettings | None = None
    ssm: SSMSettings | None = None
    shared_attn_every: int = 0   # zamba2: one shared attn block every k ssm layers
    n_enc_layers: int = 0        # whisper encoder depth
    enc_seq: int = 1500          # whisper frame count (stub frontend output)
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE (t, h, w)
    vision_seq: int = 0          # vlm: patch-embedding prefix length (stub)
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True
    flash_block_q: int = 512
    flash_block_k: int = 1024
    loss_chunk: int = 512        # chunked cross-entropy sequence chunk
    rules_override: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # long-context support marker (None = full attention everywhere -> skip 500k)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def windows(self) -> tuple[int, ...]:
        if self.window_pattern is None:
            return (-1,) * self.n_layers
        assert len(self.window_pattern) == self.n_layers
        return self.window_pattern

    @property
    def max_window(self) -> int:
        """Largest finite window; -1 if any layer is global."""
        ws = self.windows
        return -1 if any(w < 0 for w in ws) else max(ws)
