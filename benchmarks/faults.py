"""Chaos benchmark: serve-path accuracy under injected hard faults.

  PYTHONPATH=src python -m benchmarks.faults [--fast]

Three serves of the SAME compiled faults-enabled program (awareness is data —
`faults.FaultState` is a traced input, so every scenario reuses one compile):

* **baseline** — the all-healthy state. Pinned bit-identical to the plain
  (faults-free) serve first: fault awareness must cost nothing when nothing
  is broken (``zero_fault_identical`` gates in check_regression.py).
* **unaware** — K dead RX cores + stuck-at cells, but the serve plan left as
  built (identity ``serve_rows``): every class draw whose prototype bank
  lives on a dead core is answered by whatever healthy core's garbage wins
  the top-1 — the silent-misclassification failure mode.
* **aware** — the same physical faults with `faults.plan_failover` re-dealt:
  dead cores' banks are served through healthy same-shard cores' query
  copies (traced gather, no recompile), erased votes drop out of the
  live-majority threshold, and quarantined rows leave the reduction.

Reported: the pinned-scenario accuracy triplet (the acceptance gate: unaware
drops >= 5 points, aware stays within 1 point of fault-free), the
accuracy-vs-dead-cores degradation curve with and without failover, a
stuck-at-density sweep, and a `FaultTolerantHDCEngine` serving run for the
throughput floor. Everything accuracy-side is seeded and trial-exact.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save


def _draw_acc(serve, protos, state, fstate, fkey, queries_list, classes_list):
    """Mean per-draw accuracy of the faults-enabled serve over all batches.

    The fault model is static, so threading the returned fstate is a no-op;
    each batch serves under the SAME injected state.
    """
    import jax

    hits, total = 0, 0
    for (q, k), cls in zip(queries_list, classes_list):
        pred, _, _ = serve(protos, q, state, k, fstate, fkey)
        hit = np.asarray(pred) == np.asarray(cls)
        hits += int(hit.sum())
        total += hit.size
    return hits / total


def run(n_rx: int = 16, n_classes: int = 64, dim: int = 512, m_tx: int = 3,
        k_dead: int = 2, stuck_density: float = 0.01, ber: float = 0.01,
        batch: int = 64, n_batches: int = 8, curve=(0, 1, 2, 4, 8),
        stuck_densities=(0.0, 0.01, 0.05, 0.1), serve_requests: int = 32,
        seed: int = 0, quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import faults, phy
    from repro.compat import make_mesh
    from repro.core import classifier, hypervector as hv, scaleout
    from repro.serving import (FaultControllerConfig, FaultTolerantHDCEngine,
                               HDCScheduler)

    cfg = scaleout.ScaleOutConfig(
        n_classes=n_classes, dim=dim, m_tx=m_tx, n_rx_cores=n_rx, batch=batch,
        use_kernels=False, noise="exact", permuted=True, channel="bsc",
        collective="psum", representation="packed",
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(seed)
    protos_u = hv.random_hv(jax.random.fold_in(key, 0), n_classes, dim)
    protos = hv.pack(protos_u)
    state = phy.state_from_ber(jnp.full((n_rx,), ber), m_tx)
    fkey = jax.random.PRNGKey(seed + 1)

    queries_list, classes_list = [], []
    for i in range(n_batches):
        qk = jax.random.fold_in(key, 100 + i)
        cls, q = scaleout.make_queries(qk, cfg, protos_u, 1)
        queries_list.append((q, jax.random.fold_in(key, 200 + i)))
        classes_list.append(cls)

    fm = faults.get_fault_model("static")
    fserve = scaleout.make_ota_serve(mesh, cfg, faults=fm)
    plain = scaleout.make_ota_serve(mesh, cfg)
    healthy = faults.healthy_for(cfg, 1)

    # -- zero-fault identity: fault awareness must be free ---------------------
    q0, k0 = queries_list[0]
    p_plain, s_plain = plain(protos, q0, state, k0)
    p_f, s_f, _ = fserve(protos, q0, state, k0, healthy, fkey)
    zero_fault_identical = bool(
        np.array_equal(np.asarray(p_plain), np.asarray(p_f))
        and np.array_equal(np.asarray(s_plain), np.asarray(s_f))
    )

    def scenario(k: int, density: float, failover: bool):
        f = healthy
        if k:
            f = faults.inject(f, dead_rx=list(range(k)))
        if density:
            s0, s1 = faults.sample_stuck_cells(
                jax.random.fold_in(fkey, 7), n_rx, cfg.words, density)
            f = faults.inject(f, stuck0=s0, stuck1=s1)
        if failover:
            f = faults.plan_failover(f, n_rx)  # one shard on the bench mesh
        return _draw_acc(fserve, protos, state, f, fkey,
                         queries_list, classes_list)

    # -- pinned scenario (the acceptance gate) ---------------------------------
    baseline = scenario(0, 0.0, False)
    unaware = scenario(k_dead, stuck_density, False)
    aware = scenario(k_dead, stuck_density, True)

    # -- degradation curve: accuracy vs dead cores, +/- failover ---------------
    curve_rows = []
    for k in curve:
        curve_rows.append({
            "k_dead": int(k),
            "unaware_draw_acc": scenario(k, 0.0, False),
            "aware_draw_acc": scenario(k, 0.0, True),
        })

    # -- stuck-at density sweep (failover path, no dead cores) -----------------
    stuck_rows = [{"density": float(p), "draw_acc": scenario(0, p, True)}
                  for p in stuck_densities]

    # -- serving throughput: the fault-tolerant engine end-to-end --------------
    eng = FaultTolerantHDCEngine(
        mesh, cfg, state, process=phy.StaticProcess(),
        fault_model=fm, num_slots=4, max_tenants=1,
        fstate=faults.plan_failover(
            faults.inject(healthy, dead_rx=list(range(k_dead))), n_rx),
        controller=FaultControllerConfig(band_kwargs={"cap": 0.05}),
    )
    eng.registry.onboard(0, protos)
    warm = HDCScheduler(eng)
    for _ in range(4):
        warm.submit(0, queries_list[0][0])
    warm.run(timeout=600)
    sched = HDCScheduler(eng)
    t0 = time.monotonic()
    for i in range(serve_requests):
        sched.submit(0, queries_list[i % n_batches][0],
                     key=jax.random.PRNGKey(1000 + i))
    sched.run(timeout=600)
    serve_wall = time.monotonic() - t0

    out = {
        "scenario": {
            "n_rx": n_rx, "n_classes": n_classes, "dim": dim, "m_tx": m_tx,
            "k_dead": k_dead, "stuck_density": stuck_density, "ber": ber,
            "batch": batch, "n_batches": n_batches, "seed": seed,
            "representation": cfg.representation, "collective": cfg.collective,
            "channel": cfg.channel,
        },
        "zero_fault_identical": zero_fault_identical,
        "baseline_draw_acc": baseline,
        "unaware_draw_acc": unaware,
        "aware_draw_acc": aware,
        "unaware_drop_pts": 100.0 * (baseline - unaware),
        "aware_gap_pts": 100.0 * (baseline - aware),
        "degradation_curve": curve_rows,
        "stuck_sweep": stuck_rows,
        "serving": {
            "n_requests": serve_requests,
            "wall_s": serve_wall,
            "trials_per_s": serve_requests * batch / serve_wall,
        },
    }
    if not quiet:
        print(f"chaos: {n_rx} RX, C={n_classes}, d={dim} (packed), "
              f"{k_dead} dead cores + {100 * stuck_density:.0f}% stuck cells, "
              f"zero-fault-identical={zero_fault_identical}")
        print(f"  baseline draw acc : {baseline:.3f}")
        print(f"  unaware           : {unaware:.3f}  "
              f"(drop {out['unaware_drop_pts']:.1f} pts)")
        print(f"  aware (failover)  : {aware:.3f}  "
              f"(gap  {out['aware_gap_pts']:.1f} pts)")
        print("  degradation curve (k_dead: unaware / aware):")
        for row in curve_rows:
            print(f"    {row['k_dead']:2d}: {row['unaware_draw_acc']:.3f} / "
                  f"{row['aware_draw_acc']:.3f}")
        print("  stuck sweep: " + ", ".join(
            f"{r['density']:.2f}->{r['draw_acc']:.3f}" for r in stuck_rows))
        print(f"  fault-tolerant serving: "
              f"{out['serving']['trials_per_s']:.0f} trials/s")
    save("serving_faults", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer trial batches / shorter sweeps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.fast:
        run(n_batches=2, curve=(0, 2, 4), stuck_densities=(0.0, 0.01),
            serve_requests=8, seed=args.seed)
    else:
        run(seed=args.seed)


if __name__ == "__main__":
    main()
