"""Public op: packed Hamming similarity search with padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.hamming.kernel import hamming_pallas
from repro.kernels.hamming.ref import hamming_search_ref


def hamming_search(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int = 8,
    bc: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Hamming distances between packed queries [.., W] and prototypes [C, W].

    Accepts arbitrary leading query dims; pads B to bq and C to bc (padding words are
    zero on both sides, so padded prototypes report distance 0 against padded queries
    only — padded rows/cols are sliced away before returning).
    """
    if interpret is None:
        interpret = common.default_interpret()
    lead = q.shape[:-1]
    w = q.shape[-1]
    qf = q.reshape((-1, w))
    b, c = qf.shape[0], protos.shape[0]
    if not use_kernel:
        return hamming_search_ref(qf, protos).reshape(lead + (c,))
    qp = common.pad_dim(qf, 0, bq)
    pp = common.pad_dim(protos, 0, bc)
    out = hamming_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return out[:b, :c].reshape(lead + (c,))
