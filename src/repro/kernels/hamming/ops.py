"""Public op: packed Hamming similarity search with padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.hamming.kernel import (
    _KEY_SENTINEL,
    hamming_banked_pallas,
    hamming_pallas,
    hamming_topk_banked_pallas,
    hamming_topk_k_banked_pallas,
)
from repro.kernels.hamming.ref import hamming_search_banked_ref, hamming_search_ref


def _blocked(ref_fn, protos, c_axis: int, bc: int, *args):
    """Evaluate a hamming ref in prototype chunks of `bc`.

    The plain refs broadcast a [..., C, W] XOR intermediate; past ~8 MiB that
    falls out of cache and the jnp fallback goes ~6x slower than the same math
    chunked (numerics are identical — integer ops). Used by the use_kernel=False
    dispatch; the refs themselves stay the canonical one-liners.
    """
    c = protos.shape[c_axis]
    if c <= bc:
        return ref_fn(*args, protos)
    chunks = [
        ref_fn(*args, jax.lax.slice_in_dim(protos, i, min(i + bc, c), axis=c_axis))
        for i in range(0, c, bc)
    ]
    return jnp.concatenate(chunks, axis=-1)


def hamming_search(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int | None = None,
    bc: int | None = None,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Hamming distances between packed queries [.., W] and prototypes [C, W].

    Accepts arbitrary leading query dims; pads B to bq and C to bc (padding words are
    zero on both sides, so padded prototypes report distance 0 against padded queries
    only — padded rows/cols are sliced away before returning). Block sizes
    default to the `common.hamming_blocks` policy.
    """
    if interpret is None:
        interpret = common.default_interpret()
    lead = q.shape[:-1]
    w = q.shape[-1]
    qf = q.reshape((-1, w))
    b, c = qf.shape[0], protos.shape[0]
    bq, bc = common.hamming_blocks(b, c, bq, bc)
    if not use_kernel:
        return _blocked(hamming_search_ref, protos, 0, bc, qf).reshape(lead + (c,))
    qp = common.pad_dim(qf, 0, bq)
    pp = common.pad_dim(protos, 0, bc)
    out = hamming_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return out[:b, :c].reshape(lead + (c,))


def hamming_search_banked(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int | None = None,
    bc: int | None = None,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Per-bank Hamming distances: q [G, B, W], protos [G, C, W] -> [G, B, C].

    Bank g searches only bank g's prototypes — the scale-out per-core associative
    search as ONE grid (G, B/bq, C/bc) kernel launch (instead of a vmap of G tiny
    calls). B and C are zero-padded to the block sizes and sliced away; zero
    padding is safe because padded rows/banks are dropped before returning.
    Block sizes default to the `common.hamming_blocks` policy.
    """
    if interpret is None:
        interpret = common.default_interpret()
    g, b, w = q.shape
    g2, c, w2 = protos.shape
    assert g == g2 and w == w2, (q.shape, protos.shape)
    bq, bc = common.hamming_blocks(b, c, bq, bc)
    if not use_kernel:
        return _blocked(hamming_search_banked_ref, protos, 1, bc, q)
    qp = common.pad_dim(q, 1, bq)
    pp = common.pad_dim(protos, 1, bc)
    out = hamming_banked_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return out[:, :b, :c]


def _extract_smallest_k(cand: jax.Array, k: int) -> jax.Array:
    """Ascending k smallest of `cand` [..., n] by k rounds of min-extraction
    (find the minimum, emit it, poison every entry equal to it). Requires the
    values to be UNIQUE — true for ``dist*C + col`` keys (distinct cols) —
    or equal minima collapse. This is the same merge the Pallas kernel runs
    in VMEM, and on CPU it beats a per-chunk ``lax.top_k`` by ~10x: XLA
    lowers top_k to a full row sort (scalar comparator loops), while k
    min+select rounds stay vectorized and fusion-friendly."""
    outs = []
    for _ in range(k):
        m = jnp.min(cand, axis=-1, keepdims=True)
        outs.append(m[..., 0])
        cand = jnp.where(cand == m, jnp.int32(_KEY_SENTINEL), cand)
    return jnp.stack(outs, axis=-1)


def _streamed_topk_banked(
    q: jax.Array, protos: jax.Array, bc: int, key_encode: bool | None = None,
    bank_rows: jax.Array | None = None, k: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """jnp fallback for the fused top-1: stream prototype chunks of `bc` through
    a running minimum carry. The full [G, B, C] distance tensor (and the
    [G, B, C, W] XOR intermediate past one chunk) never materializes — the same
    streaming reduction the Pallas kernel performs in VMEM. With ``bank_rows``
    set, protos is a [T, C, W] table and bank g streams row ``bank_rows[g]`` —
    the gather happens per chunk tile, so the expanded [G, C, W] view never
    materializes either.

    The (dist, col) pair is encoded as ONE int32 key ``dist * C + col`` so each
    chunk is a single reduction with a single consumer of its distance tile —
    XLA then fuses the whole XOR+popcount+min chain and the [G, B, bc] tile
    stays fusion-internal (min + argmin as two separate reductions each
    re-materialize the tile to HBM). Minimizing the key IS lexicographic
    (dist, col) order, i.e. first-minimum tie breaking, identical to
    `jnp.argmin`. Falls back to the two-reduction merge if the key could
    overflow int32 (never for the paper's shapes: needs (d+1)*C >= 2^31);
    `key_encode` overrides the auto-choice so tests can pin either branch on
    small shapes.

    With ``k`` set, the scalar carry widens to a length-k sorted buffer per
    (g, b) and the result is ([G, B, k], [G, B, k]) rank-sorted ascending by
    (dist, col) — the key branch merges each chunk's keys with k rounds of
    min-extraction (`_extract_smallest_k`, the kernel's VMEM merge; a
    per-chunk ``lax.top_k`` lowers to a full row SORT on CPU and costs ~6x
    the scan itself); the overflow branch carries (val, idx) pairs through a
    two-operand lexicographic ``lax.sort``. Neither re-materializes the
    [G, B, C] distances.
    """
    g, b, w = q.shape
    c = protos.shape[1]
    d = w * 32

    def tile(start, stop):
        chunk = jax.lax.slice_in_dim(protos, start, stop, axis=1)
        if bank_rows is not None:
            chunk = jnp.take(chunk, bank_rows, axis=0)      # [G, <=bc, W]
        return chunk

    if key_encode is None:
        key_encode = (d + 1) * c < 2**31
    if k is not None:
        assert 1 <= k <= c, (k, c)
        bc = max(bc, k)  # every chunk (and so every merge) holds >= k entries
        if key_encode:
            assert (d + 1) * c < 2**31, (d, c)
            best = None                                     # [G, B, k] keys, ascending
            for start in range(0, c, bc):
                chunk = tile(start, min(start + bc, c))
                dist = hamming_search_banked_ref(q, chunk)  # [G, B, <=bc]
                cols = start + jnp.arange(chunk.shape[1], dtype=jnp.int32)
                keys = dist * c + cols
                cand = keys if best is None else jnp.concatenate([best, keys], -1)
                best = _extract_smallest_k(cand, k)
            return best // c, best % c
        best_v = best_i = None
        for start in range(0, c, bc):
            chunk = tile(start, min(start + bc, c))
            dist = hamming_search_banked_ref(q, chunk)      # [G, B, <=bc]
            cols = jnp.broadcast_to(
                start + jnp.arange(chunk.shape[1], dtype=jnp.int32), dist.shape
            )
            if best_v is None:
                cand_v, cand_i = dist, cols
            else:
                cand_v = jnp.concatenate([best_v, dist], -1)
                cand_i = jnp.concatenate([best_i, cols], -1)
            # stable two-key sort == lexicographic (dist, col) rank order
            sv, si = jax.lax.sort((cand_v, cand_i), dimension=-1, num_keys=2)
            best_v, best_i = sv[..., :k], si[..., :k]
        return best_v, best_i
    if key_encode:
        assert (d + 1) * c < 2**31, (d, c)
        best_key = None
        for start in range(0, c, bc):
            chunk = tile(start, min(start + bc, c))
            dist = hamming_search_banked_ref(q, chunk)      # [G, B, <=bc]
            cols = start + jnp.arange(chunk.shape[1], dtype=jnp.int32)
            key = jnp.min(dist * c + cols, axis=-1)         # [G, B]
            best_key = key if best_key is None else jnp.minimum(best_key, key)
        return best_key // c, best_key % c
    best_v = best_i = None
    for start in range(0, c, bc):
        chunk = tile(start, min(start + bc, c))
        dist = hamming_search_banked_ref(q, chunk)          # [G, B, <=bc]
        v = jnp.min(dist, axis=-1)
        i = start + jnp.argmin(dist, axis=-1).astype(jnp.int32)
        if best_v is None:
            best_v, best_i = v, i
        else:
            better = v < best_v
            best_i = jnp.where(better, i, best_i)
            best_v = jnp.where(better, v, best_v)
    return best_v, best_i


def hamming_topk_banked(
    q: jax.Array,
    protos: jax.Array,
    *,
    k: int | None = None,
    bank_rows: jax.Array | None = None,
    bq: int | None = None,
    bc: int | None = None,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused per-bank top-k Hamming search: q [G, B, W], protos [G, C, W]
    -> (min_dist [G, B] int32, argmin [G, B] int32) for the default k=None
    (the fused top-1), or (dists, idxs) each [G, B, k] int32 for an explicit
    ``k``, rank-sorted ascending by (distance, class index) — rank r is the
    r-th first minimum, so every rank keeps the top-1 tie convention.

    Bank g's queries are searched only against bank g's prototypes and the
    class axis is reduced without writing the [G, B, C] distances to HBM —
    the kernel carries the running (min, argmin) in the revisited output VMEM
    tile; the jnp fallback streams prototype chunks through the same carry.
    Ties break toward the lowest class index (first minimum), exactly
    `jnp.argmax` over sims = d - 2*dist. B is zero-padded to bq and sliced
    away; padded prototype rows are masked inside the reduction so zero
    padding can never win.

    ``bank_rows`` [G] int32 adds a row indirection for multi-tenant serving:
    protos is then a [T, C, W] bank *table* and bank g searches table row
    ``bank_rows[g]`` (rows may repeat — slots sharing a tenant share the
    bank). The kernel path gathers the G referenced rows before the launch
    (same footprint the direct [G, C, W] call pays); the streamed fallback
    gathers per chunk tile and never materializes the expanded view.

    Block sizes default to the `common.hamming_blocks` policy. The top-k
    kernel needs the int32 key encoding ``dist*C + col`` to fit; if
    (d+1)*C_padded >= 2^31 the call transparently streams instead (the
    streamed overflow branch carries (val, idx) pairs).
    """
    if interpret is None:
        interpret = common.default_interpret()
    g, b, w = q.shape
    c, w2 = protos.shape[1], protos.shape[2]
    if bank_rows is None:
        assert g == protos.shape[0] and w == w2, (q.shape, protos.shape)
    else:
        assert bank_rows.shape == (g,) and w == w2, (
            q.shape, protos.shape, bank_rows.shape
        )
    bq, bc = common.hamming_blocks(b, c, bq, bc)
    if k is None:
        if not use_kernel:
            return _streamed_topk_banked(q, protos, bc, bank_rows=bank_rows)
        if bank_rows is not None:
            protos = jnp.take(protos, bank_rows, axis=0)    # [G, C, W]
        qp = common.pad_dim(q, 1, bq)
        pp = common.pad_dim(protos, 1, bc)
        val, idx = hamming_topk_banked_pallas(
            qp, pp, c_real=c, bq=bq, bc=bc, interpret=interpret
        )
        return val[:, :b], idx[:, :b]
    assert 1 <= k <= c, (k, c)
    c_pad = common.cdiv(c, bc) * bc
    if not use_kernel or (w * 32 + 1) * c_pad >= 2**31:
        return _streamed_topk_banked(q, protos, bc, bank_rows=bank_rows, k=k)
    if bank_rows is not None:
        protos = jnp.take(protos, bank_rows, axis=0)        # [G, C, W]
    qp = common.pad_dim(q, 1, bq)
    pp = common.pad_dim(protos, 1, bc)
    val, idx = hamming_topk_k_banked_pallas(
        qp, pp, c_real=c, k=k, bq=bq, bc=bc, interpret=interpret
    )
    return val[:, :b], idx[:, :b]
