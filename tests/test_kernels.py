"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # prefer the real engine when installed
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic seeded fallback
    from _propcheck import given, settings, strategies as st

from repro.core import hypervector as hv
from repro.kernels.assoc_matmul import assoc_matmul
from repro.kernels.assoc_matmul.ref import assoc_matmul_ref
from repro.kernels.hamming import (
    hamming_search,
    hamming_search_banked,
    hamming_topk_banked,
)
from repro.kernels.hamming.ref import (
    hamming_search_banked_ref,
    hamming_search_ref,
    hamming_topk_banked_ref,
)
from repro.kernels.majority import majority_bundle
from repro.kernels.majority.ref import majority_bundle_ref

KEY = jax.random.PRNGKey(0)

SHAPES = [(4, 100, 512), (17, 33, 1024), (1, 7, 10016), (8, 128, 512), (3, 257, 2048)]


@pytest.mark.parametrize("b,c,d", SHAPES)
def test_hamming_kernel_sweep(b, c, d):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, b * c))
    q, p = hv.random_hv(k1, b, d), hv.random_hv(k2, c, d)
    qp, pp = hv.pack(q), hv.pack(p)
    got = hamming_search(qp, pp, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(hamming_search_ref(qp, pp)))


BANKED_SHAPES = [(4, 8, 128, 512), (3, 5, 7, 224), (8, 16, 2, 512), (1, 9, 130, 1024)]


@pytest.mark.parametrize("g,b,c,d", BANKED_SHAPES)
def test_hamming_banked_kernel_sweep(g, b, c, d):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, g * b * c))
    q = hv.pack(hv.random_hv(k1, g * b, d)).reshape(g, b, d // 32)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, d // 32)
    got = hamming_search_banked(q, p, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(hamming_search_banked_ref(q, p))
    )


@pytest.mark.parametrize("g,b,c,d", BANKED_SHAPES + [(2, 3, 300, 512)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_hamming_topk_banked_sweep(g, b, c, d, use_kernel):
    """Fused top-1 (kernel and streaming-jnp fallback) == jnp min/argmin oracle."""
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, g * b * c + 1))
    q = hv.pack(hv.random_hv(k1, g * b, d)).reshape(g, b, d // 32)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, d // 32)
    rv, ri = hamming_topk_banked_ref(q, p)
    v, i = hamming_topk_banked(q, p, use_kernel=use_kernel, interpret=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_hamming_topk_banked_tie_breaking(use_kernel):
    """Ties resolve toward the LOWEST class index — `jnp.argmax` first-max
    semantics on similarities — even when the duplicates straddle the bc=128
    tile boundary of the revisited-grid reduction (the strict `<` merge must
    keep the earlier tile's winner)."""
    d, c = 512, 300  # 3 class tiles of 128 (two full + one padded)
    q = hv.pack(hv.random_hv(jax.random.PRNGKey(0), 2, d)).reshape(1, 2, d // 32)
    base = hv.pack(hv.random_hv(jax.random.PRNGKey(1), c, d))
    # plant the query itself (distance 0) at several duplicate positions that
    # span different tiles; the reported argmin must always be the first one
    for dup_positions in [(5, 17), (5, 200), (130, 260), (129, 130, 299)]:
        p = base
        for pos in dup_positions:
            p = p.at[pos].set(q[0, 0])
        pb = p[None]  # [1, C, W]
        v, i = hamming_topk_banked(q[:, :1], pb, use_kernel=use_kernel,
                                   interpret=True)
        assert int(v[0, 0]) == 0
        assert int(i[0, 0]) == dup_positions[0], (dup_positions, int(i[0, 0]))
        # and it matches the one-shot argmax-over-similarities semantics
        dist = hamming_search_banked_ref(q[:, :1], pb)
        sims = d - 2 * dist
        assert int(i[0, 0]) == int(jnp.argmax(sims[0, 0]))


@pytest.mark.parametrize("key_encode", [True, False])
def test_hamming_topk_streamed_both_branches(key_encode):
    """Both merge strategies of the streamed fallback (int32 key encoding and
    the two-reduction strict-< carry for shapes where the key would overflow)
    must agree with the oracle — including duplicate-distance ties straddling
    the chunk boundary, which is exactly what the two-pass merge can get wrong."""
    from repro.kernels.hamming import ops

    d, c = 512, 300  # 3 chunks of bc=128
    q = hv.pack(hv.random_hv(jax.random.PRNGKey(3), 4, d)).reshape(2, 2, d // 32)
    p = hv.pack(hv.random_hv(jax.random.PRNGKey(4), 2 * c, d)).reshape(2, c, d // 32)
    # plant cross-chunk duplicates of one query so the merge sees exact ties
    p = p.at[0, 130].set(q[0, 0]).at[0, 260].set(q[0, 0])
    rv, ri = hamming_topk_banked_ref(q, p)
    v, i = ops._streamed_topk_banked(q, p, bc=128, key_encode=key_encode)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    assert int(i[0, 0]) == 130  # the first duplicate wins


def test_hamming_banked_equals_per_bank_loop():
    """One banked launch == G independent hamming_search calls."""
    g, b, c, d = 5, 6, 40, 512
    k1, k2 = jax.random.split(KEY)
    q = hv.pack(hv.random_hv(k1, g * b, d)).reshape(g, b, d // 32)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, d // 32)
    got = hamming_search_banked(q, p, interpret=True)
    loop = jnp.stack([hamming_search(q[i], p[i], interpret=True) for i in range(g)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))


@pytest.mark.parametrize("b,c,d", SHAPES)
def test_assoc_matmul_kernel_sweep(b, c, d):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, b + c))
    q, p = hv.random_hv(k1, b, d), hv.random_hv(k2, c, d)
    got = assoc_matmul(q, p, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(assoc_matmul_ref(q, p)), atol=0)


@pytest.mark.parametrize("m,b,d", [(3, 5, 512), (7, 2, 384), (4, 33, 129), (11, 8, 2048)])
def test_majority_kernel_sweep(m, b, d):
    x = hv.random_hv(jax.random.fold_in(KEY, m * d), m * b, d).reshape(m, b, d)
    got = majority_bundle(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(majority_bundle_ref(x)))


def test_kernel_identity_dot_equals_dim_minus_2hamming():
    """Cross-kernel invariant: assoc dot == d - 2*hamming (the IMC MVM identity)."""
    k1, k2 = jax.random.split(KEY)
    q, p = hv.random_hv(k1, 6, 768), hv.random_hv(k2, 50, 768)
    dots = assoc_matmul(q, p, interpret=True)
    dist = hamming_search(hv.pack(q), hv.pack(p), interpret=True)
    np.testing.assert_allclose(np.asarray(dots), 768 - 2 * np.asarray(dist), atol=0)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 9),
    st.integers(1, 40),
    st.integers(2, 40).map(lambda w: w * 32),
)
def test_hamming_kernel_property(seed, b, c, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q, p = hv.random_hv(k1, b, d), hv.random_hv(k2, c, d)
    qp, pp = hv.pack(q), hv.pack(p)
    got = hamming_search(qp, pp, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(hamming_search_ref(qp, pp)))


@pytest.mark.parametrize("dtype", [jnp.uint8])
def test_majority_vs_core_majority(dtype):
    """Kernel agrees with core.hypervector.majority for odd M."""
    x = hv.random_hv(KEY, 5 * 4, 640).reshape(5, 4, 640).astype(dtype)
    np.testing.assert_array_equal(
        np.asarray(majority_bundle(x, interpret=True)), np.asarray(hv.majority(x))
    )


# ---------------------------------------------------------------------------
# fused flash attention (TPU fast path)
# ---------------------------------------------------------------------------

import jax as _jax
import jax.numpy as _jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_fwd_ref


@pytest.mark.parametrize(
    "s,h,kh,d,win,causal,bq,bk",
    [(256, 4, 2, 32, -1, True, 64, 64),
     (256, 4, 1, 64, 64, True, 64, 128),
     (128, 6, 6, 16, -1, False, 64, 64),
     (512, 2, 2, 128, 128, True, 128, 256)],
)
def test_pallas_flash_attention_sweep(s, h, kh, d, win, causal, bq, bk):
    ks = _jax.random.split(_jax.random.fold_in(KEY, s + h + d), 3)
    q = _jax.random.normal(ks[0], (2, s, h, d), _jnp.float32)
    k = _jax.random.normal(ks[1], (2, s, kh, d), _jnp.float32)
    v = _jax.random.normal(ks[2], (2, s, kh, d), _jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, window=win,
                              block_q=bq, block_k=bk, interpret=True)
    want = flash_fwd_ref(q, k, v, causal=causal, window=win, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)
