"""Table I: accuracy vs #bundled hypervectors x {baseline, permuted} x
{ideal, wireless} channels. Wireless BER = the measured 64-RX average from the
EM + constellation pipeline (same methodology as the paper)."""
from __future__ import annotations

import jax

from benchmarks.common import save
from repro.core import classifier, em, ota

PAPER = {  # paper's Table I for reference
    ("baseline", "ideal"): [1, 0.966, 0.902, 0.803, 0.704, 0.543],
    ("baseline", "wireless"): [1, 0.966, 0.9, 0.801, 0.699, 0.537],
    ("permuted", "ideal"): [1, 1, 1, 1, 0.995, 0.978],
    ("permuted", "wireless"): [1, 1, 1, 1, 0.994, 0.963],
}
MS = (1, 3, 5, 7, 9, 11)


def run(n_trials: int = 1000, quiet: bool = False, use_kernels: bool = True,
        representation: str = "unpacked") -> dict:
    """use_kernels defaults to True (interpret mode on CPU): the figures exercise
    the Pallas similarity kernels, so a kernel regression moves the table, not
    just an allclose test. Accuracy is bit-identical either way (see
    classifier._similarity)."""
    h = em.channel_matrix(em.PackageGeometry(), 3, 64)
    res = ota.optimize_phases_exhaustive(h, ota.default_n0(h))
    wireless_ber = float(res.avg_ber)
    cfg = classifier.HDCTaskConfig(n_trials=n_trials)
    out = {"wireless_ber": wireless_ber, "ms": list(MS),
           "use_kernels": use_kernels, "representation": representation}
    key = jax.random.PRNGKey(0)
    for bundling in ("baseline", "permuted"):
        for channel, ber in (("ideal", 0.0), ("wireless", wireless_ber)):
            accs = [
                float(classifier.run_accuracy(
                    key, cfg, m, ber, bundling,
                    representation=representation, use_kernels=use_kernels))
                for m in MS
            ]
            out[f"{bundling}/{channel}"] = accs
            if not quiet:
                paper = PAPER[(bundling, channel)]
                row = "  ".join(f"{a:.3f}({p:.3f})" for a, p in zip(accs, paper))
                print(f"{bundling:8s} {channel:8s}  {row}   [ours(paper)]")
    save("table1", out)
    return out


def main():
    print(f"Table I reproduction — M = {MS}, avg wireless BER from 64-RX pipeline")
    run()


if __name__ == "__main__":
    main()
