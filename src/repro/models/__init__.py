"""Composable JAX model stack for the assigned architectures.

Everything is spec-first: a model declares `param_specs(cfg)` (shapes + logical
sharding axes + initializers) so the dry-run can build ShapeDtypeStructs for
trillion-parameter configs without allocating, and `init` materializes the same
tree for the smoke tests.
"""
from repro.models.base import ParamSpec, init_params, param_axes, param_shapes  # noqa: F401
from repro.models.zoo import get_model, Model  # noqa: F401
