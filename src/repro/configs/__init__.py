"""Architecture registry: one module per assigned architecture.

`get_config(name)` returns the full published config; `get_smoke(name)` a reduced
same-family config for CPU smoke tests. `repro.configs.shapes` defines the
assigned input-shape cells and their ShapeDtypeStruct builders.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "smollm_360m",
    "gemma3_1b",
    "tinyllama_1_1b",
    "deepseek_coder_33b",
    "qwen2_vl_7b",
    "whisper_tiny",
    "falcon_mamba_7b",
    "zamba2_2_7b",
    "mixtral_8x22b",
    "kimi_k2",
)

ALIASES = {a.replace("_", "-"): a for a in ARCHS} | {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "zamba2-2.7b": "zamba2_2_7b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "kimi-k2": "kimi_k2",
}


def _mod(name: str):
    name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    """Reduced same-family config. Forced to f32: smoke tests *execute* on CPU,
    whose runtime lacks some bf16 dot kernels (the full configs stay bf16 — the
    dry-run only lowers + compiles)."""
    import dataclasses
    import jax.numpy as jnp

    return dataclasses.replace(_mod(name).smoke_config(), dtype=jnp.float32)
