"""TinyLlama 1.1B [arXiv:2401.02385] — llama2-arch small dense GQA.

22L d_model=2048 32H (GQA kv=4, head_dim 64) d_ff=5632 vocab=32000.
Sharding: Megatron TP (32 q-heads / 16), kv heads replicated.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    rope_theta=10_000.0,
    rules_override={"kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
        vocab=512, loss_chunk=64, remat=False,
    )
