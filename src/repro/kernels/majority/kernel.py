"""Pallas TPU kernel: bit-wise majority bundling (the HDC superposition op).

This is the operation the paper computes *over the air*; the kernel is the wired
digital reference the OTA path is compared against (and the fast path for bundling
on-device, e.g. prototype construction during HDC training).

Memory-bound: one pass over [M, bb, bd] uint8 slabs; the M (num-bundled) axis is
kept whole inside the block (M <= ~33 in practice), the [B, d] plane is tiled in
(32, 128) blocks to match the uint8 VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _majority_kernel(h_ref, o_ref, *, m: int):
    h = h_ref[...].astype(jnp.int32)        # [M, bb, bd]
    counts = jnp.sum(h, axis=0)             # [bb, bd]
    o_ref[...] = (counts * 2 > m).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bb", "bd", "interpret"))
def majority_pallas(
    hvs: jax.Array,
    *,
    bb: int = 32,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """hvs [M, B, d] uint8 -> [B, d] uint8. B % bb == d % bd == 0."""
    m, b, d = hvs.shape
    assert b % bb == 0 and d % bd == 0, (b, bb, d, bd)
    grid = (b // bb, d // bd)
    return pl.pallas_call(
        functools.partial(_majority_kernel, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((m, bb, bd), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.uint8),
        interpret=interpret,
    )(hvs)
