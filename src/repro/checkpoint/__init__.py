from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointError,
    all_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
