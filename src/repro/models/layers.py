"""Shared neural building blocks: norms, RoPE (incl. M-RoPE), attention.

All functions are pure; matmuls accumulate in f32 (`preferred_element_type`) and
norm/softmax math runs in f32 regardless of the activation dtype.

The training/prefill attention path is a blockwise *flash* formulation built from
two nested `lax.scan`s with online-softmax carries, so S×S score matrices never
materialize and the same code lowers on CPU (dry-run) and TPU. The decode path is
a direct masked attention over the (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms / activations / projections
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gain.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, gain: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gain.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """[..., d_in] @ [d_in, d_out]; f32 accumulation (bf16 under REDUCE_BF16)."""
    return jnp.einsum("...d,df->...f", x, w, preferred_element_type=_pet(x.dtype)).astype(x.dtype)


def gated_mlp(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array, act: str) -> jax.Array:
    h = act_fn(act)(dense(x, wg).astype(jnp.float32)).astype(x.dtype) * dense(x, wu)
    h = shard(h, "batch", "seq", "mlp")
    return dense(h, wd)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, head_dim: int, theta: jax.Array | float) -> jax.Array:
    """positions [...] -> angles [..., head_dim//2] (f32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: jax.Array | float,
    sections: tuple[int, ...] | None = None,
) -> jax.Array:
    """Rotate q/k: x [B, S, H, D], positions [B, S] (or [B, S, 3] for M-RoPE).

    M-RoPE (Qwen2-VL): the D/2 frequency slots are split into `sections`
    (t, h, w); slot group i takes its position from positions[..., i]. Text tokens
    carry identical (t, h, w) so M-RoPE degenerates to 1-D RoPE for them.
    """
    d = x.shape[-1]
    if sections is None:
        ang = _rope_angles(positions, d, theta)                    # [B, S, D/2]
    else:
        assert positions.shape[-1] == len(sections), (positions.shape, sections)
        ang_k = _rope_angles(positions, d, theta)                  # [B, S, K, D/2] (pos last dim -> K)
        ang_k = jnp.moveaxis(ang_k, -2, -1)                        # [B, S, D/2, K]
        import numpy as np
        sec_id = jnp.asarray(np.repeat(np.arange(len(sections)), sections))  # [D/2]
        ang = jnp.take_along_axis(ang_k, sec_id[None, None, :, None], axis=-1)[..., 0]
    cos = jnp.cos(ang)[..., None, :]                               # [B, S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [seq, d_model] (f32)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention — flash (train/prefill) and direct (decode)
# ---------------------------------------------------------------------------

def _expand_kv(k: jax.Array, groups: int) -> jax.Array:
    """[B, S, KH, D] -> [B, S, KH*G, D] by repeating each kv head G times."""
    if groups == 1:
        return k
    b, s, kh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, groups, d)).reshape(
        b, s, kh * groups, d
    )


# When True (default), flash_attention uses the FlashAttention-2-style custom
# VJP: the backward pass recomputes P blockwise instead of letting autodiff
# stash S²-sized residual stacks per layer. Toggled by the §Perf A/B harness.
FLASH_CUSTOM_VJP = True

# Expand GQA KV heads to the full head count ONCE per layer before the block
# loops (instead of per block). Off by default: with replicated heads the
# per-block expand is free, but with (uneven) head-sharded activations GSPMD
# otherwise reshards KV on EVERY (q, kv) block step (measured: 94% of all
# collective bytes at deepseek-33b prefill_32k). Enabled by the perf harness
# together with __uneven__ head sharding.
EXPAND_KV_EARLY = False

# Materialize the per-block attention probabilities (and dS in the backward) in
# bf16 instead of f32. Softmax statistics (m, l, lse) stay f32. Halves the
# dominant block-temporary HBM traffic at a ~1e-3 relative error in P (§Perf).
FLASH_P_BF16 = False

# Emit projection matmuls in bf16 instead of f32: per-shard MXU accumulation is
# f32 either way, but GSPMD places the cross-shard all-reduce on the dot OUTPUT,
# so f32 outputs double every Megatron-style activation all-reduce and every
# FSDP gradient collective. bf16 reduction is standard large-scale practice
# (documented quality tradeoff). Toggled by the perf harness.
REDUCE_BF16 = False


def _pet(dtype):
    # preferred_element_type for projection dots
    return dtype if REDUCE_BF16 else jnp.float32


@jax.custom_vjp
def bf16_grad(x):
    """Identity whose cotangent is cast to bf16.

    Placed at the stack/loss boundary under REDUCE_BF16: the chunked-CE backward
    emits an f32 cotangent which otherwise stays f32 through every residual add
    and backward dot — making all 61 per-layer gradient all-reduces f32
    (measured: 58% of kimi-k2 train collective bytes). Casting once here makes
    the whole backward graph bf16-typed.
    """
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype) if g.dtype == jnp.bfloat16
            else g.astype(jnp.bfloat16),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (blocks must tile the sequence;
    cells are powers of two, whisper's 1500 frames tile at 500/750)."""
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1


def _mask(causal, window, q_pos, k_pos):
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    ok &= jnp.where(window > 0, q_pos[:, None] - k_pos[None, :] < window, True)
    return ok


def _flash_fwd_impl(q, k, v, window, causal, q_offset, block_q, block_k):
    """Returns (out [B,Sq,H,D], lse [B,H,Sq])."""
    b, sq, h, d = q.shape
    if EXPAND_KV_EARLY and k.shape[2] != h:
        k = shard(_expand_kv(k, h // k.shape[2]), "batch", "seq", "heads", None)
        v = shard(_expand_kv(v, h // v.shape[2]), "batch", "seq", "heads", None)
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(d)
    window = jnp.asarray(window, jnp.int32)

    kr = jnp.moveaxis(k.reshape(b, nk, block_k, kh, d), 1, 0)   # [nk, B, bk, KH, D]
    vr = jnp.moveaxis(v.reshape(b, nk, block_k, kh, d), 1, 0)
    qr = jnp.moveaxis(q.reshape(b, nq, block_q, h, d), 1, 0)    # [nq, B, bq, H, D]

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            k_full = _expand_kv(k_blk, g)
            v_full = _expand_kv(v_blk, g)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk, k_full, preferred_element_type=jnp.float32
            ) * scale
            k_pos = kj * block_k + jnp.arange(block_k)
            s = jnp.where(_mask(causal, window, q_pos, k_pos)[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            if FLASH_P_BF16:
                p = p.astype(jnp.bfloat16)
            l_new = l * alpha + jnp.sum(p.astype(jnp.float32), -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_full.dtype), v_full,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return jnp.moveaxis(out, 1, 2).astype(q.dtype), lse       # [B,bq,H,D], [B,H,bq]

    outs, lses = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qr))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d)
    lse = jnp.concatenate([lses[i] for i in range(nq)], axis=-1) if nq > 1 else lses[0]
    return out, lse


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, window, causal, q_offset, block_q, block_k):
    out, _ = _flash_fwd_impl(q, k, v, window, causal, q_offset, block_q, block_k)
    return out


def _flash_vjp_fwd(q, k, v, window, causal, q_offset, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, window, causal, q_offset, block_q, block_k)
    return out, (q, k, v, out, lse, window)


def _flash_vjp_bwd(causal, q_offset, block_q, block_k, res, dout):
    """FlashAttention-2 backward: P recomputed per (kv, q) block pair.

    Outer scan over kv blocks (emits dK_j, dV_j; carries dQ); inner scan over q
    blocks. Only block-sized temporaries live; no S² residuals.
    """
    q, k, v, out, lse, window = res
    b, sq, h, d = q.shape
    if EXPAND_KV_EARLY and k.shape[2] != h:
        k = shard(_expand_kv(k, h // k.shape[2]), "batch", "seq", "heads", None)
        v = shard(_expand_kv(v, h // v.shape[2]), "batch", "seq", "heads", None)
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(d)

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # [B,Sq,H]
    delta = jnp.moveaxis(delta, -1, 1)                                            # [B,H,Sq]
    qr = jnp.moveaxis(q.reshape(b, nq, block_q, h, d), 1, 0)
    dor = jnp.moveaxis(dout.reshape(b, nq, block_q, h, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, block_k, kh, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, block_k, kh, d), 1, 0)
    lser = jnp.moveaxis(lse.reshape(b, h, nq, block_q), 2, 0)                     # [nq,B,H,bq]
    deltar = jnp.moveaxis(delta.reshape(b, h, nq, block_q), 2, 0)

    def kv_block(dq_full, inp):
        kj, k_blk, v_blk = inp
        k_full = _expand_kv(k_blk, g).astype(jnp.float32)
        v_full = _expand_kv(v_blk, g).astype(jnp.float32)
        k_pos = kj * block_k + jnp.arange(block_k)

        def q_step(carry, qinp):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, dl_blk = qinp
            q_pos = q_offset + qi * block_q + jnp.arange(block_q)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk.astype(jnp.float32), k_full,
            ) * scale
            ok = _mask(causal, window, q_pos, k_pos)
            s = jnp.where(ok[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])                                   # [B,H,bq,bk]
            if FLASH_P_BF16:
                p = p.astype(jnp.bfloat16)
            dv_acc = dv_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", p, do_blk.astype(p.dtype),
                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_blk.astype(jnp.float32), v_full)
            ds = p.astype(jnp.float32) * (dp - dl_blk[..., None]) * scale
            if FLASH_P_BF16:
                ds = ds.astype(jnp.bfloat16)
            dq_blk = jnp.einsum("bhqk,bkhd->bqhd", ds, k_full.astype(ds.dtype),
                                preferred_element_type=jnp.float32)
            dk_acc = dk_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", ds, q_blk.astype(ds.dtype),
                preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), dq_blk

        dk0 = jnp.zeros((b, block_k, h, d), jnp.float32)
        dv0 = jnp.zeros((b, block_k, h, d), jnp.float32)
        (dk_e, dv_e), dq_blocks = jax.lax.scan(
            q_step, (dk0, dv0), (jnp.arange(nq), qr, dor, lser, deltar)
        )
        dq_full = dq_full + jnp.moveaxis(dq_blocks, 0, 1).reshape(b, sq, h, d)
        # GQA: fold the expanded heads back onto kv heads
        dk_j = jnp.sum(dk_e.reshape(b, block_k, kh, g, d), axis=3)
        dv_j = jnp.sum(dv_e.reshape(b, block_k, kh, g, d), axis=3)
        return dq_full, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_block, dq0, (jnp.arange(nk), kr, vr))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, skv, kh, d)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, skv, kh, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int = -1,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Blockwise attention with online softmax.

    q [B, Sq, H, D]; k, v [B, Skv, KH, D] with H % KH == 0. `window` (static or
    traced scalar) masks keys with q_pos - k_pos >= window when window > 0; -1 (or
    any negative) means global. Block sizes are clipped to the sequence lengths;
    Sq/Skv must divide by the (clipped) blocks — shape cells are powers of two.

    With FLASH_CUSTOM_VJP (default) the backward pass is the blockwise
    FlashAttention-2 recomputation; otherwise plain autodiff through the scans
    (which stashes S²-sized residuals — kept for the §Perf A/B).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    block_q = _largest_divisor(sq, block_q)
    block_k = _largest_divisor(skv, block_k)
    window = jnp.asarray(window, jnp.int32)
    if FLASH_CUSTOM_VJP:
        return _flash(q, k, v, window, causal, q_offset, block_q, block_k)
    out, _ = _flash_fwd_impl(q, k, v, window, causal, q_offset, block_q, block_k)
    return out


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    cur_pos: jax.Array,
    *,
    window: jax.Array | int = -1,
) -> jax.Array:
    """Single-token attention over a (ring) KV cache.

    q [B, 1, H, D]; caches [B, Sc, KH, D]; slot_pos [Sc] = absolute position held
    by each cache slot (-1 = empty); cur_pos = current decode position (scalar).
    """
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    window = jnp.asarray(window, jnp.int32)
    k_full = _expand_kv(k_cache, g)
    v_full = _expand_kv(v_cache, g)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k_full, preferred_element_type=jnp.float32
    ) * scale                                                     # [B, H, 1, Sc]
    ok = (slot_pos >= 0) & (slot_pos <= cur_pos)
    ok &= jnp.where(window > 0, cur_pos - slot_pos < window, True)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v_full.dtype), v_full,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)
