"""Pluggable PHY channel models for the over-the-air serve path.

The paper's whole argument hangs on one abstraction: the OTA majority channel
can be summarized as a per-RX bit-error rate (Eq. 1) without changing what the
classifier sees. This module makes that abstraction a *swappable layer* of the
serve step instead of a baked-in assumption. Three fidelity tiers implement
one `Channel` interface:

* ``ideal``  — error-free: every IMC core receives the exact majority bundle.
* ``bsc``    — the paper's methodology (and the previous hard-coded behavior):
  each core decodes a binary-symmetric-channel copy at its pre-characterized
  BER. Bit-identical to the old inline ``_core_noise`` path on the same RNG
  stream — the tier every prediction-identity guarantee is pinned to.
* ``symbol`` — the actual physics, fully batched and in-graph: per dimension,
  the M transmitters' phase-encoded symbols superpose in the channel
  (`ota.rx_constellations`), each receiver adds complex AWGN and decodes via
  its majority decision regions (`ota.majority_centroids`) — a vectorized
  re-hosting of ``ota.simulate_ota_bundle`` inside the ``shard_map`` serve
  body. This is the tier that *verifies* "BER 0.01 with no accuracy impact"
  end-to-end instead of assuming it.

The precharacterization outputs travel as a :class:`ChannelState` pytree
(channel matrix ``h``, chosen ``phase_idx``, constellation ``symbols``,
decision centroids ``c0``/``c1``, noise density ``n0``, per-RX ``ber`` +
``valid``) threaded through ``make_ota_serve``/``make_wired_serve`` in place
of the bare BER array; every leaf with a leading RX axis shards over the
``model`` mesh axis exactly like the prototype memory it sits next to.

Distribution note — the ``symbol`` tier's wire payload: the received symbol of
RX r at dimension j depends on the TX bits only through the combo index
``b = sum_m bit_mj * 2^m`` (``y[r, b] = sum_m H[r, m] * exp(i*phi_m(bit_mj))``
is precomputed per combo in ``symbols``). Since the combo index is itself a
weighted *sum* of per-TX contributions, the analog field superposition
re-hosts exactly as ONE int32 psum over the model axis — the same
single-collective shape as the paper's OTA reduction — followed by a purely
local constellation lookup + AWGN + decision at each core. No approximation:
indexing the precomputed constellation by the summed combo equals summing the
per-TX complex fields.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hypervector as hv, ota


@dataclasses.dataclass(frozen=True)
class ChannelState:
    """Precharacterized channel state (one pytree, [N] = RX cores leading).

    Produced offline by the EM + constellation pipeline (the paper's CST +
    MATLAB step) via `state_from_ota`, or synthesized from a bare BER table
    via `state_from_ber` for the ``ideal``/``bsc`` tiers that never touch the
    physical fields.
    """

    ber: jax.Array        # [N] f32 — Eq. (1) per-RX BER (the bsc abstraction)
    valid: jax.Array      # [N] bool — majority decision regions are a 2-means fit
    h: jax.Array          # [N, M] c64 — channel matrix (quasi-static, known a priori)
    phase_idx: jax.Array  # [M, 2] i32 — jointly optimized TX phase pairs
    symbols: jax.Array    # [N, 2^M] c64 — noiseless received constellation per combo
    c0: jax.Array         # [N] c64 — maj=0 decision-region centroid
    c1: jax.Array         # [N] c64 — maj=1 decision-region centroid
    n0: jax.Array         # [] f32 — AWGN noise density (per-component var n0/2)

    @property
    def n_rx(self) -> int:
        return self.ber.shape[0]

    @property
    def m_tx(self) -> int:
        return self.h.shape[1]


jax.tree_util.register_pytree_node(
    ChannelState,
    lambda s: ((s.ber, s.valid, s.h, s.phase_idx, s.symbols, s.c0, s.c1, s.n0), None),
    lambda _, leaves: ChannelState(*leaves),
)


def state_from_ota(res: "ota.OTAResult", h: jax.Array) -> ChannelState:
    """Package an `ota.OTAResult` + its channel matrix as a ChannelState."""
    m = h.shape[1]
    maj = ota.majority_labels(m)
    c0, c1 = ota.majority_centroids(res.symbols, maj)
    return ChannelState(
        ber=jnp.asarray(res.ber_per_rx, jnp.float32),
        valid=jnp.asarray(res.valid_per_rx, bool),
        h=jnp.asarray(h, jnp.complex64),
        phase_idx=jnp.asarray(res.phase_idx, jnp.int32),
        symbols=jnp.asarray(res.symbols, jnp.complex64),
        c0=jnp.asarray(c0, jnp.complex64),
        c1=jnp.asarray(c1, jnp.complex64),
        n0=jnp.asarray(res.n0, jnp.float32),
    )


def state_from_ber(ber: jax.Array, m_tx: int) -> ChannelState:
    """Minimal state for the ``ideal``/``bsc`` tiers from a bare BER table.

    The physical fields are zero placeholders with the correct shapes (they
    are inputs of the compiled serve program either way, and a few KB at
    most), and ``valid`` is all-False: these rows carry NO usable decision
    regions.  Every tier treats invalid rows as "trust the analytic BER,
    not the physics": ``bsc`` flips at ``ber`` (its only model anyway),
    ``ideal`` ignores the state, and the ``symbol`` tier falls back to
    majority + BSC flips at ``ber`` for such rows instead of silently
    decoding the all-zero constellation (which would return constant bits
    and poison the vote).  Build real physics with `state_from_ota` /
    `scaleout.precharacterize_state`.
    """
    ber = jnp.asarray(ber, jnp.float32)
    n = ber.shape[0]
    b = 2 ** m_tx
    return ChannelState(
        ber=ber,
        valid=jnp.zeros((n,), bool),
        h=jnp.zeros((n, m_tx), jnp.complex64),
        phase_idx=jnp.zeros((m_tx, 2), jnp.int32),
        symbols=jnp.zeros((n, b), jnp.complex64),
        c0=jnp.zeros((n,), jnp.complex64),
        c1=jnp.zeros((n,), jnp.complex64),
        n0=jnp.ones((), jnp.float32),
    )


def state_spec(rx_axis: str | None = "model") -> ChannelState:
    """PartitionSpec tree for a ChannelState: RX-leading leaves shard over
    `rx_axis` (aligned with the prototype/core sharding), the rest replicate.
    Feed directly to `compat.shard_map`'s in_specs."""
    from jax.sharding import PartitionSpec as P

    rx = P(rx_axis)
    rx2 = P(rx_axis, None)
    return ChannelState(ber=rx, valid=rx, h=rx2, phase_idx=P(), symbols=rx2,
                        c0=rx, c1=rx, n0=P())


def state_shape_structs(n_rx: int, m_tx: int) -> ChannelState:
    """ShapeDtypeStruct tree matching `state_from_ber`/`state_from_ota` output
    — for AOT lowering (the dry-run cells) without running the EM pipeline."""
    s = jax.ShapeDtypeStruct
    b = 2 ** m_tx
    return ChannelState(
        ber=s((n_rx,), jnp.float32), valid=s((n_rx,), bool),
        h=s((n_rx, m_tx), jnp.complex64), phase_idx=s((m_tx, 2), jnp.int32),
        symbols=s((n_rx, b), jnp.complex64), c0=s((n_rx,), jnp.complex64),
        c1=s((n_rx,), jnp.complex64), n0=s((), jnp.float32),
    )


def combo_index(bits: jax.Array, axis: int = 0) -> jax.Array:
    """TX bit combo index along `axis`: bits [.., M, ..] {0,1} -> int32 [..].

    The LSB-first weighting matches `ota.bit_combos` (TX 0 = bit 0), so
    ``symbols[:, combo_index(q)]`` is the noiseless received field of the
    transmission — the per-dimension column of `ota.rx_constellations`.
    """
    m = bits.shape[axis]
    shape = [1] * bits.ndim
    shape[axis] = m
    weights = (jnp.int32(1) << jnp.arange(m, dtype=jnp.int32)).reshape(shape)
    return jnp.sum(bits.astype(jnp.int32) * weights, axis=axis)


# the ONE physical decode definition, shared with `ota.simulate_ota_bundle`
awgn_decide = ota.awgn_decide


# ---------------------------------------------------------------------------
# the Channel interface + tiers
# ---------------------------------------------------------------------------

class Channel:
    """One fidelity tier of the OTA link inside the serve step.

    ``wire`` names what the TX columns reduce over the mesh axis:

    * ``"votes"`` — bipolar majority votes; the serve step keeps its existing
      collective realizations (psum / psum_packed / rs_ag) and hands the
      thresholded bundle to `rx_copies`.
    * ``"combo"`` — the int32 TX bit-combo index (ONE psum); `rx_copies` gets
      the summed combo and performs the physical per-core decode.

    `rx_copies` produces every local core's received copy of the query:
    [n_cores, B, d] uint8 bits, or [n_cores, B, d/32] uint32 words when
    ``packed`` (the symbol tier decodes bits, then packs — the IMC macro
    stores bits either way). ``rx_base + i`` indexes the global RX core for
    the PRNG fold, the SAME schedule for every tier so swapping tiers never
    perturbs an unrelated stream.
    """

    name: str = "?"
    wire: str = "votes"

    def rx_copies(self, key, reduced, state: ChannelState, rx_base, n_cores: int,
                  *, packed: bool, dim: int, noise: str, planes: int) -> jax.Array:
        raise NotImplementedError


class IdealChannel(Channel):
    """Error-free link: every core receives the exact majority bundle."""

    name = "ideal"
    wire = "votes"

    def rx_copies(self, key, reduced, state, rx_base, n_cores,
                  *, packed, dim, noise, planes):
        return jnp.broadcast_to(reduced[None], (n_cores,) + reduced.shape)


class BSCChannel(Channel):
    """Per-RX binary symmetric channel at the precharacterized BER (Eq. 1).

    The paper's abstraction and the repo default — bit-identical to the
    pre-phy inline serve noise on the same RNG stream: core i folds
    ``rx_base + i`` into the key and flips at ``state.ber[i]``. The packed
    representation honors the ``exact``/``bitplane`` mask modes.
    """

    name = "bsc"
    wire = "votes"

    def rx_copies(self, key, reduced, state, rx_base, n_cores,
                  *, packed, dim, noise, planes):
        from repro.distributed import collectives

        def one(i, ber):
            k = jax.random.fold_in(key, rx_base + i)
            if packed:
                return collectives.ota_noise_packed(k, reduced, ber,
                                                    mode=noise, planes=planes)
            return collectives.ota_noise(k, reduced, ber)

        return jax.vmap(one)(jnp.arange(n_cores), state.ber)


class SymbolChannel(Channel):
    """Physical OTA: constellation superposition + AWGN + decision regions.

    ``reduced`` is the psum'd combo index [B, d] int32 (see module docstring:
    the combo psum IS the field superposition, re-hosted losslessly). Each
    local core looks up its noiseless received symbol ``symbols[i][combo]``,
    adds complex AWGN at ``n0`` and decides against its (c0, c1) centroids —
    `ota.simulate_ota_bundle` vectorized over cores x batch x dimensions.
    Decodes bits, then packs when the serve representation is packed.
    """

    name = "symbol"
    wire = "combo"

    def rx_copies(self, key, reduced, state, rx_base, n_cores,
                  *, packed, dim, noise, planes):
        def one(i, sym_row, c0, c1):
            k = jax.random.fold_in(key, rx_base + i)
            return awgn_decide(k, sym_row[reduced], c0, c1, state.n0)

        bits = jax.vmap(one)(jnp.arange(n_cores), state.symbols, state.c0,
                             state.c1)  # [n_cores, B, d]

        m = state.m_tx

        def with_fallback(b):
            # rows with valid=False carry no usable decision regions — either
            # the 2-means constraint failed at characterization (their
            # analytic BER is pinned to 0.5) or the state is a
            # `state_from_ber` synthesis with zero physics. Decoding the raw
            # constellation there returns constant garbage that poisons the
            # vote; fall back to the analytic-BER abstraction instead:
            # exact majority + BSC flips at `state.ber`. The fallback stream
            # is a fold_in(., 1) off the per-core key, so VALID rows' RNG
            # (consumed inside awgn_decide off the un-suffixed key) is
            # untouched — all-valid states stay bit-identical.
            exact = ota.majority_labels(m)[reduced]  # [.., d] true majority

            def flips(i, ber):
                k = jax.random.fold_in(jax.random.fold_in(key, rx_base + i), 1)
                f = jax.random.bernoulli(k, ber, exact.shape)
                return jnp.logical_xor(exact.astype(bool), f).astype(jnp.uint8)

            fb = jax.vmap(flips)(jnp.arange(n_cores), state.ber)
            return jnp.where(
                state.valid.reshape((n_cores,) + (1,) * (b.ndim - 1)), b, fb
            )

        # all-valid states (every real characterization in the repo) skip the
        # fallback branch at runtime — lax.cond, not select: the predicate is
        # unbatched even under the multi-tenant slot vmap
        bits = jax.lax.cond(jnp.all(state.valid), lambda b: b, with_fallback,
                            bits)
        return hv.pack(bits) if packed else bits


CHANNELS: dict[str, Channel] = {}


def register_channel(channel: Channel, *, override: bool = False) -> Channel:
    """Register a `Channel` tier under ``channel.name`` for `get_channel`.

    The extension seam for out-of-tree fidelity tiers (and the process
    subsystem's derived channels): implement the `Channel` interface, register
    an instance, and ``ScaleOutConfig(channel=<name>)`` picks it up without
    editing this module. Re-registering a taken name raises unless
    ``override=True`` (deliberate replacement, e.g. an instrumented tier in a
    test).  Returns the instance so it can be used as a decorator-ish one-liner.
    """
    name = getattr(channel, "name", None)
    if not isinstance(name, str) or not name or name == "?":
        raise ValueError(f"channel must define a non-empty .name, got {name!r}")
    if not callable(getattr(channel, "rx_copies", None)):
        raise TypeError(f"channel {name!r} does not implement rx_copies()")
    if name in CHANNELS and not override:
        raise ValueError(
            f"channel tier {name!r} already registered; pass override=True "
            "to replace it"
        )
    CHANNELS[name] = channel
    return channel


for _tier in (IdealChannel(), BSCChannel(), SymbolChannel()):
    register_channel(_tier)
del _tier


def get_channel(name: str) -> Channel:
    try:
        return CHANNELS[name]
    except KeyError:
        raise ValueError(
            f"unknown channel tier {name!r}; available: {sorted(CHANNELS)}"
        ) from None
