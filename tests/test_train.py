"""Training substrate: optimizer, fault tolerance, data pipeline, checkpointing."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_test_mesh

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLM
from repro.models import get_model
from repro.train.loop import Trainer, TrainerConfig, build_train_fns
from repro.train.optimizer import OptConfig, lr_at, zero1_axes

KEY = jax.random.PRNGKey(0)


def _mesh():
    return make_test_mesh((1, 1), ("data", "model"))


def test_lr_schedule():
    cfg = OptConfig(lr=1e-3, warmup=10, total_steps=100)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_at(cfg, jnp.int32(100))) < 2e-4  # cosine floor 0.1x


def test_zero1_axes_adds_fsdp():
    axes = {"w": ("embed", "mlp"), "b": (None, None), "v": ("vocab",)}
    z = zero1_axes(axes)
    assert z["b"][0] == "fsdp"          # first replicated dim of 2-D tensor
    assert z["w"] == ("embed", "mlp")   # fully annotated stays
    assert z["v"] == ("vocab",)         # 1-D untouched


def test_data_pipeline_skip_ahead_deterministic():
    pipe = SyntheticLM(DataConfig(vocab=1000, seq=64, global_batch=4))
    b1 = pipe.batch(17)
    b2 = pipe.batch(17)  # O(1) random access, no replay
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(pipe.batch(18)["tokens"]), np.asarray(b1["tokens"]))


def test_data_pipeline_host_sharding_partitions_batch():
    full = SyntheticLM(DataConfig(vocab=1000, seq=32, global_batch=8), 0, 1)
    h0 = SyntheticLM(DataConfig(vocab=1000, seq=32, global_batch=8), 0, 2)
    h1 = SyntheticLM(DataConfig(vocab=1000, seq=32, global_batch=8), 1, 2)
    assert h0.batch(3)["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(h0.batch(3)["tokens"]),
                              np.asarray(h1.batch(3)["tokens"]))
    del full


def test_checkpoint_atomic_keep_and_restore(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    for step in (1, 2, 3, 4):
        save_checkpoint(d, step, tree, extra={"data_step": step}, keep=2)
    assert latest_step(d) == 4
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d) if p.startswith("step_"))
    assert steps == [3, 4]  # keep-2 pruned
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = restore_checkpoint(d, 4, like)
    assert extra["data_step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_trainer_failure_resume_bit_identical(tmp_path):
    mesh = _mesh()
    cfg = configs.get_smoke("smollm_360m")
    model = get_model(cfg)
    fns = build_train_fns(model, mesh, OptConfig(lr=1e-3, warmup=5, total_steps=20))
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=64, global_batch=4))

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    tr = Trainer(fns, pipe, TrainerConfig(steps=12, ckpt_every=5, ckpt_dir=d1, log_every=100), mesh)
    with pytest.raises(RuntimeError):
        tr.run(KEY, fail_at=8, quiet=True)     # crash mid-run
    p1, _, l1 = tr.run(KEY, quiet=True)        # restart resumes from step 5

    tr2 = Trainer(fns, pipe, TrainerConfig(steps=12, ckpt_every=5, ckpt_dir=d2, log_every=100), mesh)
    p2, _, l2 = tr2.run(KEY, quiet=True)       # no failure
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d == 0.0
    assert l1[-1] == l2[-1]


def test_adamw_loss_decreases():
    mesh = _mesh()
    cfg = configs.get_smoke("gemma3_1b")
    model = get_model(cfg)
    fns = build_train_fns(model, mesh, OptConfig(lr=1e-3, warmup=5, total_steps=30))
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=128, global_batch=4))
    params, opt_state = fns.init(KEY)
    losses = []
    for step in range(15):
        params, opt_state, m = fns.step(params, opt_state, pipe.batch(step), KEY)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_grad_accum_matches_full_batch():
    mesh = _mesh()
    cfg = configs.get_smoke("tinyllama_1_1b")
    model = get_model(cfg)
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=64, global_batch=8))
    f1 = build_train_fns(model, mesh, OptConfig(lr=1e-3, warmup=2, total_steps=10), microbatch=1)
    f4 = build_train_fns(model, mesh, OptConfig(lr=1e-3, warmup=2, total_steps=10), microbatch=4)
    p1, s1 = f1.init(KEY)
    p4, s4 = f4.init(KEY)
    for step in range(3):
        b = pipe.batch(step)
        p1, s1, m1 = f1.step(p1, s1, b, KEY)
        p4, s4, m4 = f4.step(p4, s4, b, KEY)
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-4, d  # f32 reduction-order tolerance (varies with XLA version)


def test_checkpoint_restore_defends_against_corruption(tmp_path):
    """Every failure mode raises CheckpointError naming the step and leaf —
    never a raw numpy/json/pytree traceback: missing step, bit-flipped leaf
    (CRC mismatch), truncated leaf, deleted leaf file, garbage manifest, and
    a checkpoint that does not cover the requested structure."""
    import json

    from repro.checkpoint import CheckpointError

    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)

    with pytest.raises(CheckpointError, match="no committed checkpoint"):
        restore_checkpoint(d, 1, like)

    for step in (1, 2, 3, 4, 5):
        save_checkpoint(d, step, tree, keep=0)

    # bit-flip -> CRC mismatch
    leaf = tmp_path / "ck" / "step_1" / "a.npy"
    blob = bytearray(leaf.read_bytes())
    blob[-1] ^= 0xFF
    leaf.write_bytes(bytes(blob))
    with pytest.raises(CheckpointError, match="corrupt"):
        restore_checkpoint(d, 1, like)

    # truncation -> CRC mismatch (caught before np.load can crash)
    leaf2 = tmp_path / "ck" / "step_2" / "b__c.npy"
    leaf2.write_bytes(leaf2.read_bytes()[:16])
    with pytest.raises(CheckpointError, match="corrupt"):
        restore_checkpoint(d, 2, like)

    # deleted leaf file
    os.remove(tmp_path / "ck" / "step_3" / "a.npy")
    with pytest.raises(CheckpointError, match="file missing"):
        restore_checkpoint(d, 3, like)

    # garbage manifest
    (tmp_path / "ck" / "step_4" / "manifest.json").write_text("{not json")
    with pytest.raises(CheckpointError, match="manifest.json unreadable"):
        restore_checkpoint(d, 4, like)

    # structure drift: a leaf the checkpoint never saved
    like2 = {**like, "z": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(CheckpointError, match="missing leaves"):
        restore_checkpoint(d, 5, like2)

    # back-compat: a pre-checksum checkpoint (no crc32 fields) still restores
    mpath = tmp_path / "ck" / "step_5" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    for entry in manifest["leaves"]:
        del entry["crc32"]
    mpath.write_text(json.dumps(manifest))
    restored, _ = restore_checkpoint(d, 5, like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
