"""Shared helpers for the Pallas kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True`` — the kernel body runs in Python against the
same BlockSpec pipeline, so index maps / tiling bugs surface on CPU.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Interpret mode on anything that is not a real TPU (CPU CI, dry-run host)."""
    return jax.default_backend() != "tpu"


def pad_dim(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    """Pad `axis` of `x` up to the next multiple of `multiple` with `fill`."""
    import jax.numpy as jnp

    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
