"""Trip-count-aware cost analysis of optimized HLO text.

XLA's cost analysis (see `xla_reported_cost`) counts a while-loop body ONCE, so any
`lax.scan`-based stack (every model here: layer stacks, flash-attention block
loops, loss chunks, microbatches) is undercounted by its trip count. This module
re-derives costs from `compiled.as_text()`:

1. parse the module into computations, ops and a per-computation symbol table
   (operands in optimized HLO are %name references, not inline shapes);
2. read each while loop's trip count from its backend_config
   ``known_trip_count`` (fallback: the s32 constant in its condition);
3. propagate execution multipliers through the call graph — while bodies
   multiply by the trip count, calls/fusions/conditionals inherit;
4. accumulate:
   * FLOPs        — dot/convolution ops: 2 * prod(result) * prod(contracting),
                    including dots inside fusion bodies;
   * HBM traffic  — operand + result bytes of top-level ops (fusion parameters
     and results are the materialized buffers; fusion-internal ops are free) —
     the same "sum of buffers" model XLA's cost analysis uses;
   * collectives  — operand bytes of all-gather / all-reduce / reduce-scatter /
                    all-to-all / collective-permute (+ async -start forms),
                    split by type.

Validated in tests/test_analysis.py against XLA's own numbers on scan-free
programs and against analytic FLOPs on scanned/shard_mapped ones.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.compat import normalized_cost_analysis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*|pred|token)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r"known_trip_count.*?n\\?\":\\?\"(\d+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _dims(dims_str: str) -> list[int]:
    return [int(d) for d in dims_str.split(",") if d]


def _shape_bytes_from_spec(spec: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(spec):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shape_spec: str    # result type text (may be a tuple)
    args: list[str]    # operand %names
    attrs: str         # trailing attribute text
    operand_text: str = ""  # raw text inside the call parens
    is_root: bool = False


def _parse_op(body: str) -> Op | None:
    """body: text after '%name = '."""
    body = body.strip()
    # result shape spec: tuple '(...)' or single token
    if body.startswith("("):
        depth = 0
        for i, ch in enumerate(body):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        spec, rest = body[: i + 1], body[i + 1 :]
    else:
        sp = body.find(" ")
        if sp < 0:
            return None
        spec, rest = body[:sp], body[sp:]
    m = re.match(r"\s*([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    # operand list: balanced paren group after opcode
    start = m.end() - 1
    depth = 0
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    operand_text = rest[start + 1 : i]
    attrs = rest[i + 1 :]
    args = _OPERAND_NAME.findall(operand_text)
    return Op("", opcode, spec, args, attrs, operand_text)


def parse_module(text: str):
    """Returns ({comp: {'ops': [Op], 'table': {name: shape_spec}}}, entry)."""
    comps: dict[str, dict] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped and (
                stripped.startswith("%") or stripped.startswith("ENTRY")
            ):
                name = stripped.split()[1 if stripped.startswith("ENTRY") else 0]
                name = name.split("(")[0].lstrip("%").rstrip()
                comps[name] = {"ops": [], "table": {}}
                cur = comps[name]
                if stripped.startswith("ENTRY"):
                    entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = _parse_op(m.group(2))
        if op is None:
            continue
        op.name = m.group(1)
        op.is_root = line.lstrip().startswith("ROOT")
        cur["ops"].append(op)
        cur["table"][op.name] = op.shape_spec
    return comps, entry


def _trip_count(op: Op, comps) -> int:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%([\w\.\-]+)", op.attrs)
    if cm and cm.group(1) in comps:
        best = 1
        for o in comps[cm.group(1)]["ops"]:
            for c in _CONST_S32.findall(o.shape_spec + o.attrs):
                best = max(best, int(c))
        return best
    return 1


_CALL_ATTR = re.compile(r"(?:body|calls|to_apply|branch_computations=\{[^}]*)=?%([\w\.\-]+)")


def _called(op: Op) -> list[tuple[str, str]]:
    """[(kind, computation)] referenced by this op."""
    out = []
    for attr, kind in (("body", "while_body"), ("condition", "while_cond"),
                       ("calls", "fusion"), ("to_apply", "apply")):
        for m in re.finditer(attr + r"=%([\w\.\-]+)", op.attrs):
            out.append((kind, m.group(1)))
    bm = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if bm:
        for c in bm.group(1).split(","):
            out.append(("branch", c.strip().lstrip("%")))
    return out


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective: dict
    raw_flops: float = 0.0
    contributions: list | None = None   # [(bytes, comp, opcode, op_name)] when detail=True

    @property
    def coll_total(self) -> float:
        return float(self.collective.get("total", 0.0))


def analyze_compiled(compiled, detail: bool = False) -> HloCost:
    """Trip-count-aware analysis straight from a ``jax.stages.Compiled``."""
    return analyze(compiled.as_text(), detail=detail)


def xla_reported_cost(compiled) -> dict:
    """XLA's own cost_analysis as a flat dict on every JAX version.

    These are the *raw* numbers (scan bodies counted once — see module
    docstring); ``analyze_compiled`` is the trip-count-corrected view.
    """
    return normalized_cost_analysis(compiled)


def analyze(text: str, detail: bool = False) -> HloCost:
    comps, entry = parse_module(text)
    if entry is None:
        return HloCost(0.0, 0.0, {"total": 0, "count": 0})
    mult: dict[str, float] = defaultdict(float)
    no_bytes: set[str] = set()  # fusion/apply bodies: internals are not HBM traffic
    mult[entry] = 1.0
    stack = [entry]
    visited = set()
    while stack:
        name = stack.pop()
        if name in visited or name not in comps:
            continue
        visited.add(name)
        m = mult[name]
        for op in comps[name]["ops"]:
            trip = _trip_count(op, comps) if op.opcode == "while" else 1
            for kind, child in _called(op):
                f = trip if kind in ("while_body", "while_cond") else 1.0
                new = m * f
                if kind in ("fusion", "apply"):
                    no_bytes.add(child)
                if new > mult[child]:
                    mult[child] = new
                    visited.discard(child)
                stack.append(child)

    # Effective per-parameter traffic of fusion bodies: a parameter consumed only
    # by dynamic-slice/slice/gather reads just the sliced region (scan bodies
    # slice the [L, ...] stacked weights); anything else reads the full buffer.
    def _fusion_param_bytes(comp_name: str) -> dict[int, float | None]:
        out: dict[int, float | None] = {}
        comp = comps.get(comp_name)
        if comp is None:
            return out
        param_idx: dict[str, int] = {}
        for op in comp["ops"]:
            if op.opcode == "parameter" and op.operand_text.strip().isdigit():
                param_idx[op.name] = int(op.operand_text.strip())
        sliced: dict[int, float] = defaultdict(float)
        full: set[int] = set()
        for op in comp["ops"]:
            for ai, a in enumerate(op.args):
                if a not in param_idx:
                    continue
                i = param_idx[a]
                if op.opcode in ("dynamic-slice", "slice", "gather") and ai == 0:
                    sliced[i] += _shape_bytes_from_spec(op.shape_spec)
                elif op.opcode in ("dynamic-update-slice",) and ai == 0:
                    upd = _shape_bytes_from_spec(comp["table"].get(op.args[1], "")) if len(op.args) > 1 else 0
                    sliced[i] += upd
                elif op.opcode == "parameter":
                    continue
                else:
                    full.add(i)
        for i in sliced:
            if i not in full:
                out[i] = sliced[i]
        return out

    fusion_eff: dict[str, dict[int, float | None]] = {}

    def _fusion_result_bytes(comp_name: str, default: float) -> float:
        comp = comps.get(comp_name)
        if comp is None:
            return default
        byname = {o.name: o for o in comp["ops"]}
        root = next((o for o in comp["ops"] if o.is_root), None)
        if root is None:
            return default

        def resolve(op):
            seen = 0
            while op is not None and op.opcode in ("convert", "bitcast", "copy") and op.args and seen < 8:
                op = byname.get(op.args[0])
                seen += 1
            return op

        roots = [root]
        if root.opcode == "tuple":
            roots = [byname.get(a) for a in root.args]
        total = 0.0
        for r in roots:
            r = resolve(r)
            if r is None:
                return default
            if r.opcode == "dynamic-update-slice" and len(r.args) > 1:
                upd = byname.get(r.args[1])
                total += _shape_bytes_from_spec(
                    comp["table"].get(r.args[1], upd.shape_spec if upd else "")
                )
            else:
                total += _shape_bytes_from_spec(r.shape_spec)
        return min(total, default)

    fusion_res: dict[str, float] = {}

    flops = 0.0
    raw = 0.0
    hbm = 0.0
    coll: dict = defaultdict(float)
    ncoll = 0
    contributions: list = []
    skip_bytes = {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "while", "after-all", "iota", "conditional", "call", "partition-id",
        "replica-id",
    }
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        table = comp["table"]
        in_fused = name in no_bytes
        for op in comps[name]["ops"]:
            if op.opcode in ("dot", "convolution"):
                res = _shape_bytes_from_spec(op.shape_spec)
                res_elems = 0
                sm = _SHAPE_RE.search(op.shape_spec)
                if sm:
                    res_elems = 1
                    for d in _dims(sm.group(2)):
                        res_elems *= d
                k = 1
                cm = _CONTRACT.search(op.attrs)
                if cm and op.args:
                    lhs_spec = table.get(op.args[0], "")
                    lm = _SHAPE_RE.search(lhs_spec)
                    if lm:
                        dims = _dims(lm.group(2))
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                f = 2.0 * res_elems * k
                flops += m * f
                raw += f
            if in_fused:
                continue
            base = next((c for c in COLLECTIVES if op.opcode.startswith(c)), None)
            if base and not op.opcode.endswith("-done"):
                # operand + result bytes: an all-reduce moves ~2N per device, an
                # all-gather receives the full result (operand alone undercounts
                # by the gather factor), reduce-scatter sends the full operand.
                # One consistent send+receive model across all five collectives.
                nb = sum(_shape_bytes_from_spec(table.get(a, "")) for a in op.args)
                nb += _shape_bytes_from_spec(op.shape_spec)
                coll[base] += m * nb
                ncoll += 1
            if op.opcode in skip_bytes or op.opcode.endswith("-done"):
                continue
            if op.opcode in ("dynamic-slice", "slice", "gather", "broadcast", "reshape"):
                # reads only the sliced/gathered region ~= result bytes
                nb = 2 * _shape_bytes_from_spec(op.shape_spec)
            elif op.opcode in ("dynamic-update-slice", "scatter"):
                # reads + writes only the updated region (in-place inside loops)
                upd = (
                    _shape_bytes_from_spec(table.get(op.args[1], ""))
                    if len(op.args) > 1 else 0
                )
                nb = 2 * upd
            elif op.opcode == "fusion":
                cm = re.search(r"calls=%([\w\.\-]+)", op.attrs)
                eff = {}
                res_bytes = _shape_bytes_from_spec(op.shape_spec)
                if cm:
                    cname = cm.group(1)
                    if cname not in fusion_eff:
                        fusion_eff[cname] = _fusion_param_bytes(cname)
                        fusion_res[cname] = _fusion_result_bytes(cname, res_bytes)
                    eff = fusion_eff[cname]
                    res_bytes = fusion_res[cname]
                nb = res_bytes
                for i, a in enumerate(op.args):
                    e = eff.get(i)
                    nb += e if e is not None else _shape_bytes_from_spec(table.get(a, ""))
            else:
                nb = _shape_bytes_from_spec(op.shape_spec) + sum(
                    _shape_bytes_from_spec(table.get(a, "")) for a in op.args
                )
            hbm += m * nb
            if detail and m * nb > 0:
                contributions.append((m * nb, name, op.opcode, op.name))
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    coll["count"] = ncoll
    if detail:
        contributions.sort(key=lambda t: -t[0])
    return HloCost(flops=flops, hbm_bytes=hbm, collective=dict(coll), raw_flops=raw,
                   contributions=contributions if detail else None)
