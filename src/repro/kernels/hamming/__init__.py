from repro.kernels.hamming.ops import hamming_search, hamming_search_banked  # noqa: F401
