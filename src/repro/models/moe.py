"""Mixture-of-Experts block: grouped, capacity-based, sort-free dispatch.

GShard/MaxText-style "dropping" implementation: tokens are split into dispatch
groups of `group_size`; within a group each token's top-k experts are assigned
slots by a priority cumsum (slot 0 of every token outranks slot 1), tokens beyond
an expert's capacity drop to the residual path. Dispatch/combine are dense
einsums over a [G, T_g, E, C] tensor — fully GSPMD-shardable: groups ride the
data axes, experts ride the model axis (EP), so the dispatch einsums lower to
all-to-alls on real meshes.

Capacity C = ceil(T_g * k / E * capacity_factor), rounded up to a multiple of 4.
The one-hot dispatch matmul costs 2·T·E·C·d FLOPs (~25% overhead at Kimi-K2
geometry, ~3% at Mixtral) — flagged in the roofline's useful-FLOPs ratio and a
target of the §Perf hillclimb (gather/scatter dispatch).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def _pet32():
    return jnp.bfloat16 if _L.REDUCE_BF16 else jnp.float32

from repro.distributed.sharding import shard
from repro.models.base import ParamSpec
from repro.models.config import ModelConfig


def moe_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    m = cfg.moe
    l = cfg.n_layers if layers is None else layers
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    lead = () if l == 0 else (l,)
    la = () if l == 0 else (None,)
    specs = {
        "router": ParamSpec(lead + (d, e), la + ("embed", "experts"), "fan_in", dtype=jnp.float32),
        "wg": ParamSpec(lead + (e, d, f), la + ("experts", "embed", "expert_mlp"), "fan_in", dtype=cfg.dtype),
        "wu": ParamSpec(lead + (e, d, f), la + ("experts", "embed", "expert_mlp"), "fan_in", dtype=cfg.dtype),
        "wd": ParamSpec(lead + (e, f, d), la + ("experts", "expert_mlp", "embed"), "fan_in", dtype=cfg.dtype),
    }
    if m.n_shared:
        fs = m.d_expert * m.n_shared
        specs["shared"] = {
            "wg": ParamSpec(lead + (d, fs), la + ("embed", "mlp"), "fan_in", dtype=cfg.dtype),
            "wu": ParamSpec(lead + (d, fs), la + ("embed", "mlp"), "fan_in", dtype=cfg.dtype),
            "wd": ParamSpec(lead + (fs, d), la + ("mlp", "embed"), "fan_in", dtype=cfg.dtype),
        }
    return specs


def _capacity(tg: int, k: int, e: int, factor: float) -> int:
    c = math.ceil(tg * k / e * factor)
    return max(4, ((c + 3) // 4) * 4)


def apply(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (out [B, S, d], aux load-balancing loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    tg = min(m.group_size, t)
    while t % tg:  # largest divisor of t below group_size (t is static; cells are 2^k)
        tg -= 1
    g = t // tg
    e, k = m.n_experts, m.top_k
    c = _capacity(tg, k, e, m.capacity_factor)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]

    xg = x.reshape(g, tg, d)
    xg = shard(xg, "moe_groups", None, "embed")
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)                        # [G, Tg, E]
    gate, idx = jax.lax.top_k(probs, k)                            # [G, Tg, K]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # --- priority-ordered slot assignment (slot-major cumsum) ---
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)                   # [G, Tg, K, E]
    ohp = jnp.moveaxis(oh, 2, 1).reshape(g, k * tg, e)             # slot-major
    pos = jnp.cumsum(ohp, axis=1) - ohp                            # position in expert
    keep = (pos < c) & (ohp > 0)
    pos_tok = jnp.moveaxis(pos.reshape(g, k, tg, e), 1, 2)         # [G, Tg, K, E]
    keep_tok = jnp.moveaxis(keep.reshape(g, k, tg, e), 1, 2)

    # combine[g,t,e,c] = gate weight of token t's assignment to slot c of expert e
    pos_sel = jnp.sum(pos_tok * oh, axis=-1)                       # [G, Tg, K]
    keep_sel = jnp.any(keep_tok & (oh > 0), axis=-1)               # [G, Tg, K]
    slot_oh = jax.nn.one_hot(pos_sel, c, dtype=cfg.dtype)          # [G, Tg, K, C]
    gatek = (gate * keep_sel).astype(cfg.dtype)                    # [G, Tg, K]
    combine = jnp.einsum(
        "gtke,gtkc->gtec", oh.astype(cfg.dtype) * gatek[..., None], slot_oh
    )                                                              # [G, Tg, E, C]
    combine = shard(combine, "moe_groups", None, "experts", None)
    dispatch = (combine > 0).astype(cfg.dtype)

    # --- expert computation (EP: experts sharded over model) ---
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg, preferred_element_type=_pet32()).astype(cfg.dtype)
    xe = shard(xe, "moe_groups", "experts", None, "embed")
    hg = jnp.einsum("gecd,edf->gecf", xe, p["wg"], preferred_element_type=_pet32())
    hu = jnp.einsum("gecd,edf->gecf", xe, p["wu"], preferred_element_type=_pet32())
    hidden = (act(hg) * hu).astype(cfg.dtype)
    hidden = shard(hidden, "moe_groups", "experts", None, "expert_mlp")
    ye = jnp.einsum("gecf,efd->gecd", hidden, p["wd"], preferred_element_type=_pet32()).astype(cfg.dtype)
    out = jnp.einsum("gtec,gecd->gtd", combine, ye, preferred_element_type=_pet32()).astype(cfg.dtype)
    out = out.reshape(b, s, d)

    if m.n_shared:
        sh = p["shared"]
        hs = (act(jnp.einsum("bsd,df->bsf", x, sh["wg"], preferred_element_type=_pet32()))
              * jnp.einsum("bsd,df->bsf", x, sh["wu"], preferred_element_type=_pet32())).astype(cfg.dtype)
        out = out + jnp.einsum("bsf,fd->bsd", hs, sh["wd"], preferred_element_type=_pet32()).astype(cfg.dtype)

    # --- switch-style load-balancing aux loss ---
    frac = jnp.mean(oh[..., 0, :].astype(jnp.float32), axis=(0, 1))  # top-1 dispatch fraction
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = m.router_aux_coef * e * jnp.sum(frac * pmean)
    return out, aux
