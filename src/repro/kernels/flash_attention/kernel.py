"""Pallas TPU kernel: fused causal flash attention (forward).

This is the fix for the dominant memory term of §Roofline: in the pure-JAX
blockwise formulation the per-(q,kv)-block score/probability tensors
materialize at fusion boundaries (HBM round-trips); here they live in VMEM for
the lifetime of a grid cell.

Grid: (batch*heads, Sq/block_q, Skv/block_k) with the kv axis innermost
("arbitrary" — it carries the online-softmax state in VMEM scratch). BlockSpecs
stream q/k/v blocks HBM->VMEM; per-cell working set is
block_q*d + block_k*d (+ block_q*block_k scores) — a few hundred KB at the
default 512/1024 blocks, well under the 128 MB VMEM budget. GQA is handled by
mapping each q-head's grid row to its kv head via the index map (no expanded KV
is ever materialized).

The backward pass stays on the custom-VJP scan path (models/layers.py); a
fused bwd kernel is the natural next step and follows the same tiling.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nk: int, block_q: int, block_k: int, causal: bool, window: int,
               scale: float):
    kj = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                         # [bq, d]
    k = k_ref[0]                         # [bk, d]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                            # [bq, bk]
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_fwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = -1,
    block_q: int = 512,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """q [B, Sq, H, D]; k, v [B, Skv, KH, D], H % KH == 0 -> out [B, Sq, H, D].

    Sq % block_q == Skv % block_k == 0 (ops.py pads).
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(d)

    # head-major layouts: q [B*H, Sq, D]; kv [B*KH, Skv, D]
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kh_ = jnp.moveaxis(k, 2, 1).reshape(b * kh, skv, d)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * kh, skv, d)

    kernel = functools.partial(
        _fa_kernel, nk=nk, block_q=block_q, block_k=block_k,
        causal=causal, window=window, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            # GQA: q-head bh reads kv head bh//g — no expanded KV materializes
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh_, vh)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
