"""LM serving engines: static-batch generate + the slot-ring decode backend.

Two execution styles over the same model interface (``prefill_fn`` /
``decode_fn`` / ``init_cache_fn``):

* ``Engine`` (static batch): a batch of same-length prompts is prefilled in one
  pass (KV cache padded to prompt + max_new), then ``lax.scan`` drives
  ``max_new`` decode steps entirely on device — one compiled program per prompt
  *shape*, no host round-trips. Compiled programs are cached keyed on every
  input shape (prompt length, vision prefix, ...), so mixed prompt lengths
  across calls each get a correctly-positioned program instead of silently
  reusing the first call's positions.

* ``ContinuousEngine``: the LM decode backend of the backend-agnostic slot
  ring (``repro.serving.slotring.SlotRingEngine`` — the same seam the HDC
  similarity-search backend ``repro.serving.hdc.HDCEngine`` plugs into). A
  fixed number of decode *slots* share one jitted multi-slot step program.
  Requests are admitted into free slots by a per-prompt-shape compiled prefill
  whose KV cache is swapped into the live slot-stacked cache via
  ``slotring.slot_update`` — cache row, next token, position, done flag, and
  RNG key, all per slot — and finished rows are evicted at step granularity
  while the remaining slots keep decoding. One step program + one admit
  program serve a stream of variable-length requests with no per-request
  recompile (prefill compiles are bounded by the length buckets the scheduler
  admits from). ``repro.serving.scheduler`` provides the request queue /
  admission policy on top.

Chunked prefill (``prefill_chunk=N``): a long prompt's prefill is split into
fixed-size chunks that the scheduler interleaves with decode steps — the slot
is *reserved* while its chunks run, so one long admission no longer stalls
every decoding slot for a whole-prompt prefill (the PR 2 admission stall).
Each chunk attends over the cache prefix + itself (``flash_attention`` with
``q_offset``) and writes its K/V into the same full-capacity cache a one-shot
prefill would produce; the final chunk's last-position logits are sampled with
the request's own key, so the output tokens match the unchunked path.
Compiled chunk programs are keyed on (start, chunk_len) — bounded by
prompt-length buckets just like whole prefills.

Production notes (multi-host): the slot-stacked cache shards batch(slot) over
data axes and kv_heads/kv_seq over model per arch rules, same as the static
cache; admission swaps are slot-local ``dynamic_update_slice`` ops so they
stay on the slot's data shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.serving import slotring


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new: int = 32
    temperature: float = 0.0     # 0 -> greedy
    eos_id: int | None = None


def _sample(cfg: ServeConfig, logits: jax.Array, key: jax.Array) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / cfg.temperature, -1).astype(jnp.int32)


def _prompt_sig(batch: dict) -> tuple:
    """Static-shape signature of a prompt batch: prompt length plus the shape
    and dtype of every extra input (patch_embeds, positions, frames, ...)."""
    return tuple(sorted((k, tuple(v.shape), str(v.dtype)) for k, v in batch.items()))


def _vision_prefix(batch: dict) -> int:
    """Extra decoder positions in front of the prompt (VLM patch embeddings)."""
    return batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0


class Engine:
    """Static-batch engine: one compiled generate per prompt-shape bucket."""

    def __init__(self, model, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self._gen: dict[tuple, Any] = {}

    def _build(self, prompt_len: int, prefix: int):
        model, cfg = self.model, self.cfg
        pos0 = prompt_len + prefix
        pad_to = pos0 + cfg.max_new + 1

        def generate(params, batch, key):
            logits, cache = model.prefill_fn(params, batch, pad_to=pad_to)
            b = logits.shape[0]
            tok0 = _sample(cfg, logits, key)
            done0 = jnp.zeros((b,), bool)

            def step(carry, i):
                cache, tok, done, key = carry
                key, k1 = jax.random.split(key)
                logits, cache = model.decode_fn(params, cache, tok, pos0 + i)
                nxt = _sample(cfg, logits, k1)
                if cfg.eos_id is not None:
                    done = done | (tok == cfg.eos_id)
                    nxt = jnp.where(done, cfg.eos_id, nxt)
                return (cache, nxt, done, key), tok

            (_, _, _, _), toks = jax.lax.scan(
                step, (cache, tok0, done0, key), jnp.arange(cfg.max_new)
            )
            return jnp.moveaxis(toks, 0, 1)  # [B, max_new]

        return jax.jit(generate)

    def generate(self, params, batch: dict, key: jax.Array | None = None) -> jax.Array:
        """batch: model inputs incl. 'tokens' [B, S_prompt]. Returns [B, max_new]."""
        sig = _prompt_sig(batch)
        fn = self._gen.get(sig)
        if fn is None:
            fn = self._gen[sig] = self._build(
                batch["tokens"].shape[1], _vision_prefix(batch)
            )
        return fn(params, batch, key if key is not None else jax.random.PRNGKey(0))


@dataclasses.dataclass
class ChunkedPrefill:
    """One in-flight chunked admission: the reserved slot's prefill progress.

    ``cache`` is the request's full-capacity B=1 cache with K/V written for
    positions [0, start); ``logits`` holds the last chunk's last-position
    logits (the sampling input once ``done``)."""

    batch: dict
    key: Any
    cache: Any
    start: int
    logits: Any = None

    @property
    def prompt_len(self) -> int:
        return self.batch["tokens"].shape[1]

    @property
    def done(self) -> bool:
        return self.start >= self.prompt_len


class ContinuousEngine(slotring.SlotRingEngine):
    """Slot-ring LM decode backend: step-granular admission/eviction over one
    compiled step.

    State is a pytree whose leaves carry a leading slot axis: the model's B=1
    cache stacked ``num_slots`` high, plus per-slot next-token / position /
    done / RNG-key arrays. Every slot's cache has identical capacity
    ``max_prompt_len (+ vision prefix) + max_new + 1`` regardless of the
    admitted prompt's length, so one decode-step program and one admission
    program cover the whole request stream. Empty slots decode garbage rows
    (fully masked attention — numerically harmless) until the next admission
    overwrites them.

    ``prefill_chunk=N`` enables chunked admission for text prompts longer than
    N on model families that implement ``prefill_chunk_fn`` (dense decoders;
    MoE routing groups over the token axis and VLM prefixes change the
    position map, so those prefill whole). The scheduler drives one chunk per
    step via ``begin/advance_chunked_prefill`` and swaps the finished cache in
    with ``admit_chunked`` — token-identical to the one-shot prefill.
    """

    def __init__(self, model, cfg: ServeConfig, num_slots: int, max_prompt_len: int,
                 max_prefix: int = 0, prefill_chunk: int | None = None):
        if cfg.max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.model = model
        self.cfg = cfg
        self.max_prompt_len = max_prompt_len
        self.capacity = max_prompt_len + max_prefix + cfg.max_new + 1
        mw = model.cfg.max_window
        if 0 <= mw < max_prompt_len + max_prefix:
            raise ValueError(
                f"pure sliding-window model (window {mw} < max prompt "
                f"{max_prompt_len + max_prefix}): prefill would produce ring caches "
                "whose capacity depends on prompt length, breaking slot uniformity"
            )
        self.prefill_chunk = None
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            if model.prefill_chunk_fn is None:
                raise ValueError(
                    "this model family has no chunked prefill "
                    "(prefill_chunk_fn is None): dense decoders only"
                )
            if 0 <= mw < self.capacity:
                raise ValueError(
                    f"chunked prefill needs a full-capacity cache; window {mw} "
                    f"< capacity {self.capacity} would make it a ring"
                )
            self.prefill_chunk = int(prefill_chunk)
        # One jit wrapper: jit itself specializes per prompt shape; the set just
        # tracks the distinct signatures (= compiles) seen, for warmup/telemetry.
        self._prefill = self._build_prefill()
        self._prefill_sigs: set[tuple] = set()
        self._chunk_fn = jax.jit(self._chunk_impl, static_argnums=(3,))
        self._chunk_sigs: set[tuple] = set()
        super().__init__(num_slots)

    # -- state ---------------------------------------------------------------

    def init_state(self) -> dict:
        n = self.num_slots
        cache1 = self.model.init_cache_fn(1, self.capacity)
        return {
            "cache": jax.tree.map(lambda x: jnp.stack([x] * n), cache1),
            "tok": jnp.zeros((n,), jnp.int32),
            "pos": jnp.zeros((n,), jnp.int32),
            "done": jnp.ones((n,), bool),   # empty slots stay EOS-frozen
            "key": jnp.zeros((n, 2), jnp.uint32),
        }

    # -- admission -----------------------------------------------------------

    def _build_prefill(self):
        model, cfg, capacity = self.model, self.cfg, self.capacity

        def prefill(params, batch, key):
            logits, cache = model.prefill_fn(params, batch, pad_to=capacity)
            return cache, _sample(cfg, logits, key)

        return jax.jit(prefill)

    def _admit_impl(self, state, slot_cache, tok0, pos0, key, slot):
        return slotring.slot_update(
            state,
            {"cache": slot_cache, "tok": tok0, "pos": pos0, "done": False,
             "key": key},
            slot,
        )

    def _check_capacity(self, batch: dict) -> int:
        prompt_len = batch["tokens"].shape[1]
        prefix = _vision_prefix(batch)
        if prompt_len + prefix + self.cfg.max_new + 1 > self.capacity:
            raise ValueError(
                f"prompt_len {prompt_len} (+prefix {prefix}) exceeds engine "
                f"capacity {self.capacity} - max_new {self.cfg.max_new} - 1"
            )
        return prompt_len + prefix

    def prefill_into_slot(self, params, state, batch: dict, slot: int,
                          key: jax.Array | None = None) -> tuple[dict, int]:
        """Prefill one request (B=1 batch) and swap it into `slot`.

        Returns (new state, first generated token). Compiles once per distinct
        prompt shape; the cache swap itself is one compiled program total.
        """
        assert batch["tokens"].shape[0] == 1, "continuous admission is per-request"
        pos0 = self._check_capacity(batch)
        key = key if key is not None else jax.random.PRNGKey(0)
        self._prefill_sigs.add(_prompt_sig(batch))
        cache, tok0 = self._prefill(params, batch, key)
        state = self._admit_fn(
            state, cache, tok0[0], jnp.int32(pos0), key, jnp.int32(slot)
        )
        return state, int(tok0[0])

    # -- chunked admission ---------------------------------------------------

    def supports_chunked_prefill(self, batch: dict) -> bool:
        """True when this request should admit chunk-by-chunk: chunking is on,
        the prompt is text-only (a vision prefix changes the position map) and
        longer than one chunk (shorter prompts ARE one chunk — the whole-prefill
        program is the better-compiled path for them)."""
        return (self.prefill_chunk is not None
                and "patch_embeds" not in batch
                and batch["tokens"].shape[1] > self.prefill_chunk)

    def begin_chunked_prefill(self, params, batch: dict,
                              key: jax.Array | None = None) -> ChunkedPrefill:
        """Reserve-side start of a chunked admission: a fresh full-capacity
        B=1 cache with no chunks run yet. `params` rides along for signature
        parity with `prefill_into_slot` (chunks run in `advance_...`)."""
        del params
        assert batch["tokens"].shape[0] == 1, "continuous admission is per-request"
        self._check_capacity(batch)
        key = key if key is not None else jax.random.PRNGKey(0)
        cache = self.model.init_cache_fn(1, self.capacity)
        return ChunkedPrefill(batch=batch, key=key, cache=cache, start=0)

    def _chunk_impl(self, params, cache, tokens, start: int):
        return self.model.prefill_chunk_fn(params, cache, tokens, start)

    def advance_chunked_prefill(self, params, job: ChunkedPrefill) -> ChunkedPrefill:
        """Run ONE prefill chunk. Compiles once per (start, chunk_len) pair —
        full chunks share programs across prompt lengths; only the remainder
        chunk is per-length."""
        cs = min(self.prefill_chunk, job.prompt_len - job.start)
        tokens = job.batch["tokens"][:, job.start:job.start + cs]
        self._chunk_sigs.add((job.start, cs))
        logits, cache = self._chunk_fn(params, job.cache, tokens, job.start)
        return dataclasses.replace(
            job, cache=cache, start=job.start + cs, logits=logits
        )

    def admit_chunked(self, state, job: ChunkedPrefill, slot: int) -> tuple[dict, int]:
        """Swap a completed chunked prefill into `slot`; samples the first
        token from the final chunk's logits with the request's key — the same
        (logits, key) the one-shot prefill would sample from."""
        assert job.done, "admit_chunked before the last chunk ran"
        tok0 = _sample(self.cfg, job.logits, job.key)
        state = self._admit_fn(
            state, job.cache, tok0[0], jnp.int32(job.prompt_len), job.key,
            jnp.int32(slot)
        )
        return state, int(tok0[0])

    # -- decode --------------------------------------------------------------

    def _step_impl(self, params, state):
        cfg = self.cfg

        def decode_one(cache, tok, pos):
            return self.model.decode_fn(params, cache, tok, pos)

        # [N, 1, V] logits: each slot decodes its own position/cache row.
        logits, cache = jax.vmap(decode_one)(
            state["cache"], state["tok"][:, None], state["pos"]
        )
        keys = jax.vmap(jax.random.split)(state["key"])      # [N, 2, 2]
        key_next, k1 = keys[:, 0], keys[:, 1]
        nxt = jax.vmap(lambda l, k: _sample(cfg, l, k))(logits, k1)[:, 0]
        done = state["done"]
        if cfg.eos_id is not None:
            done = done | (state["tok"] == cfg.eos_id)
            nxt = jnp.where(done, cfg.eos_id, nxt)
        new_state = {
            "cache": cache,
            "tok": nxt,
            "pos": state["pos"] + 1,
            "done": done,
            "key": key_next,
        }
        return new_state, nxt
