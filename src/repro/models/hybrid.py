"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The stack is organized as G super-groups; each super-group applies the shared
transformer block (attention + MLP with ONE weight set reused across all G
invocations, plus per-group scanned norm gains) followed by `shared_attn_every`
Mamba-2 layers. The outer ``lax.scan`` runs over groups; the inner one over the
group's Mamba layers; shared weights enter the scan body by closure (read-only
broadcast).

Simplifications vs the released Zamba2 (documented in DESIGN.md): the shared
block consumes the residual stream directly (no concat with the original
embedding) and per-invocation LoRA adapters are replaced by the per-group norm
gains. Shapes/FLOPs of all published dimensions are preserved.

Decode: the shared block is invoked G times per token on *different*
activations, so the KV cache carries G entries; Mamba states are [G, per-group].
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def _pet32():
    return jnp.bfloat16 if _L.REDUCE_BF16 else jnp.float32

from repro.models import mamba as mamba_lib
from repro.models.base import ParamSpec
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    decode_attention,
    flash_attention,
    gated_mlp,
    rmsnorm,
)
from repro.models.transformer import attn_specs, mlp_specs


def _counts(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.shared_attn_every
    assert per > 0 and cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per  # (groups, mamba layers per group)


def hybrid_specs(cfg: ModelConfig) -> dict:
    g, per = _counts(cfg)
    d = cfg.d_model
    mamba = mamba_lib.mamba2_specs(cfg, layers=1)
    # stack to [G, per, ...]
    mamba = jax.tree.map(
        lambda s: ParamSpec((g, per) + s.shape[1:], (None,) + s.axes, s.init, s.scale, s.dtype),
        mamba, is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02, cfg.dtype),
        "shared": {
            "attn": attn_specs(cfg, layers=0),
            "mlp": mlp_specs(cfg, layers=0),
        },
        "groups": {
            "ln1": ParamSpec((g, d), (None, "embed"), "zeros", dtype=cfg.dtype),
            "ln2": ParamSpec((g, d), (None, "embed"), "zeros", dtype=cfg.dtype),
            "mamba": mamba,
        },
        "final_norm": ParamSpec((d,), ("embed",), "zeros", dtype=cfg.dtype),
        "lm_head": ParamSpec((d, cfg.vocab), ("embed", "vocab"), "fan_in", dtype=cfg.dtype),
    }


def _shared_attn_train(shared, ln1, ln2, cfg: ModelConfig, x, positions, return_kv=False):
    from repro.models.transformer import _attn_heads

    h = rmsnorm(x, ln1, cfg.norm_eps)
    q, k, v = _attn_heads(shared["attn"], cfg, h, positions, jnp.float32(cfg.rope_theta))
    o = flash_attention(q, k, v, causal=True, block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
    o = jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"], preferred_element_type=_pet32()).astype(x.dtype)
    x = x + o
    h = rmsnorm(x, ln2, cfg.norm_eps)
    m = gated_mlp(h, shared["mlp"]["wg"], shared["mlp"]["wu"], shared["mlp"]["wd"], cfg.act)
    return x + m, ((k, v) if return_kv else None)


def run_hybrid_train(params, cfg: ModelConfig, x, positions, return_kv: bool = False):
    """Returns (hidden, aux=0, (kv, mamba_states) or None)."""

    def group_body(x, xs):
        grp = xs

        def mamba_body(x, mp):
            x, state = mamba_lib.mamba2_block(mp, cfg, x)
            return x, state

        x, kv = _shared_attn_train(
            params["shared"], grp["ln1"], grp["ln2"], cfg, x, positions, return_kv
        )
        body = jax.checkpoint(mamba_body) if cfg.remat and not return_kv else mamba_body
        x, states = jax.lax.scan(body, x, grp["mamba"])
        return x, (kv, states if return_kv else None)

    x, ys = jax.lax.scan(group_body, x, params["groups"])
    return x, 0.0, (ys if return_kv else None)


def run_hybrid_decode(params, cfg: ModelConfig, x, pos, cache):
    """cache: k/v [G,B,Sc,KH,hd], slot_pos [Sc], conv [G,per,B,K-1,Cc], ssm [G,per,B,H,N,P]."""
    b = x.shape[0]
    slot = pos % cache["k"].shape[2]
    slot_pos = cache["slot_pos"].at[slot].set(pos)
    positions = jnp.broadcast_to(pos, (b, 1))
    shared = params["shared"]

    def group_body(x, xs):
        grp, kc, vc, conv, ssm = xs
        from repro.models.transformer import _attn_heads

        h = rmsnorm(x, grp["ln1"], cfg.norm_eps)
        q, k, v = _attn_heads(shared["attn"], cfg, h, positions, jnp.float32(cfg.rope_theta))
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = decode_attention(q, kc, vc, slot_pos, pos)
        o = jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"], preferred_element_type=_pet32()).astype(x.dtype)
        x = x + o
        h = rmsnorm(x, grp["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h, shared["mlp"]["wg"], shared["mlp"]["wu"], shared["mlp"]["wd"], cfg.act)

        def mamba_body(x, xs2):
            mp, cst, sst = xs2
            x, cst, sst = mamba_lib.mamba2_decode(mp, cfg, x, cst, sst)
            return x, (cst, sst)

        x, (conv, ssm) = jax.lax.scan(mamba_body, x, (grp["mamba"], conv, ssm))
        return x, (kc, vc, conv, ssm)

    x, (k_new, v_new, conv_new, ssm_new) = jax.lax.scan(
        group_body, x, (params["groups"], cache["k"], cache["v"], cache["conv"], cache["ssm"])
    )
    new_cache = dict(cache, k=k_new, v=v_new, conv=conv_new, ssm=ssm_new, slot_pos=slot_pos)
    return x, new_cache


def hybrid_cache_specs(cfg: ModelConfig, batch: int, seq: int):
    g, per = _counts(cfg)
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    conv_dim = din + 2 * s.n_groups * s.d_state
    kv = (g, batch, seq, cfg.n_kv_heads, cfg.hd)
    shapes = {
        "k": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "slot_pos": jax.ShapeDtypeStruct((seq,), jnp.int32),
        "conv": jax.ShapeDtypeStruct((g, per, batch, s.d_conv - 1, conv_dim), cfg.dtype),
        "ssm": jax.ShapeDtypeStruct((g, per, batch, nh, s.d_state, s.head_dim), jnp.float32),
    }
    kv_axes = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    axes = {
        "k": kv_axes,
        "v": kv_axes,
        "slot_pos": (None,),
        "conv": (None, None, "batch", None, "inner"),
        "ssm": (None, None, "batch", None, "state", None),
    }
    return shapes, axes


def hybrid_init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    shapes, _ = hybrid_cache_specs(cfg, batch, seq)
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
    cache["slot_pos"] = jnp.full(shapes["slot_pos"].shape, -1, jnp.int32)
    return cache
