from repro.kernels.sparse.ops import (  # noqa: F401
    sparse_search,
    sparse_topk_banked,
)
