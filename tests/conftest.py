# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real single CPU
# device. Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/test_distributed.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # for `from _propcheck import ...`


def make_test_mesh(shape, axes):
    """The one way tests build a mesh — version-portable via repro.compat.

    Subprocess snippets (tests/test_distributed.py) can't import conftest;
    they use `from repro.compat import make_mesh` directly, which this wraps.
    """
    from repro.compat import make_mesh

    return make_mesh(shape, axes)
