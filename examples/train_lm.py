"""End-to-end driver: train a ~100M-param LM for a few hundred steps, comparing
dense gradient sync against the paper's OTA sign-majority collective.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_lm.py --steps 300 --opt sign_majority
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax

from repro import compat, configs
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.models.base import count_params
from repro.train.loop import Trainer, TrainerConfig, build_train_fns
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "sign_majority"])
    ap.add_argument("--ota-ber", type=float, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param llama-family config (smollm geometry, trimmed depth)
    cfg = dataclasses.replace(
        configs.get_config("smollm-360m"),
        n_layers=8, vocab=16384, remat=False, loss_chunk=128,
        dtype=jax.numpy.float32,
    )
    model = get_model(cfg)
    print(f"params: {count_params(model.specs)/1e6:.1f}M  opt={args.opt}")

    mesh = make_host_mesh()
    opt = OptConfig(kind=args.opt, lr=1e-3 if args.opt == "adamw" else 3e-4,
                    warmup=20, total_steps=args.steps)
    fns = build_train_fns(model, mesh, opt, ota_ber=args.ota_ber)
    pipe = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch))
    trainer = Trainer(
        fns, pipe,
        TrainerConfig(steps=args.steps, ckpt_every=100,
                      ckpt_dir=f"/tmp/repro_example_{args.opt}", log_every=25),
        mesh,
    )
    t0 = time.time()
    with compat.set_mesh(mesh):
        _, _, losses = trainer.run(jax.random.PRNGKey(0))
    dt = time.time() - t0
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} in {dt:.0f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
