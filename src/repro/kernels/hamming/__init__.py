from repro.kernels.hamming.ops import hamming_search  # noqa: F401
