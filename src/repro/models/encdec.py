"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings [B, T_enc, d]. The encoder is a non-causal
transformer over frames with fixed sinusoidal positions; the decoder adds causal
self-attention and cross-attention to the encoder output. Pre-RMSNorm blocks are
used in place of Whisper's LayerNorm+bias (shapes and FLOPs preserved; noted in
DESIGN.md). Sinusoidal decoder positions replace the learned 448-entry table so
the structural decode_32k cell is well-defined at any length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def _pet32():
    return jnp.bfloat16 if _L.REDUCE_BF16 else jnp.float32

from repro.models.base import ParamSpec
from repro.models.config import ModelConfig
from repro.models.layers import (
    decode_attention,
    flash_attention,
    gated_mlp,
    rmsnorm,
    sinusoid_positions,
)
from repro.models.transformer import attn_specs, mlp_specs


def encdec_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    le, ld = cfg.n_enc_layers, cfg.n_layers
    def blockset(l):
        return {
            "attn": attn_specs(cfg, layers=l),
            "mlp": mlp_specs(cfg, layers=l),
            "ln1": ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype),
            "ln2": ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype),
        }
    dec = blockset(ld)
    dec["xattn"] = attn_specs(cfg, layers=ld)
    dec["lnx"] = ParamSpec((ld, d), (None, "embed"), "zeros", dtype=cfg.dtype)
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02, cfg.dtype),
        "enc_blocks": blockset(le),
        "dec_blocks": dec,
        "enc_norm": ParamSpec((d,), ("embed",), "zeros", dtype=cfg.dtype),
        "final_norm": ParamSpec((d,), ("embed",), "zeros", dtype=cfg.dtype),
    }


def _proj_qkv(blk, cfg, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, blk["wq"], preferred_element_type=_pet32()).astype(xq.dtype)
    k = jnp.einsum("bsd,dhk->bshk", xkv, blk["wk"], preferred_element_type=_pet32()).astype(xq.dtype)
    v = jnp.einsum("bsd,dhk->bshk", xkv, blk["wv"], preferred_element_type=_pet32()).astype(xq.dtype)
    return q, k, v


def _out(blk, o, dtype):
    return jnp.einsum("bshk,hkd->bsd", o, blk["wo"], preferred_element_type=_pet32()).astype(dtype)


def run_encoder(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames [B, T, d] (stub frontend output) -> encoder states [B, T, d]."""
    t = frames.shape[1]
    x = (frames + sinusoid_positions(t, cfg.d_model)[None]).astype(cfg.dtype)

    def body(x, blk):
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(blk["attn"], cfg, h, h)
        o = flash_attention(q, k, v, causal=False, block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
        x = x + _out(blk["attn"], o, x.dtype)
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        return x + gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def run_decoder_train(params, cfg: ModelConfig, tokens: jax.Array, enc: jax.Array, return_kv=False):
    """tokens [B, S]; enc [B, T, d] -> (hidden [B, S, d], kv or None)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype) + sinusoid_positions(s, cfg.d_model)[None].astype(cfg.dtype)

    def body(x, blk):
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(blk["attn"], cfg, h, h)
        o = flash_attention(q, k, v, causal=True, block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
        x = x + _out(blk["attn"], o, x.dtype)
        h = rmsnorm(x, blk["lnx"], cfg.norm_eps)
        qx, kx, vx = _proj_qkv(blk["xattn"], cfg, h, enc)
        ox = flash_attention(qx, kx, vx, causal=False, block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
        x = x + _out(blk["xattn"], ox, x.dtype)
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act)
        return x, ((k, v, kx, vx) if return_kv else None)

    body_fn = jax.checkpoint(body) if cfg.remat and not return_kv else body
    x, kv = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return x, kv


def run_decoder_step(params, cfg: ModelConfig, token: jax.Array, pos, cache):
    """token [B]; cache k/v [L,B,Sc,KH,hd] + cross ck/cv [L,B,T,KH,hd]."""
    b = token.shape[0]
    slot = pos % cache["k"].shape[2]
    slot_pos = cache["slot_pos"].at[slot].set(pos)
    # sinusoid positional embedding at scalar position `pos`
    half = cfg.d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = pos.astype(jnp.float32) * freqs
    pemb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
    x = params["embed"][token][:, None].astype(cfg.dtype) + pemb.astype(cfg.dtype)

    def body(x, xs):
        blk, kc, vc = xs
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(blk["attn"], cfg, h, h)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = decode_attention(q, kc, vc, slot_pos, pos)
        x = x + _out(blk["attn"], o, x.dtype)
        h = rmsnorm(x, blk["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"], preferred_element_type=_pet32()).astype(x.dtype)
        t = blk["ck"].shape[1]
        ox = decode_attention(qx, blk["ck"], blk["cv"], jnp.arange(t), jnp.int32(t), window=-1)
        x = x + _out(blk["xattn"], ox, x.dtype)
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act)
        return x, (kc, vc)

    xs = (dict(params["dec_blocks"], ck=cache["ck"], cv=cache["cv"]), cache["k"], cache["v"])
    x, (k_new, v_new) = jax.lax.scan(body, x, xs)
    return x, dict(cache, k=k_new, v=v_new, slot_pos=slot_pos)


def encdec_cache_specs(cfg: ModelConfig, batch: int, seq: int):
    l = cfg.n_layers
    kv = (l, batch, seq, cfg.n_kv_heads, cfg.hd)
    xkv = (l, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
    kv_axes = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    shapes = {
        "k": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "ck": jax.ShapeDtypeStruct(xkv, cfg.dtype),
        "cv": jax.ShapeDtypeStruct(xkv, cfg.dtype),
        "slot_pos": jax.ShapeDtypeStruct((seq,), jnp.int32),
    }
    axes = {"k": kv_axes, "v": kv_axes, "ck": kv_axes, "cv": kv_axes, "slot_pos": (None,)}
    return shapes, axes
