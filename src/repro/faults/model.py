"""Hard-fault injection for the OTA serve path: the chaos layer.

The PHY subsystem (`repro.phy`) models *soft* degradation — drifting phases,
fading amplitudes, a rising BER the closed loop can re-characterize away.
This module models the failures no re-fit recovers: PCM crossbar cells stuck
at a conductance rail, whole IMC cores (or their RX front-ends) going dark,
and encoder votes erased from the over-the-air superposition. At WHYPE scale
(1024 RX cores) these are a statistical certainty, and a serve path that
ignores them silently misclassifies every query whose class lives on a dead
core.

Everything rides in ONE `FaultState` pytree threaded through both serve
steps (`core.scaleout.make_ota_serve` / `make_mt_ota_serve` with a
``faults=`` model), split into three fault surfaces:

* **wire faults** — ``dead_tx`` (permanent) and ``vote_drop`` (per-step,
  refreshed by the fault process) erase encoder slots from the bundle. On the
  vote wire an erased slot votes exact 0 — the same abstention mechanism as
  the unused mesh slots — and the tally threshold ``tally > 0`` is
  automatically the majority of the LIVE voters; the guard-bit packed
  collectives re-bias by the traced live counts
  (`collectives.packed_vote_allreduce(total_active=...)`) so the packed
  tally stays bit-identical to the int8 psum of the erased votes. On the
  combo (symbol) wire an erased encoder is modeled as a *stuck carrier*:
  it keeps radiating its bit-0 phase, so the received field is exactly the
  full constellation row with that bit forced 0 — `live_combo_mask` /
  `recenter_state` refit the decision centroids over the occurring
  sub-constellation (the mask extension of `ota.majority_centroids`).
* **node faults** — ``dead_rx`` marks IMC cores that answer no similarity
  query: their received copy is zeroed in-graph. Tolerance is the
  ``serve_rows`` failover indirection (`plan_failover`): each *bank* of
  classes is served by the query copy of a healthy same-shard core — the
  query-side dual of the `hamming_topk_banked` ``bank_rows`` prototype
  indirection, and like it a traced gather, so remapping never recompiles.
  Banks with no healthy server left are excluded from the top-1 via
  ``rx_mask`` (the same pre-reduction masking as the PHY quarantine).
* **memory faults** — ``stuck0`` / ``stuck1`` are per-core packed column
  masks forcing stored prototype bits to 0/1 (applied in-graph to the
  stored — post-permutation — rows, i.e. the physical crossbar columns);
  word-dropout is a whole word stuck at 0 (`sample_word_dropout`).

Key invariant (pinned in tests/test_faults.py): with the all-healthy
`healthy_state` every application is a value identity — zero masks, identity
gather — so the fault-aware serve is **bit-identical** to the fault-free
build across every representation x collective x channel combination.

Fault models evolve the state between steps through the same registry
pattern as `phy.PROCESSES` (`FAULTS` / `register_fault_model` /
`get_fault_model`) and the same RNG discipline (`phy.row_keys`:
``fold_in(fold_in(key, t), rx_base + row)``, no data-position fold), with
their own key — the serve RNG stream is untouched. TX-side leaves evolve
from the ``t`` fold alone so every model shard derives the identical
replicated update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hypervector as hv, ota
from repro.phy.channel import ChannelState
from repro.phy.process import row_keys

_FULL_WORD = jnp.uint32(0xFFFFFFFF)

# per-row RNG sub-streams (suffix folds, disjoint from phy.process's 0..2)
_WIRE = 3
_WEAR = 4


@dataclasses.dataclass(frozen=True)
class FaultState:
    """One pytree carrying every injected hard fault, [N] RX leading.

    ``m_slots = model_size * e_per`` covers every encoder slot the serve body
    can address (``gids``); slots past ``m_tx`` never vote, so their fault
    bits are inert. ``serve_rows`` holds GLOBAL core ids constrained to the
    owning shard (bank i is served by the query copy of core
    ``serve_rows[i]``; identity = no remap); the serve body converts to
    shard-local indices, so the leaf shards over ``model`` like the rest of
    the RX-leading leaves (`fstate_spec`).
    """

    dead_tx: jax.Array    # [m_slots] bool — permanently dark encoder slots
    vote_drop: jax.Array  # [m_slots] bool — THIS step's transient erasures
    dead_rx: jax.Array    # [N] bool — dark IMC cores (answer no query)
    stuck0: jax.Array     # [N, W] u32 — prototype bits stuck at 0
    stuck1: jax.Array     # [N, W] u32 — prototype bits stuck at 1
    serve_rows: jax.Array  # [N] i32 — failover: bank i served by this core
    rx_mask: jax.Array    # [N] bool — banks with no healthy server
    t: jax.Array          # [] i32 — fault-process time

    @property
    def n_rx(self) -> int:
        return self.dead_rx.shape[0]

    @property
    def m_slots(self) -> int:
        return self.dead_tx.shape[0]


jax.tree_util.register_pytree_node(
    FaultState,
    lambda f: ((f.dead_tx, f.vote_drop, f.dead_rx, f.stuck0, f.stuck1,
                f.serve_rows, f.rx_mask, f.t), None),
    lambda _, leaves: FaultState(*leaves),
)


def fstate_spec(rx_axis: str | None = "model") -> FaultState:
    """PartitionSpec tree for a FaultState (RX-leading over `rx_axis`; the
    TX-side erasure masks and ``t`` replicate — every column needs the global
    view to derive the live-voter total without an extra collective)."""
    from jax.sharding import PartitionSpec as P

    rx = P(rx_axis)
    return FaultState(dead_tx=P(), vote_drop=P(), dead_rx=rx,
                      stuck0=P(rx_axis, None), stuck1=P(rx_axis, None),
                      serve_rows=rx, rx_mask=rx, t=P())


def fstate_shape_structs(n_rx: int, m_slots: int, words: int) -> FaultState:
    """ShapeDtypeStruct tree matching `healthy_state` — for AOT lowering
    (the dry-run ``serve_faulty`` cells) without materializing the arrays."""
    s = jax.ShapeDtypeStruct
    return FaultState(
        dead_tx=s((m_slots,), bool),
        vote_drop=s((m_slots,), bool),
        dead_rx=s((n_rx,), bool),
        stuck0=s((n_rx, words), jnp.uint32),
        stuck1=s((n_rx, words), jnp.uint32),
        serve_rows=s((n_rx,), jnp.int32),
        rx_mask=s((n_rx,), bool),
        t=s((), jnp.int32),
    )


def healthy_state(n_rx: int, m_slots: int, words: int) -> FaultState:
    """The all-healthy FaultState: every application is a value identity, so
    serving through it is bit-identical to the fault-free serve build."""
    return FaultState(
        dead_tx=jnp.zeros((m_slots,), bool),
        vote_drop=jnp.zeros((m_slots,), bool),
        dead_rx=jnp.zeros((n_rx,), bool),
        stuck0=jnp.zeros((n_rx, words), jnp.uint32),
        stuck1=jnp.zeros((n_rx, words), jnp.uint32),
        serve_rows=jnp.arange(n_rx, dtype=jnp.int32),
        rx_mask=jnp.zeros((n_rx,), bool),
        t=jnp.zeros((), jnp.int32),
    )


def healthy_for(cfg, model_size: int) -> FaultState:
    """`healthy_state` sized for a `ScaleOutConfig` on a given model-axis
    width (m_slots = model_size * e_per, matching the serve body's gids)."""
    e_per = -(-cfg.m_tx // model_size)
    return healthy_state(cfg.n_rx_cores, model_size * e_per, cfg.words)


def inject(fstate: FaultState, **leaves) -> FaultState:
    """Replace fault leaves host-side, coercing to the pytree dtypes.

    ``inject(f, dead_rx=[0, 3], ...)`` accepts index lists for the bool
    masks (dead_tx / vote_drop / dead_rx / rx_mask) or full arrays for any
    leaf; shapes must match the state (the serve step is compiled for them).
    """
    coerced = {}
    for name, val in leaves.items():
        ref = getattr(fstate, name)
        if ref.dtype == jnp.bool_ and not isinstance(val, jax.Array):
            arr = np.asarray(val)
            if arr.dtype != np.bool_ or arr.shape != ref.shape:
                mask = np.zeros(ref.shape, bool)
                mask[arr.astype(np.int64)] = True
                arr = mask
            val = arr
        val = jnp.asarray(val, ref.dtype)
        assert val.shape == ref.shape, (name, val.shape, ref.shape)
        coerced[name] = val
    return dataclasses.replace(fstate, **coerced)


# ---------------------------------------------------------------------------
# memory-fault samplers
# ---------------------------------------------------------------------------

def sample_stuck_cells(
    key: jax.Array, n_rx: int, words: int, density: float
) -> tuple[jax.Array, jax.Array]:
    """(stuck0, stuck1) [N, W] u32 masks at total cell density `density`,
    split evenly between the two rails and kept disjoint (a cell has one
    conductance). The Karunaratne et al. stuck-at abstraction of PCM
    device failures."""
    k0, k1 = jax.random.split(key)
    s0 = hv.bernoulli_words(k0, density / 2.0, (n_rx, words))
    s1 = hv.bernoulli_words(k1, density / 2.0, (n_rx, words)) & ~s0
    return s0, s1


def sample_word_dropout(
    key: jax.Array, n_rx: int, words: int, p_word: float
) -> jax.Array:
    """Whole-word dropout as a stuck-at-0 mask: each of the N*W stored words
    is lost (all 32 bits forced 0 — a dead word line) w.p. `p_word`.
    OR the result into ``stuck0``."""
    drop = jax.random.bernoulli(key, p_word, (n_rx, words))
    return jnp.where(drop, _FULL_WORD, jnp.uint32(0))


# ---------------------------------------------------------------------------
# failover planning (host-side; the FaultController's remap action)
# ---------------------------------------------------------------------------

def plan_failover(fstate: FaultState, cores_per_shard: int) -> FaultState:
    """Remap every dead core's class bank onto healthy same-shard cores.

    Dead banks are dealt round-robin over the shard's healthy cores (each
    healthy core already serves its own bank; failover adds the dead ones on
    top — the kernel's G axis covers both). Failover never crosses a shard
    boundary: the query copies live per-shard, and a cross-shard remap would
    need a query exchange the wire path doesn't have. A shard with no
    healthy core left gets its banks ``rx_mask``-ed out of the top-1
    instead. Pure host-side planning — the result feeds the SAME compiled
    serve (``serve_rows``/``rx_mask`` are traced inputs)."""
    dead = np.asarray(fstate.dead_rx)
    n = dead.shape[0]
    assert n % cores_per_shard == 0, (n, cores_per_shard)
    rows = np.arange(n, dtype=np.int32)
    mask = np.zeros(n, bool)
    for lo in range(0, n, cores_per_shard):
        sl = slice(lo, lo + cores_per_shard)
        healthy = np.flatnonzero(~dead[sl])
        if healthy.size == 0:
            mask[sl] = True
            continue
        for j, i in enumerate(np.flatnonzero(dead[sl])):
            rows[lo + i] = lo + healthy[j % healthy.size]
    return dataclasses.replace(
        fstate,
        serve_rows=jnp.asarray(rows),
        rx_mask=jnp.asarray(mask),
    )


# ---------------------------------------------------------------------------
# combo-wire (symbol tier) erasure support
# ---------------------------------------------------------------------------

def live_combo_mask(dead_slots, m_tx: int) -> jax.Array:
    """[2^M] bool — the combos that can occur on the wire when the erased
    encoders are stuck radiating their bit-0 phase (combo bit forced 0)."""
    combos = ota.bit_combos(m_tx).astype(bool)          # [B, M]
    dead = jnp.asarray(dead_slots, bool)[:m_tx]
    return ~jnp.any(combos & dead[None, :], axis=-1)


def live_majority_labels(dead_slots, m_tx: int) -> jax.Array:
    """maj(b) over the LIVE encoder bits only, [2^M] uint8 — what the
    erasure-aware receiver should decode (even live counts tie to 0, the
    repo-wide convention)."""
    combos = ota.bit_combos(m_tx).astype(jnp.int32)     # [B, M]
    live = ~jnp.asarray(dead_slots, bool)[:m_tx]
    counts = jnp.sum(combos * live.astype(jnp.int32)[None, :], axis=-1)
    n_live = jnp.sum(live.astype(jnp.int32))
    return (2 * counts > n_live).astype(jnp.uint8)


def recenter_state(state: ChannelState, dead_slots) -> ChannelState:
    """Erasure-aware re-fit of the symbol-tier decision regions.

    With encoders erased, only the `live_combo_mask` sub-constellation
    occurs; the stale all-M centroids straddle the wrong partition. This
    refits ``c0/c1`` via the masked `ota.majority_centroids` over the
    occurring combos labelled by the LIVE majority — the erasure analogue of
    `phy.recharacterize`."""
    maj = live_majority_labels(dead_slots, state.m_tx)
    mask = live_combo_mask(dead_slots, state.m_tx)
    c0, c1 = ota.majority_centroids(state.symbols, maj, mask=mask)
    return dataclasses.replace(
        state,
        c0=c0.astype(jnp.complex64),
        c1=c1.astype(jnp.complex64),
    )


# ---------------------------------------------------------------------------
# fault models (the evolution laws) + registry, mirroring phy.PROCESSES
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One stochastic evolution law for the injected faults between steps.

    ``step`` advances the FaultState one serve step with the per-row RNG
    discipline of `phy.row_keys` (RX-side leaves) and plain ``t`` folds
    (TX-side leaves, so the replicated update is identical on every model
    shard). The serve integration calls it once per step with its OWN key —
    fault evolution never consumes the serve stream.
    """

    name = "?"

    def init(self, n_rx: int, m_slots: int, words: int) -> FaultState:
        return healthy_state(n_rx, m_slots, words)

    def step(self, key: jax.Array, f: FaultState, *, rx_base=0) -> FaultState:
        return dataclasses.replace(f, t=f.t + 1)


@dataclasses.dataclass(frozen=True)
class StaticFaults(FaultModel):
    """Frozen faults: `step` only advances ``t``. The bit-identity anchor —
    injected faults persist unchanged, and through `healthy_state` the serve
    is bit-identical to the fault-free build (same discipline as
    `phy.StaticProcess`)."""

    name = "static"


@dataclasses.dataclass(frozen=True)
class TransientVoteFaults(StaticFaults):
    """Per-step wire erasures: each encoder slot's vote is dropped from this
    step's superposition w.p. ``p_drop`` (redrawn every step — a glinting
    interconnect, not a dead node). Node/memory leaves pass through."""

    name = "transient_votes"
    p_drop: float = 0.05

    def step(self, key, f, *, rx_base=0):
        kt = jax.random.fold_in(jax.random.fold_in(key, f.t), _WIRE)
        drop = jax.random.bernoulli(kt, self.p_drop, f.vote_drop.shape)
        return dataclasses.replace(f, vote_drop=drop, t=f.t + 1)


@dataclasses.dataclass(frozen=True)
class WearoutFaults(FaultModel):
    """Permanent accumulation: each live core dies w.p. ``p_die`` per step
    and each stored cell sticks w.p. ``stuck_rate`` per step (split evenly
    between the rails, monotone — faults only accrue). The controller's
    remap action, not this model, updates ``serve_rows``/``rx_mask``:
    physics breaks hardware, the serving layer routes around it."""

    name = "wearout"
    p_die: float = 0.001
    stuck_rate: float = 1e-4

    def step(self, key, f, *, rx_base=0):
        n = f.dead_rx.shape[0]
        words = f.stuck0.shape[-1]
        kr = row_keys(key, f.t, rx_base, n)

        def one(k):
            kd, k0, k1 = jax.random.split(jax.random.fold_in(k, _WEAR), 3)
            die = jax.random.bernoulli(kd, self.p_die)
            s0 = hv.bernoulli_words(k0, self.stuck_rate / 2.0, (words,))
            s1 = hv.bernoulli_words(k1, self.stuck_rate / 2.0, (words,))
            return die, s0, s1

        die, s0, s1 = jax.vmap(one)(kr)
        stuck0 = f.stuck0 | s0
        return dataclasses.replace(
            f,
            dead_rx=f.dead_rx | die,
            stuck0=stuck0,
            stuck1=(f.stuck1 | s1) & ~stuck0,
            t=f.t + 1,
        )


FAULTS: dict[str, type] = {}


def register_fault_model(cls: type, *, override: bool = False) -> type:
    """Register a `FaultModel` subclass under ``cls.name`` for
    `get_fault_model` — the same open-registry contract as
    `phy.register_process`; usable as a class decorator."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name or name == "?":
        raise ValueError(f"fault model must define a non-empty .name, got {name!r}")
    if not callable(getattr(cls, "step", None)):
        raise TypeError(f"fault model {name!r} does not implement step()")
    if name in FAULTS and not override:
        raise ValueError(
            f"fault model {name!r} already registered; pass override=True "
            "to replace it"
        )
    FAULTS[name] = cls
    return cls


for _f in (StaticFaults, TransientVoteFaults, WearoutFaults):
    register_fault_model(_f)
del _f


def get_fault_model(name: str, **kwargs) -> FaultModel:
    """Instantiate a registered fault model by name (kwargs -> constructor)."""
    try:
        cls = FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault model {name!r}; available: {sorted(FAULTS)}"
        ) from None
    return cls(**kwargs)
