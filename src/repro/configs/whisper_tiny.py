"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder with stubbed conv frontend.

4 encoder + 4 decoder layers, d_model=384 6H (kv=6, head_dim 64) d_ff=1536
vocab=51865; 1500 encoder frames (stub mel/conv frontend -> precomputed frame
embeddings). Decode cells are structural: the real model caps targets at 448;
sinusoidal decoder positions make any cache length well-defined (DESIGN.md).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    kind="encdec",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    enc_seq=1500,
    tie_embeddings=True,  # whisper reuses the token embedding as the output head
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab=512, enc_seq=64, loss_chunk=32, remat=False,
    )
