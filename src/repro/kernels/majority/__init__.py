from repro.kernels.majority.ops import majority_bundle  # noqa: F401
