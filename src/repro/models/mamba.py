"""Mamba-1 (selective scan) and Mamba-2 (SSD) blocks.

TPU adaptation: the CUDA reference fuses the recurrence into one kernel holding
state in registers; on TPU we use the *chunked* formulations instead — sequential
`lax.scan` over chunks carrying the SSM state, with intra-chunk work expressed as
(a) an associative scan (mamba-1, diagonal per-channel state) or (b) MXU matmuls
against a lower-triangular decay matrix (mamba-2 / SSD). The d_inner axis is
TP-sharded (logical axis "inner" -> model): the recurrence is elementwise across
channels, so the scan needs no collectives; only in/out projections contract d_model.

Both blocks expose train (full-sequence), and single-token decode against a
(conv_state, ssm_state) cache. Oracles for the tests: `*_scan_ref` naive
sequential recurrences.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def _pet32():
    return jnp.bfloat16 if _L.REDUCE_BF16 else jnp.float32

from repro.distributed.sharding import shard
from repro.models.base import ParamSpec
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm


def _softplus(x):
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x [B, S, C], w [C, K], b [C]: depthwise causal conv (tap K-1 = current)."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for j in range(k):
        out = out + xp[:, j : j + s, :].astype(jnp.float32) * w[:, j].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array, b: jax.Array):
    """Decode: state [B, K-1, C] (oldest first), x_t [B, C] -> (new_state, out [B, C])."""
    k = w.shape[-1]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)     # [B, K, C]
    out = jnp.sum(window.astype(jnp.float32) * w.T[None].astype(jnp.float32), axis=1) + b.astype(jnp.float32)
    return window[:, 1:, :], out.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Mamba-1: diagonal selective scan
# ---------------------------------------------------------------------------

def mamba1_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    s = cfg.ssm
    l = cfg.n_layers if layers is None else layers
    d = cfg.d_model
    din = s.expand * d
    r = s.dt_rank or d // 16
    n = s.d_state
    lead, la = ((l,), (None,)) if l else ((), ())
    return {
        "norm": ParamSpec(lead + (d,), la + ("embed",), "zeros", dtype=cfg.dtype),
        "in_proj": ParamSpec(lead + (d, 2 * din), la + ("embed", "inner"), "fan_in", dtype=cfg.dtype),
        "conv_w": ParamSpec(lead + (din, s.d_conv), la + ("inner", None), "fan_in", dtype=cfg.dtype),
        "conv_b": ParamSpec(lead + (din,), la + ("inner",), "zeros", dtype=cfg.dtype),
        "x_proj": ParamSpec(lead + (din, r + 2 * n), la + ("inner", None), "fan_in", dtype=cfg.dtype),
        "dt_proj": ParamSpec(lead + (r, din), la + (None, "inner"), "fan_in", dtype=cfg.dtype),
        "dt_bias": ParamSpec(lead + (din,), la + ("inner",), "zeros", dtype=jnp.float32),
        "A_log": ParamSpec(lead + (din, n), la + ("inner", None), "zeros", dtype=jnp.float32),
        "D": ParamSpec(lead + (din,), la + ("inner",), "ones", dtype=jnp.float32),
        "out_proj": ParamSpec(lead + (din, d), la + ("inner", "embed"), "fan_in", dtype=cfg.dtype),
    }


def selective_scan(u, dt, A, B, C, D, h0, chunk: int):
    """Chunked diagonal selective scan.

    u, dt [B, S, D_in]; A [D_in, N]; B, C [B, S, N]; D [D_in]; h0 [B, D_in, N] f32.
    Returns (y [B, S, D_in], h_final). h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t;
    y_t = C_t · h_t + D u_t.
    """
    b, s, din = u.shape
    n = A.shape[-1]
    q = min(chunk, s)
    if s % q:  # pad with dt=0 steps: decay exp(0)=1, zero input -> state unchanged
        pad = q - s % q
        u, dt, B, C = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)) for a in (u, dt, B, C))
        y, h = selective_scan(u, dt, A, B, C, D, h0, chunk)
        return y[:, :s], h
    nc = s // q
    dA = (dt.astype(jnp.float32)[..., None] * A[None, None]).reshape(b, nc, q, din, n)
    dBu = (
        dt.astype(jnp.float32) * u.astype(jnp.float32)
    )[..., None] * B.astype(jnp.float32)[..., None, :]
    dBu = dBu.reshape(b, nc, q, din, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, q, n)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    def chunk_step(h, xs):
        dA_c, dBu_c, C_c = xs                    # [B, q, din, n], ..., [B, q, n]
        a = jnp.exp(dA_c)
        acum, bcum = jax.lax.associative_scan(combine, (a, dBu_c), axis=1)
        h_t = acum * h[:, None] + bcum           # [B, q, din, n]
        y = jnp.einsum("bqdn,bqn->bqd", h_t, C_c)
        return h_t[:, -1], y

    h, ys = jax.lax.scan(
        chunk_step,
        h0,
        (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0), jnp.moveaxis(Cc, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, din)
    y = y + u.astype(jnp.float32) * D[None, None]
    return y.astype(u.dtype), h


def selective_scan_ref(u, dt, A, B, C, D, h0):
    """Naive sequential oracle."""
    b, s, din = u.shape

    def step(h, t):
        dA = jnp.exp(dt[:, t].astype(jnp.float32)[..., None] * A[None])
        h = dA * h + (dt[:, t] * u[:, t]).astype(jnp.float32)[..., None] * B[:, t].astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1) + u.astype(jnp.float32) * D[None, None]
    return y.astype(u.dtype), h


def mamba1_block(p, cfg: ModelConfig, x, state=None):
    """Full-sequence mamba-1 block. state=None -> zero initial state.

    Returns (out [B,S,d], (conv_state, ssm_state)) — final states for chaining.
    """
    s_cfg = cfg.ssm
    b, s, d = x.shape
    din = s_cfg.expand * d
    r = s_cfg.dt_rank or d // 16
    n = s_cfg.d_state
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", "seq", "inner")
    xc = causal_conv1d(xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dbc = jnp.einsum("bse,ef->bsf", xc, p["x_proj"], preferred_element_type=_pet32())
    dt_raw, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = _softplus(
        jnp.einsum("bsr,re->bse", dt_raw, p["dt_proj"].astype(jnp.float32)) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, din, n), jnp.float32) if state is None else state
    y, h_fin = selective_scan(xc, dt, A, Bm, Cm, p["D"], h0, s_cfg.chunk)
    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    conv_state = jax.lax.dynamic_slice_in_dim(
        jnp.pad(xin, ((0, 0), (s_cfg.d_conv - 1, 0), (0, 0))), s, s_cfg.d_conv - 1, axis=1
    )
    return x + out, (conv_state, h_fin)


def mamba1_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """x [B, 1, d]; conv_state [B, K-1, din]; ssm_state [B, din, N] f32."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    r = s_cfg.dt_rank or d // 16
    n = s_cfg.d_state
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    xin, z = jnp.split(xz[:, 0], 2, axis=-1)
    conv_state, xc = conv_step(conv_state, xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dbc = jnp.einsum("be,ef->bf", xc, p["x_proj"], preferred_element_type=_pet32())
    dt_raw, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = _softplus(jnp.einsum("br,re->be", dt_raw, p["dt_proj"].astype(jnp.float32)) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A[None])
    ssm_state = dA * ssm_state + (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", ssm_state, Cm) + xc.astype(jnp.float32) * p["D"][None]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    return x + out[:, None], conv_state, ssm_state


# ---------------------------------------------------------------------------
# Mamba-2: SSD (scalar decay per head, matmul formulation)
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    s = cfg.ssm
    l = cfg.n_layers if layers is None else layers
    d = cfg.d_model
    din = s.expand * d
    nh = din // s.head_dim
    gn = s.n_groups * s.d_state
    conv_dim = din + 2 * gn
    lead, la = ((l,), (None,)) if l else ((), ())
    return {
        "norm": ParamSpec(lead + (d,), la + ("embed",), "zeros", dtype=cfg.dtype),
        "in_proj": ParamSpec(lead + (d, 2 * din + 2 * gn + nh), la + ("embed", "inner"), "fan_in", dtype=cfg.dtype),
        "conv_w": ParamSpec(lead + (conv_dim, s.d_conv), la + ("inner", None), "fan_in", dtype=cfg.dtype),
        "conv_b": ParamSpec(lead + (conv_dim,), la + ("inner",), "zeros", dtype=cfg.dtype),
        "A_log": ParamSpec(lead + (nh,), la + (None,), "zeros", dtype=jnp.float32),
        "dt_bias": ParamSpec(lead + (nh,), la + (None,), "zeros", dtype=jnp.float32),
        "D": ParamSpec(lead + (nh,), la + (None,), "ones", dtype=jnp.float32),
        "gate_norm": ParamSpec(lead + (din,), la + ("inner",), "zeros", dtype=cfg.dtype),
        "out_proj": ParamSpec(lead + (din, d), la + ("inner", "embed"), "fan_in", dtype=cfg.dtype),
    }


def _segsum(dA):
    """dA [..., Q] -> L [..., Q, Q], L[i,j] = sum_{j<k<=i} dA[k] for i>=j else -inf."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x, dt, A, B, C, D, h0, chunk: int):
    """SSD chunked scan.

    x [B,S,H,P]; dt [B,S,H]; A [H] (negative); B,C [B,S,G,N] (G groups broadcast
    to heads); D [H]; h0 [B,H,N,P] f32. Returns (y [B,S,H,P], h_final).
    """
    b, s, nh, pdim = x.shape
    g = B.shape[2]
    rep = nh // g
    q = min(chunk, s)
    if s % q:  # pad with dt=0 steps (decay 1, zero input): state unchanged
        pad = q - s % q
        x, dt, B, C = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)) for a in (x, dt, B, C))
        y, h = ssd(x, dt, A, B, C, D, h0, chunk)
        return y[:, :s], h
    nc = s // q
    dA = (dt.astype(jnp.float32) * A[None, None]).reshape(b, nc, q, nh)     # [B,nc,Q,H]
    xr = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]).reshape(b, nc, q, nh, pdim)
    Br = jnp.repeat(B.astype(jnp.float32), rep, axis=2).reshape(b, nc, q, nh, -1)
    Cr = jnp.repeat(C.astype(jnp.float32), rep, axis=2).reshape(b, nc, q, nh, -1)

    def chunk_step(h, xs):
        dA_c, x_c, B_c, C_c = xs          # [B,Q,H], [B,Q,H,P], [B,Q,H,N], [B,Q,H,N]
        cum = jnp.cumsum(dA_c, axis=1)                                      # [B,Q,H]
        L = jnp.exp(_segsum(jnp.moveaxis(dA_c, 1, -1)))                     # [B,H,Q,Q]
        scores = jnp.einsum("bqhn,bkhn->bhqk", C_c, B_c) * L
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", scores, x_c)
        decay0 = jnp.exp(cum)                                               # [B,Q,H]
        y_state = jnp.einsum("bqhn,bhnp->bqhp", C_c * decay0[..., None], h)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)                        # [B,Q,H]
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bqhn,bqhp->bhnp", B_c * decay_to_end[..., None], x_c
        )
        return h_new, y_intra + y_state

    h, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            jnp.moveaxis(dA, 1, 0),
            jnp.moveaxis(xr, 1, 0),
            jnp.moveaxis(Br, 1, 0),
            jnp.moveaxis(Cr, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, pdim)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h


def ssd_ref(x, dt, A, B, C, D, h0):
    """Naive sequential oracle for SSD."""
    b, s, nh, pdim = x.shape
    g = B.shape[2]
    rep = nh // g
    Br = jnp.repeat(B.astype(jnp.float32), rep, axis=2)
    Cr = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    def step(h, t):
        a = jnp.exp(dt[:, t].astype(jnp.float32) * A[None])                 # [B,H]
        xt = x[:, t].astype(jnp.float32) * dt[:, t].astype(jnp.float32)[..., None]
        h = a[..., None, None] * h + jnp.einsum("bhn,bhp->bhnp", Br[:, t], xt)
        y = jnp.einsum("bhn,bhnp->bhp", Cr[:, t], h)
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h


def mamba2_block(p, cfg: ModelConfig, x, state=None):
    """Full-sequence mamba-2 block; returns (out, (conv_state, ssm_state))."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    din = s_cfg.expand * d
    nh = din // s_cfg.head_dim
    gn = s_cfg.n_groups * s_cfg.d_state
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    z, xbc, dt_raw = jnp.split(zxbcdt, [din, 2 * din + 2 * gn], axis=-1)
    xbc = shard(xbc, "batch", "seq", "inner")
    xbc_pre = xbc  # pre-conv stream: source of the decode conv_state
    xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [din, din + gn], axis=-1)
    xh = xin.reshape(b, s, nh, s_cfg.head_dim)
    Bh = Bm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    Ch = Cm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    dt = _softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((b, nh, s_cfg.d_state, s_cfg.head_dim), jnp.float32) if state is None else state
    y, h_fin = ssd(xh, dt, A, Bh, Ch, p["D"], h0, s_cfg.chunk)
    y = y.reshape(b, s, din)
    y = rmsnorm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    conv_state = jax.lax.dynamic_slice_in_dim(
        jnp.pad(xbc_pre, ((0, 0), (s_cfg.d_conv - 1, 0), (0, 0))),
        s, s_cfg.d_conv - 1, axis=1,
    )
    return x + out, (conv_state, h_fin)


def mamba2_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """x [B,1,d]; conv_state [B,K-1,conv_dim]; ssm_state [B,H,N,P] f32."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    din = s_cfg.expand * d
    nh = din // s_cfg.head_dim
    gn = s_cfg.n_groups * s_cfg.d_state
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    z, xbc, dt_raw = jnp.split(zxbcdt[:, 0], [din, 2 * din + 2 * gn], axis=-1)
    conv_state, xbc = conv_step(conv_state, xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [din, din + gn], axis=-1)
    xh = xin.reshape(b, nh, s_cfg.head_dim).astype(jnp.float32)
    rep = nh // s_cfg.n_groups
    Bh = jnp.repeat(Bm.reshape(b, s_cfg.n_groups, s_cfg.d_state).astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.reshape(b, s_cfg.n_groups, s_cfg.d_state).astype(jnp.float32), rep, axis=1)
    dt = _softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])                # [B,H]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])
    xdt = xh * dt[..., None]
    ssm_state = a[..., None, None] * ssm_state + jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm_state) + xh * p["D"][None, :, None]
    y = y.reshape(b, din)
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"], preferred_element_type=_pet32()).astype(x.dtype)
    return x + out[:, None], conv_state, ssm_state
