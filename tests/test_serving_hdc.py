"""HDC-as-a-service: multi-tenant slot-batched serving must be bit-identical
per slot to standalone `make_ota_serve` (same RNG stream), tenant lifecycle
(admit -> serve -> evict -> re-admit) must be prediction-identical to a fresh
standalone serve across representations and channels, and the scheduler must
drain with ceil(R / slots) steps."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_test_mesh
from repro import phy
from repro.core import classifier, hypervector as hv, scaleout
from repro.serving import HDCEngine, HDCScheduler

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _cfg(**kw):
    base = dict(n_classes=40, dim=512, m_tx=3, n_rx_cores=4, batch=8,
                use_kernels=False, noise="exact")
    base.update(kw)
    return scaleout.ScaleOutConfig(**base)


def _books(cfg, n):
    tcfg = classifier.HDCTaskConfig(n_classes=cfg.n_classes, dim=cfg.dim)
    return classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, n)


def _tenant_protos(cfg, book):
    return hv.pack(book) if cfg.packed else book


def test_mt_serve_bit_identical_per_slot():
    """Each slot of one multi-tenant launch == the standalone serve of that
    slot's queries against its tenant's codebook with the slot's own key —
    including slots sharing a tenant and nonzero per-core BER."""
    mesh = make_test_mesh((1, 1), ("data", "model"))
    for rep in ("unpacked", "packed"):
        for permuted in (False, True):
            cfg = _cfg(permuted=permuted, representation=rep)
            books = _books(cfg, 3)
            state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx)
            serve = scaleout.make_ota_serve(mesh, cfg)
            mt = scaleout.make_mt_ota_serve(mesh, cfg)
            rows = jnp.array([2, 0, 2], jnp.int32)  # slots 0 and 2 share tenant 2
            keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(3)])
            store = jnp.stack([_tenant_protos(cfg, b) for b in books])
            qs, want_p, want_s = [], [], []
            for s in range(3):
                book = books[int(rows[s])]
                _, q = scaleout.make_queries(jax.random.PRNGKey(50 + s), cfg, book, 1)
                qs.append(q)
                pr, si = serve(_tenant_protos(cfg, book), q, state, keys[s])
                want_p.append(np.asarray(pr))
                want_s.append(np.asarray(si))
            pred, sim = mt(store, jnp.stack(qs), rows, state, keys)
            np.testing.assert_array_equal(np.asarray(pred), np.stack(want_p))
            np.testing.assert_array_equal(np.asarray(sim), np.stack(want_s))


@pytest.mark.parametrize("rep", ["unpacked", "packed"])
@pytest.mark.parametrize("channel", ["bsc", "symbol"])
def test_tenant_lifecycle_identity(rep, channel):
    """admit -> serve -> evict -> re-admit (lands on a DIFFERENT store row)
    stays prediction-identical to a fresh standalone serve, for every
    representation x channel tier."""
    cfg = _cfg(representation=rep, channel=channel)
    mesh = make_test_mesh((1, 1), ("data", "model"))
    if channel == "symbol":
        state = scaleout.precharacterize_state(cfg)
    else:
        state = phy.state_from_ber(jnp.full((cfg.n_rx_cores,), 0.05), cfg.m_tx)
    books = _books(cfg, 2)
    serve = scaleout.make_ota_serve(mesh, cfg)
    eng = HDCEngine(mesh, cfg, state, num_slots=2, max_tenants=4)
    sched = HDCScheduler(eng)
    for t in range(2):
        eng.registry.onboard(t, _tenant_protos(cfg, books[t]))
    row0_before = eng.registry.rows[0]

    def check(tenant, seed):
        _, q = scaleout.make_queries(jax.random.PRNGKey(seed), cfg, books[tenant], 1)
        key = jax.random.PRNGKey(1000 + seed)
        rid = sched.submit(tenant, q, key=key)
        sched.run(timeout=600)
        got = sched.poll(rid)
        pr, si = serve(_tenant_protos(cfg, books[tenant]), q, state, key)
        np.testing.assert_array_equal(got.pred, np.asarray(pr))
        np.testing.assert_array_equal(got.maxsim, np.asarray(si))

    check(0, 7)
    check(1, 8)
    eng.registry.evict(0)
    eng.registry.onboard(2, _tenant_protos(cfg, books[0]))  # claims the freed row
    eng.registry.onboard(0, _tenant_protos(cfg, books[0]))  # re-admit: new row
    assert eng.registry.rows[0] != row0_before
    check(0, 9)  # prediction identity is row-independent


def test_scheduler_interleaves_tenants_and_drains():
    """R requests over S slots drain in ceil(R/S) steps with tenants mixed in
    one launch; registry/scheduler guard rails raise on misuse."""
    cfg = _cfg()
    mesh = make_test_mesh((1, 1), ("data", "model"))
    state = phy.state_from_ber(jnp.zeros((cfg.n_rx_cores,)), cfg.m_tx)
    books = _books(cfg, 2)
    eng = HDCEngine(mesh, cfg, state, num_slots=2, max_tenants=2)
    sched = HDCScheduler(eng)
    eng.registry.onboard("a", books[0])
    eng.registry.onboard("b", hv.pack(books[1]) if cfg.packed else books[1])
    _, q = scaleout.make_queries(jax.random.PRNGKey(3), cfg, books[0], 1)
    rids = [sched.submit("a" if i % 2 == 0 else "b", q) for i in range(5)]
    res = sched.run(timeout=600)
    assert len(res) == 5 and sched.steps == 3  # ceil(5/2)
    assert all(sched.poll(r).pred.shape == (cfg.batch,) for r in rids)
    # guard rails
    with pytest.raises(ValueError, match="already onboarded"):
        eng.registry.onboard("a", books[0])
    with pytest.raises(ValueError, match="registry full"):
        eng.registry.onboard("c", books[0])
    with pytest.raises(ValueError, match="not onboarded"):
        sched.submit("nope", q)
    with pytest.raises(ValueError, match="must be"):
        eng.registry.evict("a")
        eng.registry.onboard("a", books[0][:10])
    # a request queued for a tenant evicted before admission must fail loudly
    eng.registry.onboard("a", books[0])
    rid = sched.submit("a", q)
    eng.registry.evict("a")
    with pytest.raises(RuntimeError, match="evicted"):
        sched.run(timeout=600)


def test_mt_serve_multidevice_packed_collectives():
    """On a real 2x4 mesh the slot-flattened wire path (guard-bit packed vote
    all-reduce, packed reduce-scatter + all-gather) must stay bit-identical
    per slot to the standalone serve — the collectives see [N*B] rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro import phy
    from repro.compat import make_mesh
    from repro.core import scaleout, hypervector as hv, classifier
    mesh = make_mesh((2, 4), ("data", "model"))
    tcfg = classifier.HDCTaskConfig(n_classes=40, dim=512)
    books = classifier.make_tenant_codebooks(jax.random.PRNGKey(0), tcfg, 2)
    state = phy.state_from_ber(jnp.full((8,), 0.05), 3)
    for coll in ("psum_packed", "rs_ag"):
        cfg = scaleout.ScaleOutConfig(
            n_classes=40, dim=512, m_tx=3, n_rx_cores=8, batch=8,
            collective=coll, use_kernels=True, representation="packed",
            noise="exact")
        serve = scaleout.make_ota_serve(mesh, cfg)
        mt = scaleout.make_mt_ota_serve(mesh, cfg)
        rows = jnp.array([1, 0, 1], jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(3)])
        store = jnp.stack([hv.pack(b) for b in books])
        qs, preds, sims = [], [], []
        for s in range(3):
            book = books[int(rows[s])]
            _, q = scaleout.make_queries(jax.random.PRNGKey(50 + s), cfg, book, 4)
            qs.append(q)
            pr, si = serve(hv.pack(book), q, state, keys[s])
            preds.append(np.asarray(pr)); sims.append(np.asarray(si))
        pred, sim = mt(store, jnp.stack(qs), rows, state, keys)
        np.testing.assert_array_equal(np.asarray(pred), np.stack(preds))
        np.testing.assert_array_equal(np.asarray(sim), np.stack(sims))
    print("OK")
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"


# ---------------------------------------------------------------------------
# living channels: adaptive engine + link controller
# ---------------------------------------------------------------------------

def test_adaptive_engine_static_process_is_bit_identical():
    """AdaptiveHDCEngine under StaticProcess must serve bit-identically to the
    plain HDCEngine — the controller idles (no guard trips) and the process
    tick is a pure time increment."""
    from repro.serving import AdaptiveHDCEngine, LinkControllerConfig

    cfg = _cfg(channel="symbol")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    state = scaleout.precharacterize_state(cfg)
    books = _books(cfg, 2)
    engines = (
        HDCEngine(mesh, cfg, state, num_slots=2, max_tenants=2),
        AdaptiveHDCEngine(
            mesh, cfg, state, process=phy.StaticProcess(guard_dims=16),
            num_slots=2, max_tenants=2,
            controller=LinkControllerConfig(band_kwargs={"cap": 0.05})),
    )
    results = []
    for eng in engines:
        sched = HDCScheduler(eng)
        for t in range(2):
            eng.registry.onboard(t, books[t])
        rids = []
        for r in range(4):
            _, q = scaleout.make_queries(jax.random.PRNGKey(50 + r), cfg,
                                         books[r % 2], 1)
            rids.append(sched.submit(r % 2, q, key=jax.random.PRNGKey(100 + r)))
        sched.run(timeout=600)
        results.append([sched.results[r].pred for r in rids])
    for a, b in zip(*results):
        np.testing.assert_array_equal(a, b)
    adaptive = engines[1]
    assert int(adaptive.pstate.t) == 2        # 4 requests / 2 slots = 2 steps
    assert adaptive.controller.trace == []    # nothing tripped


def test_link_controller_hysteresis_no_flap():
    """Quarantine rides a bad/good re-fit hysteresis: persistently bad re-fits
    quarantine a core ONCE (no flapping while it stays bad), recovery releases
    it once, and the fleet m_drop/m_restore fires exactly once per direction."""
    from repro.serving import LinkController, LinkControllerConfig

    cfg = _cfg(channel="symbol")
    state = scaleout.precharacterize_state(cfg)
    proc = phy.StaticProcess(guard_dims=8)
    p = proc.init(state)
    n = state.n_rx
    cc = LinkControllerConfig(patience=1, quarantine_after=2, release_after=2,
                              drop_frac=0.5, band_kwargs={"cap": 0.05})
    ctl = LinkController(cc, p)
    hi = jnp.full((n,), 0.45, jnp.float32)
    junk = jax.random.normal(jax.random.PRNGKey(0), p.chan.symbols.shape,
                             jnp.float32).astype(jnp.complex64)
    p_bad = dataclasses.replace(
        p, chan=dataclasses.replace(p.chan, symbols=junk), est=hi)
    p_good = dataclasses.replace(p, est=hi)

    for _ in range(6):                        # persistently bad link
        ctl.act(p_bad)
    acts = [e["action"] for e in ctl.trace]
    assert acts.count("quarantine") == 1 and acts.count("release") == 0
    assert acts.count("m_drop") == 1 and acts.count("m_restore") == 0
    assert ctl.quarantined.all() and ctl.degraded

    for _ in range(6):                        # link recovers
        ctl.act(p_good)
    acts = [e["action"] for e in ctl.trace]
    assert acts.count("quarantine") == 1 and acts.count("release") == 1
    assert acts.count("m_drop") == 1 and acts.count("m_restore") == 1
    assert not ctl.quarantined.any() and not ctl.degraded


def test_adaptive_engine_fleet_switch_reuses_variants():
    """On a votes-wire tier the fleet degrade path (quarantine fraction over
    drop_frac) swaps to the prebuilt (m_floor, collective) serve variant —
    compiled once, reused across subsequent switches, serving uninterrupted."""
    from repro.serving import AdaptiveHDCEngine, LinkControllerConfig

    cfg = _cfg(channel="bsc")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    state = scaleout.precharacterize_state(cfg)     # symbol-valid state: the
    #   guard monitor + re-fit run on physics while bsc serves off chan.ber
    books = _books(cfg, 1)
    eng = AdaptiveHDCEngine(
        mesh, cfg, state,
        process=phy.PhaseDriftProcess(sigma=0.5, alpha=0.7, guard_dims=64),
        num_slots=1, max_tenants=1,
        controller=LinkControllerConfig(
            patience=1, quarantine_ber=-1.0, quarantine_after=1,
            release_ber=-1.0, drop_frac=0.25, band_kwargs={"cap": 0.02}))
    sched = HDCScheduler(eng)
    eng.registry.onboard(0, books[0])
    for r in range(8):
        _, q = scaleout.make_queries(jax.random.PRNGKey(50 + r), cfg,
                                     books[0], 1)
        sched.submit(0, q, key=jax.random.PRNGKey(100 + r))
        sched.run(timeout=600)
    acts = [e["action"] for e in eng.controller.trace]
    assert "quarantine" in acts and "m_drop" in acts and "link_mode" in acts
    assert sorted(eng._variants) == [(1, "psum"), (3, "psum")]
    assert len(sched.results) == 8            # serving never stalled


def test_link_controller_quarantine_and_release_thresholds_exact():
    """The hysteresis counters are exact: quarantine fires on the
    quarantine_after-th consecutive bad re-fit and not one earlier; release
    fires on the release_after-th consecutive good re-fit and not one
    earlier."""
    from repro.serving import LinkController, LinkControllerConfig

    cfg = _cfg(channel="symbol")
    state = scaleout.precharacterize_state(cfg)
    p = phy.StaticProcess(guard_dims=8).init(state)
    n = state.n_rx
    cc = LinkControllerConfig(patience=1, quarantine_after=3, release_after=2,
                              drop_frac=2.0, band_kwargs={"cap": 0.05})
    ctl = LinkController(cc, p)
    hi = jnp.full((n,), 0.45, jnp.float32)
    junk = jax.random.normal(jax.random.PRNGKey(0), p.chan.symbols.shape,
                             jnp.float32).astype(jnp.complex64)
    p_bad = dataclasses.replace(
        p, chan=dataclasses.replace(p.chan, symbols=junk), est=hi)
    p_good = dataclasses.replace(p, est=hi)

    for k in range(cc.quarantine_after - 1):
        ctl.act(p_bad)
        assert not ctl.quarantined.any(), k  # one short of the threshold
    ctl.act(p_bad)
    assert ctl.quarantined.all()             # exactly at quarantine_after

    for k in range(cc.release_after - 1):
        ctl.act(p_good)
        assert ctl.quarantined.all(), k      # one short of the threshold
    ctl.act(p_good)
    assert not ctl.quarantined.any()         # exactly at release_after
    assert not ctl.degraded                  # drop_frac=2.0 never binds
    assert not any(e["action"] == "m_drop" for e in ctl.trace)


def test_link_controller_drop_frac_boundary_is_inclusive():
    """The fleet degrade threshold is frac >= drop_frac: quarantining exactly
    one of n cores trips m_drop at drop_frac == 1/n and stays below it at any
    larger threshold — pinning the boundary so a config sized to 'degrade
    when a quarter is dark' fires on exactly a quarter."""
    from repro.serving import LinkController, LinkControllerConfig

    cfg = _cfg(channel="symbol")
    state = scaleout.precharacterize_state(cfg)
    p = phy.StaticProcess(guard_dims=8).init(state)
    n = state.n_rx
    junk = jax.random.normal(jax.random.PRNGKey(0), p.chan.symbols.shape,
                             jnp.float32).astype(jnp.complex64)
    # only row 0 is out of band: est 0.45 vs a <=0.05 band; the rest sit at 0
    est = jnp.zeros((n,), jnp.float32).at[0].set(0.45)
    p_bad0 = dataclasses.replace(
        p, chan=dataclasses.replace(p.chan, symbols=junk), est=est)
    for drop_frac, fires in ((1.0 / n, True), (1.0 / n + 0.01, False)):
        cc = LinkControllerConfig(patience=1, quarantine_after=1,
                                  drop_frac=drop_frac,
                                  band_kwargs={"cap": 0.05})
        ctl = LinkController(cc, p)
        ctl.act(p_bad0)
        assert ctl.quarantined.tolist() == [True] + [False] * (n - 1)
        assert ctl.degraded == fires, drop_frac
        assert any(e["action"] == "m_drop" for e in ctl.trace) == fires


def test_link_controller_no_flap_under_oscillating_refits():
    """A link whose re-fit quality oscillates bad/good around the split
    thresholds never flaps into quarantine: each direction's counter demands
    CONSECUTIVE outcomes and the opposite outcome resets it, so an oscillator
    can never reach quarantine_after (or, once quarantined, release_after)."""
    from repro.serving import LinkController, LinkControllerConfig

    cfg = _cfg(channel="symbol")
    state = scaleout.precharacterize_state(cfg)
    p = phy.StaticProcess(guard_dims=8).init(state)
    n = state.n_rx
    cc = LinkControllerConfig(patience=1, quarantine_after=2, release_after=2,
                              drop_frac=2.0, band_kwargs={"cap": 0.05})
    ctl = LinkController(cc, p)
    hi = jnp.full((n,), 0.45, jnp.float32)
    junk = jax.random.normal(jax.random.PRNGKey(0), p.chan.symbols.shape,
                             jnp.float32).astype(jnp.complex64)
    p_bad = dataclasses.replace(
        p, chan=dataclasses.replace(p.chan, symbols=junk), est=hi)
    p_good = dataclasses.replace(p, est=hi)

    for i in range(10):                       # bad, good, bad, good, ...
        ctl.act(p_bad if i % 2 == 0 else p_good)
    assert not ctl.quarantined.any() and not ctl.degraded
    assert not any(e["action"] in ("quarantine", "release", "m_drop")
                   for e in ctl.trace)
