"""Pure-jnp oracle for the packed Hamming similarity-search kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_search_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Packed-word Hamming distances via XOR + popcount.

    q: [B, W] uint32 (bit-packed queries), protos: [C, W] uint32 -> [B, C] int32.
    This is the operation an IMC associative-memory core performs in O(1); here it
    is the memory-bound digital realization used as the kernel oracle.
    """
    x = jnp.bitwise_xor(q[:, None, :], protos[None, :, :])  # [B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_search_banked_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Per-bank packed Hamming distances: q [G, B, W], protos [G, C, W] -> [G, B, C].

    Bank g's queries are compared only against bank g's prototypes — the
    per-IMC-core search of the scale-out serve step, as one batched op.
    """
    x = jnp.bitwise_xor(q[:, :, None, :], protos[:, None, :, :])  # [G, B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_topk_banked_ref(
    q: jax.Array, protos: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused per-bank top-1: (min_dist, argmin), each [G, B] int32.

    `jnp.argmin` returns the FIRST minimum — the tie convention the fused
    kernel must reproduce (identical to `jnp.argmax` over similarities, since
    sim = d - 2*dist is strictly decreasing in dist).
    """
    dist = hamming_search_banked_ref(q, protos)
    return jnp.min(dist, axis=-1), jnp.argmin(dist, axis=-1).astype(jnp.int32)


def hamming_topk_k_banked_ref(
    q: jax.Array, protos: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused per-bank top-k: (dists, idxs), each [G, B, k] int32,
    rank-sorted ascending by (distance, class index).

    Encoding (dist, col) as the single int32 key ``dist*C + col`` makes plain
    ascending key order EXACTLY lexicographic (dist, col) order; keys are
    globally unique (distinct cols), so rank r of the sorted keys is the r-th
    "first minimum" — the same tie convention as the top-1 oracle, extended to
    every rank.
    """
    dist = hamming_search_banked_ref(q, protos)
    c = dist.shape[-1]
    d = q.shape[-1] * 32
    assert 1 <= k <= c, (k, c)
    assert (d + 1) * c < 2**31, "key encoding would overflow int32"
    keys = dist * c + jnp.arange(c, dtype=jnp.int32)[None, None, :]
    keys = jnp.sort(keys, axis=-1)[..., :k]
    return keys // c, keys % c
