"""Collectives implementing the paper's OTA majority as mesh operations.

The paper's observation, transplanted to a TPU pod: *a reduce-then-broadcast of
binary data is one collective, and it may be lossy*. On the wireless chip the
superposition happens in the channel; on a pod the same semantics is an all-reduce
whose payload is 1 bit/element (sent as ±1) followed by a sign, with an optional
per-receiver binary-symmetric channel modelling the measured OTA BER.

These run inside ``compat.shard_map`` bodies (manual axes). The float variant
(``sign_allreduce``) is the majority-vote signSGD aggregation used by the
``sign_majority`` gradient-compression mode of the trainer — the beyond-paper
application of the same collective to data-parallel LM training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hypervector as hv


def ota_noise(key: jax.Array, bits: jax.Array, ber, axis_name: str | None = None) -> jax.Array:
    """Binary symmetric channel at rate `ber` on uint8 {0,1} bits.

    When `axis_name` is given, the key is folded with this device's index along
    that axis so every receiver sees an *independent* noisy copy — the paper's
    "each IMC core receives a slightly different version of Q".
    """
    if axis_name is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    flips = jax.random.bernoulli(key, ber, bits.shape)
    return jnp.bitwise_xor(bits, flips.astype(bits.dtype))


def ota_noise_packed(
    key: jax.Array,
    words: jax.Array,
    ber,
    axis_name: str | None = None,
    mode: str = "exact",
    planes: int = 16,
) -> jax.Array:
    """BSC on bit-packed uint32 words [..., W] — the packed serve path's channel.

    mode "exact": the flip mask is the same Bernoulli draw `ota_noise` makes
    (generated per 32-lane block, then packed), so the packed pipeline is
    bit-identical to the unpacked one on the same key. mode "bitplane": the
    mask is drawn directly as uint32 words via a bit-sliced `planes`-plane
    comparator (`hv.bernoulli_words`) — `planes` random bits per mask bit
    instead of 32, and no unpacked intermediate, at 2^-planes BER quantization;
    the production choice when replaying the unpacked stream doesn't matter.
    """
    if axis_name is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    if mode == "exact":
        return hv.flip_bits_packed(key, words, ber)
    if mode == "bitplane":
        return jnp.bitwise_xor(
            words, hv.bernoulli_words(key, ber, words.shape, precision=planes)
        )
    raise ValueError(f"unknown packed noise mode {mode!r}")


# ---------------------------------------------------------------------------
# guard-bit packed vote all-reduce
#
# The int8 vote psum of the OTA serve path sends 1 byte per hypervector
# dimension even though the tally only spans [-S*e_per, S*e_per]. Packing
# several votes per uint32 lane with guard bits makes the SAME reduction cost
# 32/(8*k) of the wire bytes: bias each vote to non-negative, give every field
# ceil(log2(2*S*e_per + 1)) bits so the summed field can never overflow into
# its neighbour, run ONE uint32 psum, unpack, un-bias. The tally is
# bit-identical to the int8 psum by construction (psum of packed fields ==
# packed psum of fields; property-tested in tests/test_distributed.py).
# ---------------------------------------------------------------------------


def vote_field_spec(
    group_size: int, e_per: int = 1, pow2_fields: bool = False,
    n_active: int | None = None,
) -> tuple[int, int]:
    """(field_bits, fields_per_lane) for guard-bit packed vote reduction.

    Each participant contributes a vote in [-e_per, e_per]; `group_size`
    participants sum over the reduce axis, so the biased per-field tally spans
    [0, 2*group_size*e_per] and needs ``field_bits = ceil(log2(span + 1))``
    bits. ``k = 32 // field_bits`` fields fit one uint32 lane. With
    `pow2_fields` k is rounded down to a power of two (the reduce-scatter leg
    needs the lane count to tile evenly over the mesh axis).

    `n_active` opts into **active-slot-aware** fields: when only M of the
    group's slots actually vote (the OTA serve's abstaining encoder slots),
    the tally spans [-M, M] regardless of how wide the mesh axis is, so the
    field only needs ``ceil(log2(2*M + 1))`` bits. At S=16/e_per=1/M=3 that is
    3-bit fields (k=10, a ~2.5x wire cut over int8) where S-sized guards gave
    6-bit fields (k=5, 1.25x). Callers must then bias each column by its OWN
    active count (`local_active` in the collectives below), not by e_per.
    """
    span = 2 * (group_size * e_per if n_active is None else n_active)
    fbits = max(1, span.bit_length())
    k = 32 // fbits
    assert k >= 1, f"vote span {span} does not fit a uint32 lane"
    if pow2_fields:
        k = 1 << (k.bit_length() - 1)
    return fbits, k


def _pack_vote_fields(votes: jax.Array, bias, fbits: int, k: int) -> jax.Array:
    """Bias int votes [..., d] by `bias` (non-negative) and pack k fields per
    uint32 lane.

    `bias` is this column's per-field offset: e_per for slot-blind packing, or
    the column's active-voter count (possibly traced) for slot-aware packing.
    d is padded to a multiple of k with zero votes (which bias to `bias` and
    stay within the field's guard bits; sliced away after unpacking). Field i
    of a lane holds element lane*k + i at bit offset i*fbits.
    """
    d = votes.shape[-1]
    pad = (-d) % k
    bias = jnp.asarray(bias, jnp.int32)
    biased = (votes.astype(jnp.int32) + bias).astype(jnp.uint32)
    if pad:
        fill = jnp.broadcast_to(
            bias.astype(jnp.uint32), votes.shape[:-1] + (pad,)
        )
        biased = jnp.concatenate([biased, fill], axis=-1)
    blocks = biased.reshape(biased.shape[:-1] + (-1, k))
    shifts = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(fbits))
    return jnp.sum(blocks << shifts, axis=-1, dtype=jnp.uint32)


def _unpack_vote_fields(
    lanes: jax.Array, d: int, bias: int, fbits: int, k: int
) -> jax.Array:
    """Inverse of `_pack_vote_fields` after the reduction: int32 tally [..., d].

    `bias` is the accumulated per-field offset (group_size * e_per after a full
    all-reduce or reduce-scatter over the group).
    """
    shifts = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(fbits))
    mask = jnp.uint32((1 << fbits) - 1)
    fields = (lanes[..., None] >> shifts) & mask
    flat = fields.reshape(lanes.shape[:-1] + (lanes.shape[-1] * k,))
    return flat[..., :d].astype(jnp.int32) - bias


def packed_vote_allreduce(
    votes: jax.Array, axis_name: str, *, group_size: int, e_per: int = 1,
    n_active: int | None = None, local_active=None, total_active=None,
) -> jax.Array:
    """Guard-bit packed vote all-reduce: int votes [..., d] -> int32 tally [..., d].

    Bit-identical to ``psum(votes, axis_name)`` (no field can overflow by
    construction) while sending ``ceil(d/k)`` uint32 lanes instead of d int8
    bytes — a 2x wire-byte cut at the paper's M=3 operating point on a 4-wide
    model axis (4-bit fields, k=8). This is the OTA majority collective of
    `make_ota_serve(collective="psum_packed")`.

    **Active-slot-aware mode** (`n_active` + `local_active`): when only
    `n_active` voters across the whole group are live (every other slot votes
    exactly 0), fields shrink to the [-n_active, n_active] tally span —
    3-bit fields / k=10 / ~2.5x at S=16, M=3, where slot-blind guards give
    6-bit / k=5 / 1.25x. `local_active` is this column's own live-voter count
    (traced is fine; it becomes the column's bias so the biased fields sum to
    exactly n_active + tally). Caller contract: ``|votes| <= local_active``
    element-wise and ``psum(local_active) == n_active`` — both hold for the
    serve body's abstaining-slot votes by construction.

    ``total_active`` (traced is fine) overrides the accumulated bias the
    unpack subtracts when the LIVE voter count differs from the static
    ``n_active`` — the erasure-aware mode (`repro.faults`): dead or dropped
    slots vote exact 0 with `local_active` excluding them, and the caller
    passes the group-wide live total (``psum(local_active)``, computed
    locally from the replicated fault masks — no extra collective). Field
    sizing stays ``n_active`` (a valid span upper bound: live <= n_active),
    so erasures never change the compiled wire format.
    """
    fbits, k = vote_field_spec(group_size, e_per, n_active=n_active)
    if n_active is None:
        bias, total_bias = e_per, group_size * e_per
    else:
        assert local_active is not None, "slot-aware packing needs local_active"
        bias = local_active
        total_bias = n_active if total_active is None else total_active
    lanes = _pack_vote_fields(votes, bias, fbits, k)
    lanes = jax.lax.psum(lanes, axis_name)
    return _unpack_vote_fields(lanes, votes.shape[-1], total_bias, fbits, k)


def packed_vote_psum_scatter(
    votes: jax.Array, axis_name: str, *, group_size: int, e_per: int = 1,
    n_active: int | None = None, local_active=None, total_active=None,
) -> jax.Array:
    """Guard-bit packed reduce-scatter of votes along their last dimension.

    Returns this device's contiguous tally shard [..., d // group_size] int32,
    bit-identical to ``psum_scatter(votes, tiled=True)`` on the same shard.
    Fields per lane are rounded down to a power of two so whole lanes tile
    evenly over the axis; if d doesn't divide into lanes x group_size the
    plain scatter is used unchanged (int8 on the wire whenever the tally span
    fits int8, so no saving but also no regression). `n_active`/`local_active`
    select the active-slot-aware field sizing exactly as in
    `packed_vote_allreduce`; ``total_active`` is the same erasure-aware
    live-total override (ignored by the plain-scatter fallback, which sums
    the raw votes and needs no bias at all).
    """
    d = votes.shape[-1]
    fbits, k = vote_field_spec(group_size, e_per, pow2_fields=True,
                               n_active=n_active)
    if d % (k * group_size) != 0:
        wire = votes if group_size * e_per <= 127 else votes.astype(jnp.int32)
        part = jax.lax.psum_scatter(
            wire, axis_name, scatter_dimension=votes.ndim - 1, tiled=True
        )
        return part.astype(jnp.int32)
    if n_active is None:
        bias, total_bias = e_per, group_size * e_per
    else:
        assert local_active is not None, "slot-aware packing needs local_active"
        bias = local_active
        total_bias = n_active if total_active is None else total_active
    lanes = _pack_vote_fields(votes, bias, fbits, k)
    part = jax.lax.psum_scatter(
        lanes, axis_name, scatter_dimension=votes.ndim - 1, tiled=True
    )
    return _unpack_vote_fields(part, d // group_size, total_bias, fbits, k)


def sparse_index_allgather(idx: jax.Array, axis_name: str) -> jax.Array:
    """All-gather sparse index lists over `axis_name`, slot-flattened.

    idx: int32 [..., e, k_max] (this shard's `e` encoder slots as sorted
    sentinel-padded index lists) -> [..., S*e, k_max] with the slot axis in
    global-encoder order (shard-major: slot s*e + j is shard s's slot j —
    the `gids = tx*e_per + arange(e_per)` convention of the serve body).

    This is the sparse wire format of the OTA majority: each TX ships its
    k_max·32 bits of indices instead of the d field-packed vote bits of
    `packed_vote_allreduce`, and the majority is taken locally over the
    gathered union (`sparse.bundle`). Crossover vs the guard-bit psum is at
    k_max ~ d/field_bits·... — measured, fitted, and gated by
    benchmarks/sparse.py; `ScaleOutConfig.representation="auto"` picks per
    workload from that fit.
    """
    g = jax.lax.all_gather(idx, axis_name)  # [S, ..., e, k_max]
    g = jnp.moveaxis(g, 0, -3)              # [..., S, e, k_max]
    return g.reshape(g.shape[:-3] + (g.shape[-3] * g.shape[-2], g.shape[-1]))


def majority_allreduce(
    bits: jax.Array,
    axis_name: str,
    *,
    key: jax.Array | None = None,
    ber=None,
    rx_axis_name: str | None = None,
) -> jax.Array:
    """OTA majority bundling across `axis_name`: uint8 {0,1} shards -> majority bits.

    Equivalent to the paper's over-the-air computation: every device along
    `axis_name` contributes its hypervector; all devices receive maj(·) in a single
    all-reduce. Ties on even group size resolve to 0 (`tally > 0`) — the repo-wide
    convention shared by `hv.majority`/`hv.majority_packed` (without a key) and
    the `kernels.majority` oracle, asserted in tests/test_hdc_core.py.
    Optional (key, ber): apply the OTA error channel to the *received* copy,
    independently per device along `rx_axis_name` (default: the reduce axis).
    """
    bipolar = 2 * bits.astype(jnp.int32) - 1
    votes = jax.lax.psum(bipolar, axis_name)
    out = (votes > 0).astype(jnp.uint8)
    if ber is not None:
        assert key is not None, "OTA noise needs a PRNG key"
        out = ota_noise(key, out, ber, rx_axis_name or axis_name)
    return out


def sign_allreduce(
    x: jax.Array, axis_name: str, *, key=None, ber=None, device_index=None
) -> jax.Array:
    """Majority-vote sign aggregation (1-bit compressed all-reduce) for floats.

    Payload on the wire is sign(x) (1 bit/element vs 32): the majority-vote
    signSGD aggregation [Bernstein et al.] — structurally identical to the
    paper's OTA bundling with gradients in place of query hypervectors. Optional
    BER applies the OTA channel to the result (sign flips), which HDC-style error
    tolerance (and signSGD's) absorbs.

    `device_index`: this device's linear index along the reduce axes, used to
    decorrelate the per-receiver noise. Callers inside a *partially-auto*
    shard_map (the sign_majority trainer) must pass it explicitly (threaded in
    as a sharded iota input): `lax.axis_index` there lowers to a partition-id
    HLO op that 0.4.x XLA's SPMD partitioner rejects. Fully-manual bodies may
    omit it and get the `lax.axis_index` fold, which is fine on every pin.
    """
    votes = jax.lax.psum(jnp.sign(x).astype(jnp.float32), axis_name)
    out = jnp.sign(votes)
    if ber is not None:
        assert key is not None, "OTA noise needs a PRNG key"
        if device_index is not None:
            key = jax.random.fold_in(key, device_index)
        else:
            axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
            for ax in axes:
                key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        flips = jax.random.bernoulli(key, ber, out.shape)
        out = jnp.where(flips, -out, out)
    return out.astype(x.dtype)
