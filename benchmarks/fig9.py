"""Fig. 9: architecture scalability — average BER vs number of RX cores
(3 TXs; re-optimizing the joint TX phases for every RX population)."""
from __future__ import annotations

from benchmarks.common import save
from repro.core import em, ota

N_RX = (4, 8, 16, 32, 64, 128)


def run(quiet: bool = False) -> dict:
    geom = em.PackageGeometry()
    avg, worst = [], []
    for n in N_RX:
        h = em.channel_matrix(geom, 3, n)
        res = ota.optimize_phases_exhaustive(h, ota.default_n0(h))
        avg.append(float(res.avg_ber))
        worst.append(float(res.max_ber))
        if not quiet:
            print(f"N_rx={n:4d}  avg BER {avg[-1]:.5f}  max {worst[-1]:.5f}")
    out = {"n_rx": list(N_RX), "avg_ber": avg, "max_ber": worst}
    save("fig9", out)
    return out


if __name__ == "__main__":
    run()
