"""Continuous-batching request scheduler: submit/poll queue + length-bucketed
admission over a ``ContinuousEngine``.

Pending requests sit in per-prompt-shape FIFO buckets (prompt length plus the
shapes of any extra inputs) — one compiled prefill serves each bucket, so the
number of prefill compiles is bounded by the number of distinct prompt shapes
(the same bucketing rule the static engine applies per ``generate`` call). Admission fills free slots from the bucket holding the
globally oldest pending request, so same-length requests drain together while
arrival order is respected across buckets.

Eviction is step-granular: each engine step emits one token per slot; a slot
whose request reached ``max_new`` (or emitted EOS) is freed immediately and
refilled on the next admission pass while the remaining slots keep decoding —
no drain barrier, no recompile.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import ContinuousEngine, _prompt_sig


@dataclasses.dataclass
class Request:
    rid: int
    batch: dict                  # B=1 model inputs incl. 'tokens' [1, S]
    prompt_len: int
    max_new: int
    key: Any
    t_submit: float


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list[int]            # generated tokens (incl. the final EOS, if any)
    finish_reason: str           # "length" | "eos"
    prompt_len: int
    t_submit: float
    t_admit: float
    t_finish: float

    @property
    def latency(self) -> float:
        """Submit-to-finish wall time (includes queueing)."""
        return self.t_finish - self.t_submit


class Scheduler:
    """Request queue + admission policy in front of a ``ContinuousEngine``."""

    def __init__(self, engine: ContinuousEngine, params,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.params = params
        self.clock = clock
        self.state = engine.init_state()
        self.free: list[int] = list(range(engine.num_slots))
        # slot -> (request, tokens so far, t_admit)
        self.running: dict[int, tuple[Request, list[int], float]] = {}
        self.buckets: dict[int, collections.deque] = collections.defaultdict(
            collections.deque
        )
        self.results: dict[int, Completion] = {}
        self.steps = 0
        self._next_rid = 0

    # -- queue ---------------------------------------------------------------

    def submit(self, tokens, *, extras: dict | None = None,
               max_new: int | None = None, key: jax.Array | None = None) -> int:
        """Queue one request. `tokens` [S] or [1, S]; `extras` holds additional
        B=1 model inputs (patch_embeds, positions, frames). Returns request id."""
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim == 1:
            tokens = tokens[None]
        batch = {"tokens": tokens, **(extras or {})}
        max_new = self.engine.cfg.max_new if max_new is None else max_new
        if not 1 <= max_new <= self.engine.cfg.max_new:
            raise ValueError(f"max_new must be in [1, {self.engine.cfg.max_new}]")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid, batch, tokens.shape[1], max_new,
            key if key is not None else jax.random.PRNGKey(rid), self.clock(),
        )
        self.buckets[_prompt_sig(batch)].append(req)
        return rid

    def poll(self, rid: int) -> Completion | None:
        return self.results.get(rid)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.buckets.values())

    @property
    def active(self) -> int:
        return len(self.running)

    # -- admission / eviction ------------------------------------------------

    def _oldest_bucket(self) -> tuple | None:
        live = [(q[0].t_submit, q[0].rid, s) for s, q in self.buckets.items() if q]
        return min(live)[2] if live else None

    def _finish(self, slot: int, reason: str) -> Completion:
        req, toks, t_admit = self.running.pop(slot)
        done = Completion(
            req.rid, toks, reason, req.prompt_len, req.t_submit, t_admit, self.clock()
        )
        self.results[req.rid] = done
        self.free.append(slot)
        return done

    def _admit_free_slots(self) -> list[Completion]:
        finished = []
        while self.free:
            bucket = self._oldest_bucket()
            if bucket is None:
                break
            q = self.buckets[bucket]
            while self.free and q:
                req = q.popleft()
                slot = self.free.pop(0)
                self.state, tok0 = self.engine.prefill_into_slot(
                    self.params, self.state, req.batch, slot, req.key
                )
                self.running[slot] = (req, [tok0], self.clock())
                eos = self.engine.cfg.eos_id
                if eos is not None and tok0 == eos:
                    finished.append(self._finish(slot, "eos"))
                elif req.max_new <= 1:
                    finished.append(self._finish(slot, "length"))
        return finished

    # -- drive ---------------------------------------------------------------

    def step(self) -> list[Completion]:
        """Admit into free slots, run one multi-slot decode step, evict finished
        slots. Returns the requests completed during this call."""
        finished = self._admit_free_slots()
        if not self.running:
            return finished
        self.state, emitted = self.engine.step(self.params, self.state)
        self.steps += 1
        em = np.asarray(emitted)    # device sync: this is the step barrier
        eos = self.engine.cfg.eos_id
        for slot in sorted(self.running):
            req, toks, _ = self.running[slot]
            tok = int(em[slot])
            toks.append(tok)
            if eos is not None and tok == eos:
                finished.append(self._finish(slot, "eos"))
            elif len(toks) >= req.max_new:
                finished.append(self._finish(slot, "length"))
        return finished

    def run(self, timeout: float | None = None) -> dict[int, Completion]:
        """Step until the queue and all slots drain. Returns {rid: Completion}."""
        t0 = self.clock()
        while self.pending or self.running:
            self.step()
            if timeout is not None and self.clock() - t0 > timeout:
                raise TimeoutError(
                    f"scheduler did not drain within {timeout}s "
                    f"(pending={self.pending}, active={self.active})"
                )
        return self.results
