"""Public op: fused flash attention forward (TPU fast path)."""
from __future__ import annotations

import jax

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_fwd_pallas
from repro.kernels.flash_attention.ref import flash_fwd_ref


def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = -1,
    block_q: int = 512,
    block_k: int = 1024,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Fused attention forward. Block sizes are clipped to divisors of Sq/Skv."""
    if interpret is None:
        interpret = common.default_interpret()
    if not use_kernel:
        return flash_fwd_ref(q, k, v, causal=causal, window=window,
                             block_q=block_q, block_k=block_k)
    from repro.models.layers import _largest_divisor

    bq = _largest_divisor(q.shape[1], block_q)
    bk = _largest_divisor(k.shape[1], block_k)
    return flash_fwd_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=interpret,
    )
