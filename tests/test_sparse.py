"""Ultra-sparse index-list hypervectors: algebra properties, kernel sweeps,
serve/classifier parity, config validation, and the single-row rebaseline.

The algebra properties pin every sparse op bit-exact against an RNG-matched
dense reference (sparsify/densify round-trips + the hv.* dense ops), including
the canonical keep-smallest saturation rule and the all-SENTINEL empty HV.
The kernel sweeps pin the Pallas family (interpret mode) and the streamed
fallback against the deliberately-dense oracles in kernels/sparse/ref.py.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # prefer the real engine when installed
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic seeded fallback
    from _propcheck import given, settings, strategies as st

from conftest import make_test_mesh

from repro.core import classifier, hypervector as hv, scaleout, sparse
from repro.kernels.sparse import sparse_search, sparse_topk_banked
from repro.kernels.sparse.ref import (
    sparse_search_banked_ref,
    sparse_search_ref,
    sparse_topk_banked_ref,
)

KEY = jax.random.PRNGKey(0)

# ---------------------------------------------------------------------------
# algebra properties: every op == its dense reference, bit for bit
# ---------------------------------------------------------------------------

# (seed, words, k_max, dense) -> d = words*32; dense=True draws ~1/2 density
# so results SATURATE and exercise the keep-smallest truncation
_cases = st.lists(st.integers(0, 2**20), min_size=4, max_size=4).map(
    lambda v: (v[0], 2 + v[1] % 15, 4 + v[2] % 29, v[3] % 2 == 0))


def _draw_bits(key, n, d, dense):
    p = 0.5 if dense else 4.0 / d
    return jax.random.bernoulli(key, p, (n, d)).astype(jnp.uint8)


@settings(max_examples=15, deadline=None)
@given(_cases)
def test_sparsify_densify_roundtrip_and_saturation(case):
    seed, words, k_max, dense = case
    d = words * 32
    bits = _draw_bits(jax.random.PRNGKey(seed), 3, d, dense)
    idx = sparse.sparsify(bits, k_max)
    # sorted, sentinel-padded, and exactly the k_max SMALLEST set indices
    assert idx.shape == (3, k_max) and idx.dtype == jnp.int32
    np.testing.assert_array_equal(np.sort(np.asarray(idx), -1), np.asarray(idx))
    for row_bits, row_idx in zip(np.asarray(bits), np.asarray(idx)):
        set_idx = np.flatnonzero(row_bits)[:k_max]
        np.testing.assert_array_equal(row_idx[: len(set_idx)], set_idx)
        assert (row_idx[len(set_idx):] == sparse.SENTINEL).all()
    # densify inverts exactly on the truncated image
    trunc = sparse.densify(idx, d)
    np.testing.assert_array_equal(
        np.asarray(sparse.sparsify(trunc, k_max)), np.asarray(idx))


@settings(max_examples=15, deadline=None)
@given(_cases)
def test_bind_matches_dense_xor(case):
    seed, words, k_max, dense = case
    d = words * 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a_bits = _draw_bits(k1, 4, d, dense)
    b_bits = _draw_bits(k2, 4, d, dense)
    a = sparse.sparsify(a_bits, k_max)
    b = sparse.sparsify(b_bits, k_max)
    got = sparse.bind(a, b)
    want = sparse.sparsify(
        jnp.bitwise_xor(sparse.densify(a, d), sparse.densify(b, d)), k_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(_cases)
def test_bundle_matches_dense_majority(case):
    seed, words, k_max, dense = case
    d = words * 32
    for m in (1, 2, 3, 5):
        bits = _draw_bits(jax.random.fold_in(jax.random.PRNGKey(seed), m),
                          m, d, dense)
        stack = sparse.sparsify(bits, k_max)
        got = sparse.bundle(stack[None])[0]
        want = sparse.sparsify(hv.majority(sparse.densify(stack, d)), k_max)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want)), m


def test_bundle_with_abstaining_slots():
    """Traced m < M with all-SENTINEL abstainers == dense majority over the
    first m voters (an empty list is exactly a dense all-zero vote)."""
    d, k_max, m_act, m_tot = 256, 16, 3, 5
    bits = _draw_bits(KEY, m_act, d, dense=False)
    stack = sparse.sparsify(bits, k_max)
    empty = jnp.full((m_tot - m_act, k_max), sparse.SENTINEL, jnp.int32)
    padded = jnp.concatenate([stack, empty], axis=0)
    got = sparse.bundle(padded[None], m=jnp.int32(m_act))[0]
    want = sparse.sparsify(hv.majority(bits), k_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(_cases)
def test_permute_matches_dense_cyclic_shift(case):
    seed, words, k_max, dense = case
    d = words * 32
    bits = _draw_bits(jax.random.PRNGKey(seed), 3, d, dense)
    idx = sparse.sparsify(bits, k_max)
    for shift in (0, 1, 7, d - 1):
        got = sparse.permute(idx, shift, d)
        want = sparse.sparsify(
            hv.permute(sparse.densify(idx, d), shift), k_max)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want)), shift


@settings(max_examples=15, deadline=None)
@given(_cases)
def test_flip_bits_sparse_matches_rng_matched_dense_ref(case):
    seed, words, k_max, dense = case
    d = words * 32
    key = jax.random.PRNGKey(seed)
    bits = _draw_bits(jax.random.fold_in(key, 1), 3, d, dense)
    idx = sparse.sparsify(bits, k_max)
    for ber in (0.0, 0.01, 0.3):
        got = sparse.densify(sparse.flip_bits_sparse(key, idx, ber, d), d)
        want = sparse.flip_bits_sparse_ref(
            key, sparse.densify(idx, d), ber, k_max)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want)), ber


def test_empty_hv_through_every_op():
    d, k_max = 256, 8
    empty = jnp.full((1, k_max), sparse.SENTINEL, jnp.int32)
    other = sparse.sparsify(_draw_bits(KEY, 1, d, dense=False), k_max)
    assert int(sparse.count(empty)[0]) == 0
    np.testing.assert_array_equal(  # bind with empty == identity
        np.asarray(sparse.bind(empty, other)), np.asarray(other))
    np.testing.assert_array_equal(  # 1-voter bundle of empty stays empty
        np.asarray(sparse.bundle(empty[None])), np.asarray(empty))
    np.testing.assert_array_equal(
        np.asarray(sparse.permute(empty, 5, d)), np.asarray(empty))
    np.testing.assert_array_equal(  # ber=0: nothing to drop, nothing inserted
        np.asarray(sparse.flip_bits_sparse(KEY, empty, 0.0, d)),
        np.asarray(empty))
    assert not np.asarray(sparse.densify(empty, d)).any()


# ---------------------------------------------------------------------------
# kernel sweeps vs the dense oracles (interpret mode)
# ---------------------------------------------------------------------------

SEARCH_SHAPES = [(4, 100, 512, 16), (17, 33, 1024, 32), (8, 130, 224, 8)]


@pytest.mark.parametrize("b,c,d,k_max", SEARCH_SHAPES)
@pytest.mark.parametrize("use_kernel", [True, False])
def test_sparse_search_sweep(b, c, d, k_max, use_kernel):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, b * c))
    q = sparse.random_sparse(k1, b, d, k_max, 4.0 / d)
    p = hv.pack(hv.random_hv(k2, c, d))
    got = sparse_search(q, p, use_kernel=use_kernel, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(sparse_search_ref(q, p)))


BANKED_SHAPES = [(4, 8, 128, 512, 16), (3, 5, 7, 224, 8), (1, 9, 130, 1024, 32)]


@pytest.mark.parametrize("g,b,c,d,k_max", BANKED_SHAPES)
@pytest.mark.parametrize("use_kernel", [True, False])
def test_sparse_topk_banked_sweep(g, b, c, d, k_max, use_kernel):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, g * b * c))
    q = sparse.random_sparse(k1, g * b, d, k_max, 4.0 / d).reshape(g, b, k_max)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, d // 32)
    rv, ri = sparse_topk_banked_ref(q, p)
    v, i = sparse_topk_banked(q, p, use_kernel=use_kernel, interpret=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_sparse_topk_tie_breaking(use_kernel):
    """Exact-duplicate prototypes across class-tile boundaries: the FIRST
    minimum must win, matching `jnp.argmin` / the hamming family convention."""
    d, c, k_max = 512, 300, 16
    q = sparse.random_sparse(jax.random.PRNGKey(5), 1, d, k_max, 8.0 / d)
    q_dense = sparse.densify(q, d)
    base = hv.pack(hv.random_hv(jax.random.PRNGKey(6), c, d))
    for dup_positions in [(5, 17), (5, 200), (130, 260), (129, 130, 299)]:
        p = base
        for pos in dup_positions:
            p = p.at[pos].set(hv.pack(q_dense)[0])
        pb = p[None]
        v, i = sparse_topk_banked(q[None], pb, use_kernel=use_kernel,
                                  interpret=True)
        assert int(v[0, 0]) == 0
        assert int(i[0, 0]) == dup_positions[0], (dup_positions, int(i[0, 0]))
    # empty query: distance == popcount(p), still first-minimum on ties
    empty = jnp.full((1, 1, k_max), sparse.SENTINEL, jnp.int32)
    rv, ri = sparse_topk_banked_ref(empty, base[None])
    v, i = sparse_topk_banked(empty, base[None], use_kernel=use_kernel,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_sparse_search_banked_ref_consistency():
    """The banked oracle is the per-bank stack of the flat oracle."""
    g, b, c, d, k_max = 3, 4, 10, 256, 8
    k1, k2 = jax.random.split(KEY)
    q = sparse.random_sparse(k1, g * b, d, k_max, 4.0 / d).reshape(g, b, k_max)
    p = hv.pack(hv.random_hv(k2, g * c, d)).reshape(g, c, d // 32)
    banked = sparse_search_banked_ref(q, p)
    for gi in range(g):
        np.testing.assert_array_equal(
            np.asarray(banked[gi]), np.asarray(sparse_search_ref(q[gi], p[gi])))


# ---------------------------------------------------------------------------
# serve + classifier parity on the single-device mesh
# ---------------------------------------------------------------------------


def _sparse_codebook(key, n, d, k_max, density):
    """Rows that all fit k_max, so sparsify is lossless (identity scenario)."""
    return sparse.densify(sparse.random_sparse(key, n, d, k_max, density), d)


def test_serve_sparse_prediction_identical_to_packed():
    from repro import phy

    mesh = make_test_mesh((1, 1), ("data", "model"))
    base = dict(n_classes=64, dim=1024, m_tx=3, n_rx_cores=4, batch=16,
                channel="ideal", use_kernels=False)
    cfg_sp = scaleout.ScaleOutConfig(representation="sparse", k_max=32,
                                     collective="index_ag", **base)
    cfg_pk = scaleout.ScaleOutConfig(representation="packed",
                                     collective="psum_packed", **base)
    protos_u = _sparse_codebook(KEY, 64, 1024, 32, 8.0 / 1024)
    protos = hv.pack(protos_u)
    _, q_sp = scaleout.make_queries(KEY, cfg_sp, protos_u, 1)
    _, q_pk = scaleout.make_queries(KEY, cfg_pk, protos_u, 1)
    state = phy.state_from_ber(jnp.full((4,), 0.01, jnp.float32), 3)
    k_serve = jax.random.PRNGKey(11)
    pred_sp, sim_sp = scaleout.make_ota_serve(mesh, cfg_sp)(
        protos, q_sp, state, k_serve)
    pred_pk, sim_pk = scaleout.make_ota_serve(mesh, cfg_pk)(
        protos, q_pk, state, k_serve)
    np.testing.assert_array_equal(np.asarray(pred_sp), np.asarray(pred_pk))
    np.testing.assert_allclose(np.asarray(sim_sp), np.asarray(sim_pk))
    # oracle agreement + the dense psum fallback for sparse queries
    pred_ref, sim_ref = scaleout.serve_reference(cfg_sp, protos_u, q_sp)
    np.testing.assert_array_equal(np.asarray(pred_sp), np.asarray(pred_ref))
    np.testing.assert_allclose(np.asarray(sim_sp), np.asarray(sim_ref))
    import dataclasses
    cfg_psum = dataclasses.replace(cfg_sp, collective="psum")
    pred_f, sim_f = scaleout.make_ota_serve(mesh, cfg_psum)(
        protos, q_sp, state, k_serve)
    np.testing.assert_array_equal(np.asarray(pred_f), np.asarray(pred_sp))


def test_classifier_sparse_parity_at_zero_ber():
    """m=1, ber=0: every representation sees the same codebook bits and must
    land the same (perfect) accuracy; sparse noise at small ber stays high."""
    cfg = classifier.HDCTaskConfig(n_classes=32, dim=512, n_trials=64)
    accs = {
        rep: float(classifier.run_accuracy(
            KEY, cfg, 1, 0.0, "baseline", representation=rep,
            density=16 / 512, k_max=64))
        for rep in ("sparse", "packed", "unpacked")
    }
    assert accs["sparse"] == accs["packed"] == accs["unpacked"] == 1.0, accs
    noisy = float(classifier.run_accuracy(
        KEY, cfg, 1, 2e-3, "baseline", representation="sparse",
        density=16 / 512, k_max=64))
    assert noisy >= 0.9, noisy


# ---------------------------------------------------------------------------
# config validation: sparse x unsupported features must fail at build time
# ---------------------------------------------------------------------------


def test_sparse_config_validation_raises():
    base = dict(n_classes=16, dim=256, m_tx=3, n_rx_cores=4, batch=4)
    for bad in (
        dict(representation="sparse", k_max=0, collective="index_ag"),
        dict(representation="sparse", k_max=8, collective="index_ag",
             permuted=True),
        dict(representation="sparse", k_max=8, collective="index_ag",
             coarse_group=4),
        dict(representation="sparse", k_max=8, collective="rs_ag"),
        dict(representation="sparse", k_max=8, collective="index_ag",
             channel="symbol"),
        dict(representation="packed", collective="index_ag"),
        dict(representation="auto", k_max=0, collective="psum"),
    ):
        with pytest.raises(ValueError):
            scaleout.ScaleOutConfig(**{**base, **bad})


def test_sparse_unsupported_serves_raise():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = scaleout.ScaleOutConfig(
        n_classes=16, dim=256, m_tx=3, n_rx_cores=4, batch=4,
        representation="sparse", k_max=8, collective="index_ag")
    with pytest.raises(ValueError):
        scaleout.make_mt_ota_serve(mesh, cfg)
    with pytest.raises(ValueError):
        scaleout.make_wired_serve(mesh, cfg)
    from repro import faults
    with pytest.raises(ValueError):
        scaleout.make_ota_serve(mesh, cfg, faults=faults.StaticFaults())
    cfg_t = classifier.HDCTaskConfig(n_classes=8, dim=128, n_trials=4)
    with pytest.raises(ValueError):  # sparse classifier needs k_max
        classifier.run_accuracy(KEY, cfg_t, 1, 0.0, "baseline",
                                representation="sparse")
    with pytest.raises(ValueError):  # and rejects non-baseline bundling
        classifier.run_accuracy(KEY, cfg_t, 1, 0.0, "permute",
                                representation="sparse", k_max=8)


def test_auto_resolution_and_crossover_table():
    cfg = scaleout.ScaleOutConfig(
        n_classes=16, dim=2048, m_tx=3, n_rx_cores=4, batch=4,
        representation="auto", k_max=32, collective="psum")
    lo = scaleout.resolve_representation(cfg)
    assert lo.representation == "sparse" and lo.collective == "index_ag"
    import dataclasses
    hi = scaleout.resolve_representation(
        dataclasses.replace(cfg, k_max=256))
    assert hi.representation == "packed" and hi.collective == "psum_packed"
    scaleout.set_crossover_table({"density": 0.5})
    try:  # with a 50% crossover even k_max=256 (12.5% density) goes sparse
        both = scaleout.resolve_representation(
            dataclasses.replace(cfg, k_max=256))
        assert both.representation == "sparse"
    finally:
        scaleout.set_crossover_table(None)
    # non-auto configs pass through untouched
    assert scaleout.resolve_representation(lo) is lo


# ---------------------------------------------------------------------------
# the single-row rebaseline: only the named row changes, byte-identical rest
# ---------------------------------------------------------------------------


def _load_check_regression():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import importlib.util

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_regression.py")
    spec = importlib.util.spec_from_file_location("_cr_under_test", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _fake_artifacts():
    serve_row = lambda v: {
        rep: {"hbm_bytes_per_device": v, "collective_bytes_per_device": v / 2,
              "trials_per_s": 100.0}
        for rep in ("unpacked", "packed")
    } | {"hbm_ratio": 5.0}
    packed = {
        "config": {"mesh": "2x4", "reps": 2},
        "serve": {coll: serve_row(1000.0 * (i + 1)) for i, coll in
                  enumerate(("psum", "psum_packed", "rs_ag", "symbol"))}
        | {"psum_packed_wire_cut_unpacked": 2.4,
           "psum_packed_wire_cut_packed": 2.4,
           "prediction_identical": True},
        "classifier": {"packed": {"trials_per_s": 5000.0}},
    }
    sparse_art = {
        "config": {"mesh": "2x4", "reps": 2, "fast": True},
        "serve": {"prediction_identical": True},
        "grid": [],
        "crossover": {"per_dim": {}, "density": 0.01},
        "headline": {
            "dim": 1048576, "density": 0.001, "k_max": 2097,
            "sparse": {"collective_bytes_per_device": 600000.0,
                       "trials_per_s": 200.0},
            "packed": {"collective_bytes_per_device": 12000000.0,
                       "trials_per_s": 28.0},
            "speedup": 7.1,
        },
    }
    return packed, sparse_art


def test_rebaseline_row_rewrites_only_named_row(tmp_path):
    import copy
    import json

    cr = _load_check_regression()
    packed, sparse_art = _fake_artifacts()
    path = str(tmp_path / "baseline.json")
    cr.rebaseline(packed, path, sparse=sparse_art)
    before = open(path).read()
    old = json.loads(before)

    # refresh ONLY the sparse row from a changed sparse artifact
    sparse2 = copy.deepcopy(sparse_art)
    sparse2["crossover"]["density"] = 0.02
    sparse2["headline"]["sparse"]["trials_per_s"] = 300.0
    cr.rebaseline_row("sparse_crossover", packed, path, sparse=sparse2)
    after = open(path).read()
    new = json.loads(after)

    assert new["sparse_crossover"]["crossover_density"] == 0.02
    assert new["sparse_crossover"]["headline"]["sparse_trials_per_s"] == 30.0
    # every other top-level row is untouched
    for k in old:
        if k != "sparse_crossover":
            assert new[k] == old[k], k
    # ... and byte-identical outside the named section: splicing the fresh row
    # into the old dict and re-serializing reproduces the new file exactly
    expected = dict(old)
    expected["sparse_crossover"] = new["sparse_crossover"]
    assert after == json.dumps(expected, indent=1) + "\n"
    # an unknown row name fails loudly instead of silently no-opping
    with pytest.raises(SystemExit):
        cr.rebaseline_row("no_such_row", packed, path, sparse=sparse2)


def test_check_sparse_gate(tmp_path):
    import copy
    import json

    cr = _load_check_regression()
    packed, sparse_art = _fake_artifacts()
    path = str(tmp_path / "baseline.json")
    cr.rebaseline(packed, path, sparse=sparse_art)
    baseline = json.loads(open(path).read())
    assert cr.check_sparse(sparse_art, baseline) == []
    # a collapsed headline speedup or a lost identity must fail the gate
    bad = copy.deepcopy(sparse_art)
    bad["headline"]["speedup"] = 1.2
    assert any("speedup" in f for f in cr.check_sparse(bad, baseline))
    bad = copy.deepcopy(sparse_art)
    bad["serve"]["prediction_identical"] = False
    assert any("identical" in f for f in cr.check_sparse(bad, baseline))
    bad = copy.deepcopy(sparse_art)
    bad["crossover"]["density"] = 0.001
    assert any("crossover" in f for f in cr.check_sparse(bad, baseline))
