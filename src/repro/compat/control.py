"""Version-portable control-flow helpers.

``jax.lax.map`` grew its ``batch_size=`` kwarg (scan-of-vmap chunking) midway
through the 0.4.x line; older pins only have the pure sequential scan form.
``lax_map_batched`` uses the native kwarg when the runtime has it and otherwise
falls back to manual chunking: split the leading axis into full chunks of
``batch_size`` (scan over vmap) plus one vmapped remainder call — the same
evaluation strategy, identical results.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import version as _version


def lax_map_batched(f, xs, *, batch_size: int):
    """``jax.lax.map(f, xs, batch_size=batch_size)`` on every supported pin.

    Only the single-array / leading-axis form the repo uses is supported
    (xs: an array or pytree with a common leading axis).
    """
    # probed through the module (not a from-import) so tests can monkeypatch
    # the feature away and exercise the fallback on any pin
    if _version.has_lax_map_batch_size():
        return jax.lax.map(f, xs, batch_size=batch_size)
    leaves = jax.tree_util.tree_leaves(xs)
    n = leaves[0].shape[0]
    if n == 0 or batch_size <= 1:
        return jax.lax.map(f, xs)
    n_full = (n // batch_size) * batch_size
    parts = []
    if n_full:
        chunked = jax.tree_util.tree_map(
            lambda x: x[:n_full].reshape((n_full // batch_size, batch_size) + x.shape[1:]),
            xs,
        )
        _, ys = jax.lax.scan(lambda c, chunk: (c, jax.vmap(f)(chunk)), None, chunked)
        parts.append(
            jax.tree_util.tree_map(
                lambda y: y.reshape((n_full,) + y.shape[2:]), ys
            )
        )
    if n_full < n:
        rest = jax.tree_util.tree_map(lambda x: x[n_full:], xs)
        parts.append(jax.vmap(f)(rest))
    if len(parts) == 1:
        return parts[0]
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), parts[0], parts[1]
    )
