from repro.kernels.hamming.ops import (  # noqa: F401
    hamming_search,
    hamming_search_banked,
    hamming_topk_banked,
)
