"""Deterministic sharded synthetic data pipeline with O(1) skip-ahead resume.

Every batch is a pure function of (seed, step): `batch(step)` folds the step into
the PRNG key, so resuming from a checkpoint at step k needs no replay — the
pipeline state IS the step counter (stored in the checkpoint). Per-host sharding
slices the global batch by host index, giving identical global streams on any
mesh size (elastic restore).

The stream is a Zipf-distributed token process with short-range structure
(a Markov-ish blend of a repeated motif and fresh draws) so cross-entropy has
learnable signal for the examples/tests, unlike uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.7


class SyntheticLM:
    """Deterministic LM stream; `batch(step)` -> {'tokens','targets'} [B, S]."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self._local = cfg.global_batch // host_count
        ranks = jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32)
        p = ranks ** (-cfg.zipf_a)
        self._logits = jnp.log(p / jnp.sum(p))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        key = jax.random.fold_in(key, self.host_index)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = self._local, cfg.seq + 1
        fresh = jax.random.categorical(k1, self._logits, shape=(b, s))
        motif = jax.random.categorical(k2, self._logits, shape=(b, cfg.motif_len))
        tiled = jnp.tile(motif, (1, s // cfg.motif_len + 1))[:, :s]
        use_motif = jax.random.bernoulli(k3, cfg.motif_prob, (b, s))
        toks = jnp.where(use_motif, tiled, fresh).astype(jnp.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    # --- checkpointable state: just the step counter ---
    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])
