"""Quickstart: the paper's full pipeline in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

1. model the in-package wireless channel (the CST substitute);
2. jointly optimize TX phases for the OTA majority constellations;
3. bundle 3 query hypervectors over the air;
4. similarity-search 100 classes at each of 64 receivers.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import classifier, em, hypervector as hv, ota

key = jax.random.PRNGKey(0)

# 1. channel pre-characterization (deterministic given package geometry)
geom = em.PackageGeometry()
h = em.channel_matrix(geom, n_tx=3, n_rx=64)
n0 = ota.default_n0(h)

# 2. joint TX-phase optimization (exhaustive for M=3)
res = ota.optimize_phases_exhaustive(h, n0)
print(f"avg BER {float(res.avg_ber):.4f}  max {float(res.max_ber):.4f} "
      f"(paper: <0.01 avg, ~0.1 max)")

# 3. three encoders transmit simultaneously; every RX decodes its own copy
protos = classifier.make_codebook(key, classifier.HDCTaskConfig())
classes = jax.random.randint(jax.random.fold_in(key, 1), (3,), 0, 100)
queries = protos[classes]
decoded = ota.simulate_ota_bundle(key, queries, h, res.phase_idx, n0)  # [64, 512]

# 4. similarity search at each receiver
sims = jax.vmap(lambda q: hv.hamming_similarity(q, protos))(decoded)   # [64, 100]
pred = jnp.argmax(sims, -1)
hit = jnp.isin(pred, classes).mean()
print(f"sent classes {classes.tolist()}; top-1 lands in the sent set at "
      f"{float(hit)*100:.1f}% of receivers")
