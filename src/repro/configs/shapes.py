"""Assigned input-shape cells and their dry-run input builders.

Four cells per architecture (40 total):
  train_4k     seq 4,096   global_batch 256   -> loss_fn       (train step)
  prefill_32k  seq 32,768  global_batch 32    -> prefill_fn    (inference prefill)
  decode_32k   seq 32,768  global_batch 128   -> decode_fn     (one token, KV cache)
  long_500k    seq 524,288 global_batch 1     -> decode_fn     (sub-quadratic only)

`long_500k` runs only for architectures with a sub-quadratic / bounded-KV decode
path (cfg.subquadratic): falcon-mamba (SSM), zamba2 (SSD + single shared-attn
cache), gemma3 (5:1 sliding:global, kv=1), mixtral (pure SWA ring cache). The
skip list and rationale live in DESIGN.md. Enc-dec (whisper) runs decode cells in
the structural sense (self-cache length as assigned; the real model caps at 448).

`input_specs` returns (kind, kwargs of ShapeDtypeStruct, logical-axes pytree)
— zero allocation, mirroring the model's batch contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


CELLS = {
    "train_4k": Cell("train_4k", 4096, 256, "train"),
    "prefill_32k": Cell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Cell("decode_32k", 32768, 128, "decode"),
    "long_500k": Cell("long_500k", 524288, 1, "decode"),
}

# VLM cells: vision-prefix length (stub patch embeddings), grid h*w = s_vis
VLM_VISION = {"train_4k": (256, (16, 16)), "prefill_32k": (1024, (32, 32)),
              "decode_32k": (1024, (32, 32)), "long_500k": (1024, (32, 32))}


def cell_applicable(cfg: ModelConfig, cell: Cell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention — no sub-quadratic path (see DESIGN.md)"
    return True, ""


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, cell: Cell):
    """Returns (kind, batch_kwargs_shapes, batch_kwargs_axes)."""
    b, s = cell.batch, cell.seq
    tok_axes = ("batch", "seq")
    if cell.kind in ("train", "prefill"):
        if cfg.kind == "vlm":
            s_vis, _grid = VLM_VISION[cell.name]
            s_txt = s - s_vis
            shapes = {
                "tokens": _tok(b, s_txt),
                "patch_embeds": jax.ShapeDtypeStruct((b, s_vis, cfg.d_model), cfg.dtype),
                "positions": jax.ShapeDtypeStruct((b, s, 3), jnp.int32),
            }
            axes = {
                "tokens": tok_axes,
                "patch_embeds": ("batch", "seq", "embed"),
                "positions": ("batch", "seq", None),
            }
        elif cfg.kind == "encdec":
            shapes = {
                "frames": jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), cfg.dtype),
                "tokens": _tok(b, s),
            }
            axes = {"frames": ("batch", "seq", "embed"), "tokens": tok_axes}
        else:
            shapes = {"tokens": _tok(b, s)}
            axes = {"tokens": tok_axes}
        if cell.kind == "train":
            shapes["targets"] = jax.ShapeDtypeStruct(shapes["tokens"].shape, jnp.int32)
            axes["targets"] = tok_axes
        return cell.kind, shapes, axes

    # decode: token [B], pos scalar, cache of length seq
    shapes = {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
              "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"token": ("batch",), "pos": ()}
    return "decode", shapes, axes
