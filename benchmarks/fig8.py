"""Fig. 8: per-receiver BER in the 3-TX / 64-RX system (+ the Eq. 1 vs
per-symbol analytic gap — our beyond-paper refinement of the error model —
and the Monte-Carlo empirical BER of the `phy` symbol channel, the tier the
serve path can now run end-to-end)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save
from repro import phy
from repro.core import em, hypervector as hvlib, ota


def empirical_ber_per_rx(state: phy.ChannelState, key, dim: int = 8192) -> np.ndarray:
    """Monte-Carlo per-RX bit-flip rate of the physical symbol channel.

    Random M-TX bit draws -> combo psum equivalent (`phy.combo_index`) ->
    per-RX constellation + AWGN + decision decode (`phy.awgn_decide`) vs the
    true majority — the same vectorized decode the serve's ``symbol`` tier
    runs, measured against `ota.decision_metrics`'s analytic predictions.
    """
    kq, kn = jax.random.split(key)
    queries = hvlib.random_hv(kq, state.m_tx, dim)
    majq = hvlib.majority(queries)
    combo = phy.combo_index(queries, axis=0)                     # [dim]
    def one(i):
        sym = state.symbols[i][combo]
        return phy.awgn_decide(jax.random.fold_in(kn, i), sym,
                               state.c0[i], state.c1[i], state.n0)
    decoded = jax.vmap(one)(jnp.arange(state.n_rx))              # [N, dim]
    return np.asarray(jnp.mean((decoded != majq[None]).astype(jnp.float32), axis=1))


def run(quiet: bool = False) -> dict:
    h = em.channel_matrix(em.PackageGeometry(), 3, 64)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_exhaustive(h, n0)
    maj = ota.majority_labels(3)
    ber_sym, _ = ota.decision_metrics(res.symbols, maj, n0, method="symbol")
    ber = np.asarray(res.ber_per_rx)
    state = phy.state_from_ota(res, h)
    emp = empirical_ber_per_rx(state, jax.random.PRNGKey(8))
    out = {
        "ber_per_rx_eq1": ber.tolist(),
        "ber_per_rx_symbol": np.asarray(ber_sym).tolist(),
        "ber_per_rx_empirical": emp.tolist(),
        "snr_per_rx_db": np.asarray(em.snr_per_rx(h, n0)).tolist(),
        "avg_eq1": float(ber.mean()),
        "max_eq1": float(ber.max()),
        "avg_symbol": float(np.asarray(ber_sym).mean()),
        "avg_empirical": float(emp.mean()),
        "phases": np.asarray(res.phase_idx).tolist(),
        "n0": float(n0),
    }
    if not quiet:
        print(f"avg BER (Eq.1) {out['avg_eq1']:.4f}  max {out['max_eq1']:.4f}  "
              f"(paper: avg <0.01, max ~0.1)")
        print(f"avg BER (per-symbol, tight) {out['avg_symbol']:.4f}")
        print(f"avg BER (Monte-Carlo symbol channel) {out['avg_empirical']:.4f}")
        print(f"RXs below 1e-5: {(ber < 1e-5).sum()}/64")
    save("fig8", out)
    return out


if __name__ == "__main__":
    run()
