"""Public op: bipolar associative matmul with padding + backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels import common
from repro.kernels.assoc_matmul.kernel import assoc_matmul_pallas
from repro.kernels.assoc_matmul.ref import assoc_matmul_ref


def assoc_matmul(
    q: jax.Array,
    protos: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Bipolar dots between {0,1} queries [.., d] and prototypes [C, d] -> [.., C].

    Row/col (B, C) zero padding is sliced away; the contraction-dim padding is
    masked inside the kernel (see kernel.py).
    """
    if interpret is None:
        interpret = common.default_interpret()
    lead = q.shape[:-1]
    d = q.shape[-1]
    qf = q.reshape((-1, d))
    b, c = qf.shape[0], protos.shape[0]
    if not use_kernel:
        return assoc_matmul_ref(qf, protos).reshape(lead + (c,))
    bk_eff = min(bk, ((d + 127) // 128) * 128)
    qp = common.pad_dim(common.pad_dim(qf, 0, bm), 1, bk_eff)
    pp = common.pad_dim(common.pad_dim(protos, 0, bn), 1, bk_eff)
    out = assoc_matmul_pallas(
        qp, pp, bm=bm, bn=bn, bk=bk_eff, k_actual=d, interpret=interpret
    )
    return out[:b, :c].reshape(lead + (c,))
