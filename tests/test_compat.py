"""repro.compat unit tests: both branches of every shim on a single JAX pin.

The shims probe the live jax module at call time, so presence/absence of each
new-API symbol is monkeypatched here and both code paths run regardless of
which JAX version the host actually provides.
"""
import contextlib
import enum

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import version as compat_version
from repro.compat.xla import normalize_cost_result


class _FakeAxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"


# ---------------------------------------------------------------------------
# version / feature table
# ---------------------------------------------------------------------------

def test_feature_table_keys_and_types():
    feats = compat.detect_features()
    assert set(feats) >= {
        "axis_type", "make_mesh", "make_mesh_axis_types", "set_mesh",
        "get_abstract_mesh", "top_level_shard_map", "dict_cost_analysis",
    }
    assert all(isinstance(v, bool) for v in feats.values())
    assert set(compat.VERSION_FEATURES) == set(feats)
    assert "jax" in compat.describe()


def test_detect_features_tracks_monkeypatching(monkeypatch):
    monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType, raising=False)
    assert compat.detect_features()["axis_type"]
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert not compat.detect_features()["axis_type"]


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

def test_make_mesh_new_api_branch(monkeypatch):
    """When AxisType exists and make_mesh accepts axis_types, both are used."""
    calls = {}

    def fake_make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        calls["args"] = (axis_shapes, axis_names, axis_types, devices)
        return "fake-mesh"

    monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType, raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    out = compat.make_mesh((1, 1), ("data", "model"))
    assert out == "fake-mesh"
    shapes, names, types, _ = calls["args"]
    assert shapes == (1, 1) and names == ("data", "model")
    assert types == (_FakeAxisType.Auto, _FakeAxisType.Auto)


def test_make_mesh_legacy_branch(monkeypatch):
    """Without AxisType, a real usable Mesh comes back (the 0.4.x path)."""
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert tuple(mesh.devices.shape) == (1, 1)


def test_make_mesh_mesh_utils_fallback(monkeypatch):
    """Oldest path: no jax.make_mesh at all -> mesh_utils.create_device_mesh."""
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == 1


def test_make_mesh_shape_mismatch_raises():
    with pytest.raises(ValueError):
        compat.make_mesh((1, 1), ("data",))


# ---------------------------------------------------------------------------
# set_mesh / current_mesh
# ---------------------------------------------------------------------------

def test_set_mesh_prefers_jax_set_mesh(monkeypatch):
    seen = {}

    @contextlib.contextmanager
    def fake_set_mesh(mesh):
        seen["mesh"] = mesh
        yield mesh

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        pass
    assert seen["mesh"] is mesh


def test_set_mesh_fallback_installs_ambient_mesh(monkeypatch):
    """Fallback path (Mesh as its own context manager) really installs the
    ambient mesh that current_mesh() then reports."""
    monkeypatch.delattr(jax, "set_mesh", raising=False)
    monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
    monkeypatch.delattr(jax.sharding, "get_abstract_mesh", raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert compat.current_mesh() is None
    with compat.set_mesh(mesh):
        got = compat.current_mesh()
        assert got is not None
        assert tuple(got.axis_names) == ("data", "model")
        assert compat.current_mesh_axis_sizes() == {"data": 1, "model": 1}
    assert compat.current_mesh() is None
    assert compat.current_mesh_axis_sizes() is None


def test_current_mesh_prefers_get_abstract_mesh(monkeypatch):
    class FakeMesh:
        empty = False
        axis_names = ("a",)
        axis_sizes = (4,)

    monkeypatch.setattr(
        jax.sharding, "get_abstract_mesh", lambda: FakeMesh(), raising=False
    )
    assert compat.current_mesh_axis_sizes() == {"a": 4}
    # empty abstract mesh -> None, never an exception
    FakeMesh.empty = True
    assert compat.current_mesh() is None
    monkeypatch.setattr(jax.sharding, "get_abstract_mesh", lambda: None, raising=False)
    assert compat.current_mesh() is None


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def test_shard_map_new_api_branch(monkeypatch):
    calls = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, axis_names, check_vma):
        calls.update(axis_names=axis_names, check_vma=check_vma)
        return f

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    fn = compat.shard_map(
        lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(),
        axis_names={"data"}, check_vma=False,
    )
    assert fn(3) == 3
    assert calls["axis_names"] == {"data"} and calls["check_vma"] is False


def test_shard_map_legacy_branch_translates_kwargs(monkeypatch):
    import jax.experimental.shard_map as sm_mod

    calls = {}

    def fake_legacy(f, *, mesh, in_specs, out_specs, check_rep, auto):
        calls.update(check_rep=check_rep, auto=auto)
        return f

    monkeypatch.delattr(jax, "shard_map", raising=False)
    monkeypatch.setattr(sm_mod, "shard_map", fake_legacy)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    compat.shard_map(
        lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(),
        axis_names={"data"}, check_vma=False,
    )
    # manual axes -> complement becomes auto; check_vma -> check_rep
    assert calls == {"check_rep": False, "auto": frozenset({"model"})}


def test_shard_map_executes_on_this_pin():
    """No monkeypatching: whatever branch this JAX takes must actually run."""
    mesh = compat.make_mesh((1,), ("d",))
    fn = compat.shard_map(
        lambda x: jax.lax.psum(x, "d"), mesh=mesh,
        in_specs=P("d"), out_specs=P(), axis_names={"d"}, check_vma=False,
    )
    out = jax.jit(fn)(jnp.ones((1, 3)))
    assert out.shape == (1, 3)


def test_shard_map_unknown_axis_raises():
    mesh = compat.make_mesh((1,), ("d",))
    with pytest.raises(ValueError):
        compat.shard_map(
            lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(),
            axis_names={"nope"},
        )


# ---------------------------------------------------------------------------
# normalized_cost_analysis
# ---------------------------------------------------------------------------

class _FakeCompiled:
    def __init__(self, result):
        self._result = result

    def cost_analysis(self):
        return self._result


def test_cost_analysis_dict_passthrough():
    d = {"flops": 10.0, "bytes accessed": 4.0}
    out = compat.normalized_cost_analysis(_FakeCompiled(d))
    assert out == d
    assert out is not d  # defensive copy


def test_cost_analysis_single_element_list():
    out = compat.normalized_cost_analysis(
        _FakeCompiled([{"flops": 10.0, "bytes accessed": 4.0}])
    )
    assert out == {"flops": 10.0, "bytes accessed": 4.0}


def test_cost_analysis_multi_program_list_sums_numeric():
    out = compat.normalized_cost_analysis(
        _FakeCompiled([{"flops": 10.0, "label": "a"}, {"flops": 5.0, "extra": 1.0}])
    )
    assert out["flops"] == 15.0
    assert out["label"] == "a" and out["extra"] == 1.0


def test_cost_analysis_none_and_empty():
    assert compat.normalized_cost_analysis(_FakeCompiled(None)) == {}
    assert compat.normalized_cost_analysis(_FakeCompiled([])) == {}
    with pytest.raises(TypeError):
        normalize_cost_result("bogus")


def test_cost_analysis_real_compiled_is_dict():
    comp = jax.jit(lambda a: a * 2).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)
    ).compile()
    out = compat.normalized_cost_analysis(comp)
    assert isinstance(out, dict)
    assert "bytes accessed" in out


# ---------------------------------------------------------------------------
# pallas compiler params
# ---------------------------------------------------------------------------

def test_tpu_compiler_params_builds_on_this_pin():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary")
    )
    assert params.dimension_semantics == ("parallel", "arbitrary")


def test_tpu_compiler_params_prefers_new_name(monkeypatch):
    from jax.experimental.pallas import tpu as pltpu

    class NewParams:
        def __init__(self, **kw):
            self.kw = kw

    monkeypatch.setattr(pltpu, "CompilerParams", NewParams, raising=False)
    out = compat.tpu_compiler_params(dimension_semantics=("parallel",))
    assert isinstance(out, NewParams)


# ---------------------------------------------------------------------------
# lax.map batch_size chunking
# ---------------------------------------------------------------------------

def test_lax_map_batched_native_branch():
    """When the runtime's jax.lax.map has batch_size=, results match plain map."""
    xs = jnp.arange(10, dtype=jnp.float32)
    f = lambda x: x * 2 + 1
    out = compat.lax_map_batched(f, xs, batch_size=4)
    assert jnp.array_equal(out, jax.lax.map(f, xs))


@pytest.mark.parametrize("n,batch_size", [(10, 4), (8, 4), (3, 8), (7, 1), (5, 5)])
def test_lax_map_batched_fallback_branch(monkeypatch, n, batch_size):
    """With the kwarg monkeypatched away, the manual scan-of-vmap chunking must
    return identical results for full chunks, remainders, and degenerate sizes."""
    from repro.compat import version as v

    monkeypatch.setattr(v, "has_lax_map_batch_size", lambda: False)
    xs = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)
    f = lambda x: jnp.sum(x) + x
    out = compat.lax_map_batched(f, xs, batch_size=batch_size)
    assert jnp.array_equal(out, jax.lax.map(f, xs))


def test_lax_map_batched_fallback_used_by_score_assignments(monkeypatch):
    """ota._score_assignments runs (and returns identical scores) on pins
    without the batch_size kwarg."""
    import numpy as np

    from repro.compat import version as v
    from repro.core import em, ota

    h = em.channel_matrix(em.PackageGeometry(), 3, 4)
    n0 = ota.default_n0(h)
    maj = ota.majority_labels(3)
    pairs = ota.ordered_phase_pairs()
    batch = jnp.stack([jnp.stack([pairs[i], pairs[i + 1], pairs[i + 2]])
                       for i in range(5)])
    want = np.asarray(ota._score_assignments(h, batch, maj, n0, "centroid"))
    monkeypatch.setattr(v, "has_lax_map_batch_size", lambda: False)
    ota._score_assignments.clear_cache()
    got = np.asarray(ota._score_assignments(h, batch, maj, n0, "centroid"))
    ota._score_assignments.clear_cache()
    np.testing.assert_allclose(got, want, rtol=1e-6)
