"""Minimal offline stand-in for the `hypothesis` subset this suite uses.

The CI container has no network access and `hypothesis` is not baked in, so
the property tests fall back to this module (see the try/except import in
tests/test_hdc_core.py and tests/test_kernels.py). Implements only what the
suite needs — `given`, `settings`, `strategies.integers/booleans/lists` and
`Strategy.map` — with *seeded, deterministic* example generation: a test's
examples are a pure function of its name and the example index, so failures
reproduce across runs and machines.

This is NOT a shrinking/property-testing engine: no shrinking, no database,
no assume(). When the real `hypothesis` is importable it is always preferred.
"""
from __future__ import annotations

import random
import zlib
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 20
_SALT = int("0x5eed", 16)  # fixed corpus salt; bump to rotate every test's examples


class Strategy:
    """A deterministic value generator: draw(rng) -> value."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str = "strategy"):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)), f"{self.label}.map")

    def __repr__(self) -> str:
        return f"<propcheck {self.label}>"


def _integers(min_value: int = 0, max_value: int = 2**31 - 1) -> Strategy:
    if min_value > max_value:
        raise ValueError(f"integers: min {min_value} > max {max_value}")
    return Strategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def _booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, "booleans()")


def _lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    if not isinstance(elements, Strategy):
        raise TypeError("lists() needs an element Strategy")

    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return Strategy(draw, f"lists({elements.label}, {min_size}, {max_size})")


class _StrategiesNamespace:
    """Mirrors `hypothesis.strategies` for the subset the suite imports as `st`."""

    integers = staticmethod(_integers)
    booleans = staticmethod(_booleans)
    lists = staticmethod(_lists)


strategies = _StrategiesNamespace()


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator factory; only max_examples matters here (deadline and other
    hypothesis knobs are accepted and ignored so call sites stay identical)."""

    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: Strategy):
    """Run the test once per example with values drawn from the strategies.

    The RNG for example i of test `f` is seeded with adler32(f.qualname)+i:
    deterministic across runs, processes and machines, independent of
    execution order. On failure the drawn values are attached to the error.
    """
    if not arg_strategies or not all(isinstance(s, Strategy) for s in arg_strategies):
        raise TypeError("given() requires Strategy positional arguments")

    def deco(fn):
        base = zlib.adler32(f"{fn.__module__}.{fn.__qualname__}".encode()) ^ _SALT

        # Deliberately no functools.wraps: the runner must present a zero-arg
        # signature so pytest doesn't mistake strategy parameters for fixtures.
        def runner():
            # @settings may sit above @given (attr lands on runner) or below
            # it (attr lands on fn) — real hypothesis accepts either order.
            n = getattr(
                runner, "_propcheck_max_examples",
                getattr(fn, "_propcheck_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            for i in range(n):
                rng = random.Random((base << 20) + i)
                values = [s.draw(rng) for s in arg_strategies]
                try:
                    fn(*values)
                except Exception as e:
                    raise AssertionError(
                        f"propcheck falsified {fn.__name__} on example {i}/{n}: "
                        f"args={values!r}"
                    ) from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner._propcheck_inner = fn
        return runner

    return deco
