# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real single CPU
# device. Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/test_distributed.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
