"""Model-stack correctness: attention/SSM oracles + per-arch smoke + consistency.

* flash attention (fwd + custom-VJP bwd) vs naive softmax attention;
* chunked selective scan / SSD vs naive sequential recurrences;
* every assigned arch (reduced config): one train step finite, shapes right;
* decode(prefill(x), next) == prefill(x + next) for every arch (cache, rope,
  ring-buffer and M-RoPE consistency).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model, init_params, layers
from repro.models import mamba as mamba_lib
from repro.models import vlm as vlm_lib

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, causal=True, window=-1):
    b, s, h, d = q.shape
    kh = k.shape[2]
    kf = layers._expand_kv(k, h // kh)
    vf = layers._expand_kv(v, h // kh)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    ok = kp <= qp if causal else jnp.ones((s, s), bool)
    if window > 0:
        ok &= (qp - kp) < window
    sc = jnp.where(ok[None, None], sc, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vf)


@pytest.mark.parametrize(
    "s,h,kh,d,win,causal",
    [(256, 4, 2, 32, -1, True), (256, 4, 1, 32, 64, True),
     (128, 6, 6, 16, -1, False), (512, 2, 2, 64, 128, True)],
)
def test_flash_attention_fwd_bwd(s, h, kh, d, win, causal):
    ks = jax.random.split(jax.random.fold_in(KEY, s + h), 4)
    q = jax.random.normal(ks[0], (2, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, s, kh, d), jnp.float32)
    ct = jax.random.normal(ks[3], (2, s, h, d), jnp.float32)
    out = layers.flash_attention(q, k, v, causal=causal, window=win, block_q=64, block_k=64)
    ref = naive_attention(q, k, v, causal, win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    gf = jax.grad(lambda *a: jnp.sum(layers.flash_attention(
        a[0], a[1], a[2], causal=causal, window=win, block_q=64, block_k=64) * ct),
        argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(lambda *a: jnp.sum(naive_attention(*a, causal, win) * ct),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-4)


def test_decode_attention_matches_naive_last_row():
    s, h, kh, d = 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, s, kh, d), jnp.float32)
    full = naive_attention(q, k, v, causal=True)
    got = layers.decode_attention(
        q[:, -1:], k, v, jnp.arange(s), jnp.int32(s - 1)
    )
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


def test_selective_scan_matches_ref():
    b, s, din, n = 2, 64, 8, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)))
    A = -jnp.exp(jax.random.normal(ks[2], (din, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((din,))
    h0 = jnp.zeros((b, din, n))
    y1, h1 = mamba_lib.selective_scan(u, dt, A, B, C, D, h0, chunk=16)
    y2, h2 = mamba_lib.selective_scan_ref(u, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_selective_scan_odd_length_padding():
    b, s, din, n = 1, 37, 4, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)))
    A = -jnp.exp(jax.random.normal(ks[2], (din, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((din,))
    h0 = jnp.zeros((b, din, n))
    y1, h1 = mamba_lib.selective_scan(u, dt, A, B, C, D, h0, chunk=16)
    y2, h2 = mamba_lib.selective_scan_ref(u, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_ssd_matches_ref():
    b, s, nh, p, g, n = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, nh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    D = jnp.ones((nh,))
    h0 = jnp.zeros((b, nh, n, p))
    y1, h1 = mamba_lib.ssd(x, dt, A, B, C, D, h0, chunk=16)
    y2, h2 = mamba_lib.ssd_ref(x, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_mrope_text_degenerates_to_1d():
    """Text tokens (t=h=w) under M-RoPE equal plain RoPE."""
    b, s, h, d = 1, 16, 2, 32
    x = jax.random.normal(KEY, (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3 = jnp.stack([pos, pos, pos], -1)
    r1 = layers.apply_rope(x, pos, 10000.0)
    r3 = layers.apply_rope(x, pos3, 10000.0, sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r3), atol=1e-6)


# ---------------------------------------------------------------------------
# per-arch smoke + decode/prefill consistency
# ---------------------------------------------------------------------------

def _batch_for(cfg, B, S, with_targets=True, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if with_targets:
        batch["targets"] = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    if cfg.kind == "vlm":
        sv = 16
        batch["patch_embeds"] = 0.02 * jax.random.normal(key, (B, sv, cfg.d_model), cfg.dtype)
        batch["positions"] = vlm_lib.default_positions(B, sv, S, (4, 4))
    if cfg.kind == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    batch = _batch_for(cfg, 2, 128)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_decode_matches_prefill(arch):
    cfg = configs.get_smoke(arch)
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    pre = _batch_for(cfg, B, S, with_targets=False)
    pre["tokens"] = toks[:, :S]
    pre_full = dict(pre, tokens=toks)
    pos_dec = S
    if cfg.kind == "vlm":
        pre_full["positions"] = vlm_lib.default_positions(B, 16, S + 1, (4, 4))
        pos_dec = S + 16
    lg1, cache = jax.jit(functools.partial(model.prefill_fn, pad_to=pos_dec + 4))(params, pre)
    lg_step, _ = jax.jit(model.decode_fn)(params, cache, toks[:, S], jnp.int32(pos_dec))
    lg2, _ = jax.jit(model.prefill_fn)(params, pre_full)
    err = float(jnp.max(jnp.abs(lg_step - lg2)))
    assert err < 5e-3, (arch, err)


def test_ring_buffer_cache_beyond_window():
    """Pure-SWA arch (mixtral smoke): decode far past the window stays exact."""
    cfg = configs.get_smoke("mixtral_8x22b")  # window 64, ring cache
    model = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), model.specs)
    B, S, EXTRA = 1, 96, 3  # S > window 64 -> ring wraps
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + EXTRA), 0, cfg.vocab)
    _, cache = jax.jit(model.prefill_fn)(params, {"tokens": toks[:, :S]})
    assert cache["k"].shape[2] == 64  # ring capacity == window
    lg = None
    for i in range(EXTRA):
        lg, cache = jax.jit(model.decode_fn)(params, cache, toks[:, S + i], jnp.int32(S + i))
    lg_ref, _ = jax.jit(model.prefill_fn)(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(lg - lg_ref)))
    assert err < 5e-3, err
