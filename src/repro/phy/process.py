"""Time-varying PHY: channel *processes* + online re-characterization.

PR 5 made the OTA link a swappable `Channel` tier fed by a static
`ChannelState` snapshot — the paper's methodology, where the package is
characterized once (CST + MATLAB) and frozen. Real millimeter-wave in-package
links drift: LO phase noise random-walks each receiver's effective rotation,
thermal gradients re-scale path gains block-wise, and off-mesh aggressors leak
energy into the cavity. This module upgrades the snapshot to a *process*:

    pstate = process.init(chan_state)          # wrap the characterization
    pstate = process.step(key, pstate)         # evolve one serve step

`ProcessState` carries BOTH sides of a drifting link:

* channel truth — ``chan.h`` / ``chan.symbols`` are re-derived every step from
  the evolving degrees of freedom (``phase``, ``fade``, interferer tone), and
  ``chan.ber`` is recomputed as the TRUE flip rate of nearest-centroid
  decoding the live constellation against the receiver's (possibly stale)
  ``c0/c1`` (`ota.per_symbol_ber`). The serve step keeps consuming plain
  `ChannelState`, so every tier (``bsc`` flips at the live BER, ``symbol``
  decodes the live field) degrades physically instead of silently.
* receiver knowledge — ``c0/c1/valid`` stay whatever the last
  characterization fit; ``est`` is the receiver's own EW-MA flip-rate
  estimate from ``guard_dims`` per-step guard-symbol decodes (known majority
  truth, same `ota.awgn_decide` as the data path). When ``est`` leaves the
  analytic acceptance band (`em.analytic_ber_band` over `em.snr_per_rx`),
  `recharacterize` re-fits the decision regions from the live constellation —
  the M-step of the 2-means characterization with known labels, i.e. the
  online EM re-fit.

RNG discipline: the per-step, per-row key is

    fold_in(fold_in(process_key, t), rx_base + row)

with NO data-position fold — the process state replicates over the data/pod
mesh axes and must evolve identically on every data shard, which is what
makes (1, 1)- and (2, 4)-mesh rollouts bit-reproducible from one key.
Within a row, sub-streams are suffix folds (`_EVOLVE`/`_INJECT`/`_GUARD`) so
adding an observer never perturbs the physics stream.

`StaticProcess.step` is a literal identity on ``chan`` (only ``t``
advances): serving through it is prediction-bit-identical to the PR 5/PR 6
static-state paths on every tier x collective x representation combination.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import em, ota
from repro.phy.channel import ChannelState, state_shape_structs, state_spec

# per-row RNG sub-streams (suffix folds off the per-row key)
_EVOLVE = 0
_INJECT = 1
_GUARD = 2


@dataclasses.dataclass(frozen=True)
class ProcessState:
    """One pytree carrying channel truth + receiver knowledge, [N] RX leading.

    ``chan`` is the live `ChannelState` the serve step consumes (truth-side
    ``h``/``symbols``/``ber``, knowledge-side ``c0/c1/valid``). The remaining
    leaves are the process's degrees of freedom and the monitor/controller
    surface; every RX-leading leaf shards over the ``model`` mesh axis
    exactly like ``chan`` (see `pstate_spec`), ``t`` replicates.
    """

    chan: ChannelState    # live channel state (what the serve tiers consume)
    base_h: jax.Array     # [N, M] c64 — characterized anchor channel (t = 0)
    phase: jax.Array      # [N, M] f32 — accumulated drift rotation of base_h
    fade: jax.Array       # [N] f32 — block-fading amplitude scale (1 nominal)
    igain: jax.Array      # [N] c64 — off-mesh interferer coupling (0 unused)
    est: jax.Array        # [N] f32 — EW-MA empirical flip-rate estimate
    quarantine: jax.Array  # [N] bool — controller vote-exclusion mask
    t: jax.Array          # [] i32 — process time (serve steps since init)

    @property
    def n_rx(self) -> int:
        return self.chan.n_rx

    @property
    def m_tx(self) -> int:
        return self.chan.m_tx


jax.tree_util.register_pytree_node(
    ProcessState,
    lambda p: ((p.chan, p.base_h, p.phase, p.fade, p.igain, p.est,
                p.quarantine, p.t), None),
    lambda _, leaves: ProcessState(*leaves),
)


def pstate_spec(rx_axis: str | None = "model") -> ProcessState:
    """PartitionSpec tree for a ProcessState (RX-leading over `rx_axis`)."""
    from jax.sharding import PartitionSpec as P

    rx = P(rx_axis)
    return ProcessState(chan=state_spec(rx_axis), base_h=P(rx_axis, None),
                        phase=P(rx_axis, None), fade=rx, igain=rx, est=rx,
                        quarantine=rx, t=P())


def pstate_shape_structs(n_rx: int, m_tx: int) -> ProcessState:
    """ShapeDtypeStruct tree matching `ChannelProcess.init` output — for AOT
    lowering (the dry-run `serve_adaptive` cells) without the EM pipeline."""
    s = jax.ShapeDtypeStruct
    return ProcessState(
        chan=state_shape_structs(n_rx, m_tx),
        base_h=s((n_rx, m_tx), jnp.complex64),
        phase=s((n_rx, m_tx), jnp.float32),
        fade=s((n_rx,), jnp.float32),
        igain=s((n_rx,), jnp.complex64),
        est=s((n_rx,), jnp.float32),
        quarantine=s((n_rx,), bool),
        t=s((), jnp.int32),
    )


def row_keys(key: jax.Array, t: jax.Array, rx_base, n: int) -> jax.Array:
    """The single fold_in schedule: fold_in(fold_in(key, t), rx_base + row).

    Shared by every per-row evolution law (channel processes here, the
    `repro.faults` models) — no data-position fold, so state replicated over
    the data/pod axes evolves identically on every shard and rollouts are
    mesh-placement invariant."""
    kt = jax.random.fold_in(key, t)
    rows = rx_base + jnp.arange(n)
    return jax.vmap(lambda r: jax.random.fold_in(kt, r))(rows)


_row_keys = row_keys  # historical private name


# ---------------------------------------------------------------------------
# the ChannelProcess interface + tiers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelProcess:
    """One stochastic evolution law for the OTA link between serve steps.

    Subclasses override `_evolve` (advance the drift degrees of freedom) and
    optionally `_inject` (add an external field to the constellation); the
    `step` template then re-derives the truth-side symbols via
    `ota.rx_constellations`, recomputes the TRUE per-RX flip rate against the
    receiver's current centroids (`ota.per_symbol_ber`) and updates the
    guard-symbol monitor. Rows with ``valid=False`` carry no physics: their
    analytic BER and estimate pass through unchanged (the serve tiers already
    fall back to the BSC abstraction there).

    ``guard_dims`` extra dimensions per step feed the empirical flip-rate
    monitor (EW-MA weight ``alpha``); they ride the same combo wire as the
    data, so adaptation costs ``guard_dims`` int32 psum lanes per step
    (4 * guard_dims bytes/hop — 256 B at the default 64, vs a d = 2048 data
    payload of 8 KB: ~3% wire overhead). Set ``guard_dims=0`` to disable.
    """

    name = "?"
    guard_dims: int = 64
    alpha: float = 0.25

    def init(self, state: ChannelState) -> ProcessState:
        n, m = state.n_rx, state.m_tx
        return ProcessState(
            chan=state,
            base_h=state.h,
            phase=jnp.zeros((n, m), jnp.float32),
            fade=jnp.ones((n,), jnp.float32),
            igain=jnp.zeros((n,), jnp.complex64),
            est=jnp.asarray(state.ber, jnp.float32),
            quarantine=jnp.zeros((n,), bool),
            t=jnp.zeros((), jnp.int32),
        )

    # --- subclass hooks ---------------------------------------------------
    def _evolve(self, kr, p: ProcessState):
        """Advance (phase [N, M], fade [N]) one step; kr = per-row keys."""
        return p.phase, p.fade

    def _inject(self, kr, y, p: ProcessState):
        """Add an external field to the live constellation y [N, B]."""
        return y

    # --- the template -----------------------------------------------------
    def step(self, key: jax.Array, p: ProcessState, *, rx_base=0) -> ProcessState:
        n, m = p.chan.ber.shape[0], p.chan.m_tx
        kr = _row_keys(key, p.t, rx_base, n)
        phase, fade = self._evolve(kr, p)
        h = (p.base_h * jnp.exp(1j * phase) * fade[:, None]).astype(jnp.complex64)
        y = ota.rx_constellations(h, p.chan.phase_idx)
        y = self._inject(kr, y, p).astype(jnp.complex64)
        maj = ota.majority_labels(m)
        ber_true = ota.per_symbol_ber(y, p.chan.c0, p.chan.c1, maj, p.chan.n0)
        ber = jnp.where(p.chan.valid, ber_true, p.chan.ber).astype(jnp.float32)
        chan = dataclasses.replace(p.chan, h=h, symbols=y, ber=ber)
        est = self._observe(kr, chan, p.est)
        return dataclasses.replace(p, chan=chan, phase=phase, fade=fade,
                                   est=est, t=p.t + 1)

    def _observe(self, kr, chan: ChannelState, est: jax.Array) -> jax.Array:
        """Guard-symbol monitor: EW-MA of empirical decode-vs-truth flips."""
        if self.guard_dims <= 0:
            return est
        maj = ota.majority_labels(chan.m_tx)
        b = chan.symbols.shape[-1]

        def one(k, sym_row, c0, c1):
            kg, kn = jax.random.split(jax.random.fold_in(k, _GUARD))
            combos = jax.random.randint(kg, (self.guard_dims,), 0, b)
            dec = ota.awgn_decide(kn, sym_row[combos], c0, c1, chan.n0)
            return jnp.mean((dec != maj[combos]).astype(jnp.float32))

        rate = jax.vmap(one)(kr, chan.symbols, chan.c0, chan.c1)
        rate = jnp.where(chan.valid, rate, est)  # no physics to observe
        return ((1.0 - self.alpha) * est + self.alpha * rate).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class StaticProcess(ChannelProcess):
    """Frozen channel — the paper's once-and-forever characterization.

    `step` is a literal identity on every leaf except ``t``: zero extra
    compute, and serving through it stays prediction-bit-identical to the
    static-`ChannelState` paths on all tiers (the bsc tier keeps flipping at
    the characterized Eq.-1 BER, not a per-symbol recomputation)."""

    name = "static"
    guard_dims: int = 0

    def step(self, key, p, *, rx_base=0):
        return dataclasses.replace(p, t=p.t + 1)


@dataclasses.dataclass(frozen=True)
class PhaseDriftProcess(ChannelProcess):
    """LO phase noise: random-walk rotation of each receiver's channel row.

    ``sigma`` rad/step of COMMON per-RX rotation (the receiver's local
    oscillator drifting against the TX reference) — a rigid rotation of the
    whole constellation, so stale centroids degrade toward (and past) chance
    while `recharacterize` recovers the fit EXACTLY. ``tx_sigma`` adds
    independent per-(RX, TX)-pair jitter: that distorts the constellation
    geometry itself, the component no re-fit can undo (kept 0 in the
    closed-loop scenarios; exposed for worst-case ablations)."""

    name = "phase_drift"
    sigma: float = 0.08
    tx_sigma: float = 0.0

    def _evolve(self, kr, p):
        m = p.chan.m_tx

        def one(k):
            k_rx, k_tx = jax.random.split(jax.random.fold_in(k, _EVOLVE))
            d = self.sigma * jax.random.normal(k_rx, ())
            dtx = self.tx_sigma * jax.random.normal(k_tx, (m,))
            return jnp.broadcast_to(d + dtx, (m,))

        return p.phase + jax.vmap(one)(kr), p.fade


@dataclasses.dataclass(frozen=True)
class BlockFadingProcess(ChannelProcess):
    """Block fading: per-RX log-normal amplitude scale, redrawn every
    ``block`` steps (thermal/mechanical gradients re-scaling path gains on a
    timescale much slower than a serve step). ``sigma_db`` is the std of the
    20*log10 amplitude scale; fades compress the constellation toward the
    origin, raising the true flip rate without moving the stale boundary."""

    name = "block_fading"
    sigma_db: float = 4.0
    block: int = 8

    def _evolve(self, kr, p):
        def one(k):
            kf = jax.random.fold_in(k, _EVOLVE)
            return 10.0 ** (self.sigma_db * jax.random.normal(kf, ()) / 20.0)

        new_fade = jax.vmap(one)(kr).astype(jnp.float32)
        redraw = (p.t % self.block) == 0
        return p.phase, jnp.where(redraw, new_fade, p.fade)


@dataclasses.dataclass(frozen=True)
class InterfererProcess(ChannelProcess):
    """Off-mesh interferer: a CW aggressor outside the package leaking a tone
    into the cavity. `init` computes the per-RX coupling from the `em` ray
    model at ``pos`` (mm, may lie outside the package) and calibrates it so
    ``amp`` is in units of the mean link amplitude; each step injects
    ``amp * igain * exp(j * omega * t)`` into EVERY combo symbol of the
    field — a rigid translation of each constellation whose phase rotates at
    ``omega`` rad/step, so stale decision boundaries sweep through the
    symbol clusters while a re-fit tracks the offset exactly."""

    name = "interferer"
    amp: float = 0.6
    omega: float = 0.7
    pos: tuple = (15.0, -6.0)
    geom: em.PackageGeometry | None = None

    def init(self, state: ChannelState) -> ProcessState:
        p = super().init(state)
        geom = self.geom if self.geom is not None else em.PackageGeometry()
        rxp = em.rx_positions(geom, state.n_rx)
        d = jnp.linalg.norm(rxp - jnp.asarray(self.pos, jnp.float32)[None],
                            axis=-1)
        g = em._ray_gain(d, geom)
        scale = jnp.mean(jnp.abs(state.h)) / jnp.maximum(
            jnp.mean(jnp.abs(g)), 1e-12)
        return dataclasses.replace(p, igain=(g * scale).astype(jnp.complex64))

    def _inject(self, kr, y, p):
        tone = jnp.exp(1j * self.omega * p.t.astype(jnp.float32))
        return y + self.amp * p.igain[:, None] * tone


# ---------------------------------------------------------------------------
# online re-characterization + controller helpers
# ---------------------------------------------------------------------------

def recharacterize(pstate: ProcessState, mask=None) -> ProcessState:
    """EM re-fit of the decision regions from the LIVE constellation.

    Per masked RX: ``c0, c1 = ota.majority_centroids(symbols, maj)`` — the
    M-step of the balanced 2-means characterization with known majority
    labels — then BER/validity recomputed per-symbol against the new
    boundary (`ota.decision_metrics(method="symbol")`). The estimator is
    re-seeded at the refit BER so the monitor restarts in-band. ``mask``
    selects rows to re-fit (default: all); unmasked rows pass through
    untouched, including their RNG-free knowledge side."""
    chan = pstate.chan
    maj = ota.majority_labels(chan.m_tx)
    c0n, c1n = ota.majority_centroids(chan.symbols, maj)
    bern, validn = ota.decision_metrics(chan.symbols, maj, chan.n0,
                                        method="symbol")
    if mask is None:
        mask = jnp.ones(chan.ber.shape, bool)
    mask = jnp.asarray(mask, bool)
    chan2 = dataclasses.replace(
        chan,
        c0=jnp.where(mask, c0n, chan.c0).astype(jnp.complex64),
        c1=jnp.where(mask, c1n, chan.c1).astype(jnp.complex64),
        ber=jnp.where(mask, bern, chan.ber).astype(jnp.float32),
        valid=jnp.where(mask, validn, chan.valid),
    )
    est = jnp.where(mask, chan2.ber, pstate.est).astype(jnp.float32)
    return dataclasses.replace(pstate, chan=chan2, est=est)


def set_quarantine(pstate: ProcessState, mask) -> ProcessState:
    """Replace the controller's vote-exclusion mask ([N] bool)."""
    return dataclasses.replace(pstate,
                               quarantine=jnp.asarray(mask, bool))


def monitor_band(pstate: ProcessState, **kw) -> jax.Array:
    """Acceptance ceiling for ``est`` from the CURRENT receiver knowledge.

    `em.analytic_ber_band` over the live channel and the last-characterized
    BER. Evaluate at init and again after each `recharacterize` (when
    ``chan.ber`` IS the refit value); holding it fixed between refits is what
    makes drift — not noise — trip the re-fit."""
    chan = pstate.chan
    return em.analytic_ber_band(chan.h, chan.n0, chan.ber, **kw)


# ---------------------------------------------------------------------------
# rollouts (scan-carried; one compile for N steps)
# ---------------------------------------------------------------------------

def rollout(process: ChannelProcess, pstate: ProcessState, key: jax.Array,
            n_steps: int, *, rx_base=0):
    """Evolve `n_steps` under `process`: (final, stacked ProcessState [T]).

    A `lax.scan` with the ProcessState as carry — the pytree-stability and
    one-compile property the serve integration relies on. `step` folds
    ``pstate.t`` into the key itself, so ONE key drives the whole schedule
    and resuming from any intermediate state replays identically."""
    def body(p, _):
        p2 = process.step(key, p, rx_base=rx_base)
        return p2, p2

    return jax.lax.scan(body, pstate, None, length=n_steps)


def adaptive_rollout(process: ChannelProcess, pstate: ProcessState,
                     key: jax.Array, n_steps: int, *, band=None,
                     band_kwargs: dict | None = None,
                     patience: int = 2, rx_base=0):
    """Closed-loop rollout: drift + monitor + banded EM re-fit, in-graph.

    Each step, rows whose estimate has sat above the analytic band for
    ``patience`` consecutive steps (hysteresis — shot noise on the guard
    block must not flap the fit) are re-characterized and the band is
    re-evaluated from the refit state. Returns (final, stacked ProcessState
    [T], refit mask [T, N] bool — the action trace). This is the in-graph
    twin of the serving-layer `LinkController` (which acts host-side at the
    step barrier); the classifier robustness sweeps use this one."""
    band_kwargs = band_kwargs or {}
    if band is None:
        band = monitor_band(pstate, **band_kwargs)
    n = pstate.chan.ber.shape[0]

    def body(carry, _):
        p, over, bnd = carry
        p = process.step(key, p, rx_base=rx_base)
        over = jnp.where(p.est > bnd, over + 1, 0)
        trip = (over >= patience) & p.chan.valid

        def refit(pp):
            pp2 = recharacterize(pp, trip)
            # re-evaluate the band ONLY for the refit rows (their chan.ber is
            # now the refit value); other rows' chan.ber is the live drifting
            # truth — folding it in would ratchet their band up with the
            # drift and the monitor would never trip again
            return pp2, jnp.where(trip, monitor_band(pp2, **band_kwargs), bnd)

        p, bnd = jax.lax.cond(jnp.any(trip), refit, lambda pp: (pp, bnd), p)
        over = jnp.where(trip, 0, over)
        return (p, over, bnd), (p, trip)

    init = (pstate, jnp.zeros((n,), jnp.int32), jnp.asarray(band, jnp.float32))
    (pf, _, _), (traj, trips) = jax.lax.scan(body, init, None, length=n_steps)
    return pf, traj, trips


# ---------------------------------------------------------------------------
# registry (mirrors `channel.register_channel`)
# ---------------------------------------------------------------------------

PROCESSES: dict[str, type] = {}


def register_process(cls: type, *, override: bool = False) -> type:
    """Register a `ChannelProcess` subclass under ``cls.name`` for
    `get_process`. Out-of-tree drift models plug in the same way the channel
    tiers do; usable as a class decorator."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name or name == "?":
        raise ValueError(f"process must define a non-empty .name, got {name!r}")
    if not callable(getattr(cls, "step", None)):
        raise TypeError(f"process {name!r} does not implement step()")
    if name in PROCESSES and not override:
        raise ValueError(
            f"channel process {name!r} already registered; pass override=True "
            "to replace it"
        )
    PROCESSES[name] = cls
    return cls


for _p in (StaticProcess, PhaseDriftProcess, BlockFadingProcess,
           InterfererProcess):
    register_process(_p)
del _p


def get_process(name: str, **kwargs) -> ChannelProcess:
    """Instantiate a registered process by name (kwargs -> constructor)."""
    try:
        cls = PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown channel process {name!r}; available: {sorted(PROCESSES)}"
        ) from None
    return cls(**kwargs)
