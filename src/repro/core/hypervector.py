"""Binary hyperdimensional-computing algebra.

Hypervectors (HVs) are d-dimensional pseudo-random binary vectors (d >= 512 in this
paper; classically d ~ 10,000). We keep two representations:

* **unpacked**: ``uint8`` arrays of {0, 1} — convenient for algebra and majority.
* **packed**: ``uint32`` arrays of d/32 words — used by the Pallas Hamming kernel,
  mirroring how an IMC macro would store a row.

All ops are pure jnp and jit-friendly. Bipolar view {-1,+1} = 2*hv-1 is used where a
matmul (MXU) formulation is preferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

WORD = 32


def random_hv(key: jax.Array, num: int, dim: int) -> jax.Array:
    """`num` i.i.d. random binary hypervectors of dimension `dim` (uint8 {0,1})."""
    return jax.random.bernoulli(key, 0.5, (num, dim)).astype(jnp.uint8)


def bind(a: jax.Array, b: jax.Array) -> jax.Array:
    """Binding = elementwise XOR. Involutive, similarity-preserving."""
    return jnp.bitwise_xor(a, b)


def permute(hv: jax.Array, shift: int | jax.Array) -> jax.Array:
    """Cyclic permutation rho^shift along the last (dimension) axis."""
    return jnp.roll(hv, shift, axis=-1)


def permute_batch(hvs: jax.Array, shifts: jax.Array) -> jax.Array:
    """Apply per-row cyclic shifts: hvs [M, d], shifts [M] -> [M, d].

    Used for the paper's *permuted bundling*: transmitter m applies rho^m so each
    TX has a distinguishable signature and the shared codebook decorrelates.
    """
    d = hvs.shape[-1]
    idx = (jnp.arange(d)[None, :] - shifts[:, None]) % d
    return jnp.take_along_axis(hvs, idx.astype(jnp.int32), axis=-1)


def majority(hvs: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """Bit-wise logical majority (the HDC *bundling* op) over axis 0.

    hvs: [M, ..., d] uint8 in {0,1}.  For even M, ties are broken with a random
    hypervector (the standard HDC convention); pass `key` in that case.
    """
    m = hvs.shape[0]
    counts = jnp.sum(hvs.astype(jnp.int32), axis=0)
    if m % 2 == 1:
        return (counts * 2 > m).astype(jnp.uint8)
    if key is None:
        # deterministic tie-break: ties -> 0 (documents parity; tests use odd M)
        return (counts * 2 > m).astype(jnp.uint8)
    tie = jax.random.bernoulli(key, 0.5, counts.shape)
    return jnp.where(counts * 2 == m, tie, counts * 2 > m).astype(jnp.uint8)


def hamming_similarity(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Normalized similarity in [0,1]: 1 - hamming/d.

    q: [..., d]; protos: [C, d] -> [..., C].
    Implemented as a bipolar dot product so that on TPU it maps to the MXU —
    the direct analogue of the IMC crossbar MVM of the paper (Fig. 2).
    """
    d = q.shape[-1]
    qb = (2.0 * q.astype(jnp.float32) - 1.0)
    pb = (2.0 * protos.astype(jnp.float32) - 1.0)
    dots = qb @ pb.T  # in [-d, d]; = d - 2*hamming
    return (dots + d) / (2.0 * d)


def flip_bits(key: jax.Array, hv: jax.Array, ber: jax.Array | float) -> jax.Array:
    """Binary symmetric channel: flip each bit independently w.p. `ber`.

    This is how the paper injects the wireless OTA error figures into the HDC
    chain ("errors ... are modeled as uncorrelated bit flips over the query
    hypervectors").
    """
    flips = jax.random.bernoulli(key, ber, hv.shape)
    return jnp.bitwise_xor(hv, flips.astype(jnp.uint8))


def flip_bits_per_rx(key: jax.Array, hv: jax.Array, ber_per_rx: jax.Array) -> jax.Array:
    """Per-receiver BSC: hv [..., d] broadcast against ber_per_rx [N] -> [N, ..., d]."""
    n = ber_per_rx.shape[0]
    p = ber_per_rx.reshape((n,) + (1,) * hv.ndim)
    flips = jax.random.bernoulli(key, p, (n,) + hv.shape)
    return jnp.bitwise_xor(hv[None], flips.astype(jnp.uint8))


# ---------------------------------------------------------------------------
# packed representation
# ---------------------------------------------------------------------------

def pack(hv: jax.Array) -> jax.Array:
    """Pack uint8 {0,1} [..., d] -> uint32 [..., d//32] (little-endian bit order)."""
    d = hv.shape[-1]
    assert d % WORD == 0, f"dim {d} must be a multiple of {WORD}"
    w = hv.reshape(hv.shape[:-1] + (d // WORD, WORD)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(w * weights, axis=-1).astype(jnp.uint32)


def unpack(packed: jax.Array, dim: int) -> jax.Array:
    """Inverse of `pack`."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(packed.shape[:-1] + (dim,)).astype(jnp.uint8)


def hamming_distance_packed(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Packed-word Hamming distance via XOR + popcount.

    q: [..., W] uint32, protos: [C, W] uint32 -> int32 [..., C].
    The pure-jnp oracle for kernels/hamming.
    """
    x = jnp.bitwise_xor(q[..., None, :], protos)  # [..., C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
