"""Pure-jnp oracle for the majority-bundling kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def majority_bundle_ref(hvs: jax.Array) -> jax.Array:
    """Bit-wise logical majority over axis 0.

    hvs: [M, B, d] uint8 in {0,1} -> [B, d] uint8.  Even-M ties resolve to 0
    (the deterministic convention; the stochastic tie-break lives at the
    `core.hypervector.majority` level, not in the kernel).
    """
    m = hvs.shape[0]
    counts = jnp.sum(hvs.astype(jnp.int32), axis=0)
    return (counts * 2 > m).astype(jnp.uint8)
