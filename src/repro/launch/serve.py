"""Serving launcher: batched generation with the static-batch engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --batch 4 --prompt-len 64 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro import configs
    from repro.models import get_model, init_params
    from repro.serving import Engine, ServeConfig

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.specs)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.kind == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
    if cfg.kind == "vlm":
        from repro.models import vlm as vlm_lib
        sv = 16
        batch["patch_embeds"] = 0.02 * jax.random.normal(key, (args.batch, sv, cfg.d_model), cfg.dtype)
        batch["positions"] = vlm_lib.default_positions(args.batch, sv, args.prompt_len, (4, 4))

    eng = Engine(model, ServeConfig(max_new=args.max_new, temperature=args.temperature))
    t0 = time.time()
    toks = eng.generate(params, batch, key)
    t1 = time.time()
    toks2 = eng.generate(params, batch, key)  # warm
    t2 = time.time()
    print(f"generated {toks.shape} tokens; compile+run {t1-t0:.2f}s, warm {t2-t1:.3f}s "
          f"({args.batch*args.max_new/(t2-t1):.1f} tok/s)")
    print("sample:", jnp.asarray(toks2[0][:12]).tolist())


if __name__ == "__main__":
    main()
