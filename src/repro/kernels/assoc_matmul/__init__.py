from repro.kernels.assoc_matmul.ops import assoc_matmul  # noqa: F401
