from repro.serving.engine import ServeConfig, Engine  # noqa: F401
