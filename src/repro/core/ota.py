"""Over-the-air (OTA) majority computation — constellation engineering.

The paper's central mechanism (Sec. IV): M transmitters emit simultaneously, each
encoding its bit in one of two phases drawn from an 8-phase (45-degree) codebook.
Each receiver r observes the superposition

    y_r(b) = sum_m H[r, m] * exp(j * phi_m(b_m)),          b in {0,1}^M

and decodes the *logical majority* maj(b) by a pre-computed binary decision region:
balanced K-means (K=2) over the 2^M constellation points, constrained to coincide
with the majority labelling.  TX phases are optimized *jointly across all receivers*
to minimize the mean BER, with the BPSK-style error model of Eq. (1):

    BER = 0.5 * erfc(0.5 * d_c / sqrt(N0))

(d_c = centroid distance; complex AWGN with per-component variance N0/2).

Everything here is pure JAX and fully vectorized: the exhaustive search for M = 3
evaluates all gauge-reduced phase assignments (7 * 56^(M-1)) against all receivers at
once; a coordinate-descent search covers M > 3.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

N_PHASES = 8  # 45-degree discretization (Sec. IV)


# ---------------------------------------------------------------------------
# enumeration helpers
# ---------------------------------------------------------------------------

def bit_combos(m: int) -> jnp.ndarray:
    """All 2^m TX bit combinations, [B, m] uint8 (LSB = TX 0)."""
    b = jnp.arange(2 ** m, dtype=jnp.uint32)
    return ((b[:, None] >> jnp.arange(m, dtype=jnp.uint32)) & 1).astype(jnp.uint8)


def majority_labels(m: int) -> jnp.ndarray:
    """maj(b) for every bit combination, [B] uint8 (m odd -> no ties)."""
    combos = bit_combos(m)
    return (2 * jnp.sum(combos.astype(jnp.int32), axis=-1) > m).astype(jnp.uint8)


def phase_codebook() -> jnp.ndarray:
    return 2.0 * jnp.pi * jnp.arange(N_PHASES) / N_PHASES


def ordered_phase_pairs() -> jnp.ndarray:
    """All ordered pairs (i0, i1), i0 != i1, of codebook indices: [56, 2]."""
    i = jnp.arange(N_PHASES)
    a, b = jnp.meshgrid(i, i, indexing="ij")
    mask = a.reshape(-1) != b.reshape(-1)
    pairs = jnp.stack([a.reshape(-1), b.reshape(-1)], axis=-1)
    return pairs[mask]


# ---------------------------------------------------------------------------
# constellation synthesis + decision metrics
# ---------------------------------------------------------------------------

def rx_constellations(h: jnp.ndarray, phase_idx: jnp.ndarray) -> jnp.ndarray:
    """Received superposition symbols for every RX and bit combo.

    h: [N, M] complex channel; phase_idx: [M, 2] int codebook indices (bit 0/1).
    Returns y: [N, B] complex64.
    """
    m = h.shape[1]
    phases = phase_codebook()
    combos = bit_combos(m)  # [B, M]
    tx_phase = phases[phase_idx]  # [M, 2]
    sel = jnp.where(combos.astype(bool), tx_phase[None, :, 1], tx_phase[None, :, 0])  # [B, M]
    tx_sym = jnp.exp(1j * sel)  # [B, M]
    return jnp.einsum("nm,bm->nb", h, tx_sym)


def majority_centroids(
    y: jnp.ndarray, maj: jnp.ndarray, mask: jnp.ndarray | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Centroids (c0, c1) of the two majority decision regions.

    y: [..., B] symbols (B = 2^M bit combos); maj: [B] labels. The single
    definition of the decision-region centers shared by `decision_metrics`,
    `simulate_ota_bundle`, and the `phy` symbol-channel decode — they must
    agree or the analytic BER describes a different decoder than the one the
    serve path runs.

    ``mask`` [B] bool restricts the fit to a sub-constellation: only masked
    combos contribute to either centroid. Used by the erasure-aware refit
    (`repro.faults.recenter_state`) where dead encoders make part of the
    constellation unreachable — the live combos are then labelled by the
    LIVE majority, so ``maj`` and ``mask`` travel together. ``mask=None``
    (or all-True) is exactly the historical all-combo fit.
    """
    m0 = (maj == 0)
    m1 = ~m0
    if mask is not None:
        mask = jnp.asarray(mask, bool)
        m0 = m0 & mask
        m1 = m1 & mask
    c0 = jnp.sum(jnp.where(m0, y, 0.0), axis=-1) / jnp.sum(m0)
    c1 = jnp.sum(jnp.where(m1, y, 0.0), axis=-1) / jnp.sum(m1)
    return c0, c1


def per_symbol_ber(
    y: jnp.ndarray, c0: jnp.ndarray, c1: jnp.ndarray, maj: jnp.ndarray, n0
) -> jnp.ndarray:
    """Per-RX BER of nearest-centroid decoding `y` against GIVEN centroids.

    y: [..., B] symbols; c0/c1: [...] centroids (broadcast over the symbol
    axis); maj: [B] labels.  Each symbol's error probability is the Gaussian
    tail beyond its signed margin to the decision boundary (the perpendicular
    bisector of c0/c1), averaged over the B equiprobable combos.

    Unlike `decision_metrics` the centroids are an argument, NOT refit from
    `y` — this is the TRUE flip rate of a receiver whose decision regions may
    be stale: the channel-truth side of a drifting link (`repro.phy.process`
    evolves `y` while the receiver keeps yesterday's c0/c1).  With
    ``c0, c1 = majority_centroids(y, maj)`` it equals the method="symbol"
    branch of `decision_metrics` exactly.  A symbol on the WRONG side of the
    boundary contributes > 0.5 — a rigidly rotated constellation decoded
    against stale centroids degrades toward (and past) chance, which is what
    makes re-characterization measurable.
    """
    axis = (c1 - c0)
    axis = axis / jnp.maximum(jnp.abs(axis), 1e-12)
    mid = 0.5 * (c0 + c1)
    t = jnp.real((y - mid[..., None]) * jnp.conj(axis[..., None]))
    t_correct = jnp.where(maj.astype(bool), t, -t)  # signed margin, own side +
    return jnp.mean(0.5 * jax.scipy.special.erfc(t_correct / jnp.sqrt(n0)), axis=-1)


def decision_metrics(
    y: jnp.ndarray, maj: jnp.ndarray, n0: float, method: str = "centroid"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-RX BER + validity of the majority decision regions.

    y: [..., N, B] symbols; maj: [B] labels.  Returns (ber [..., N], valid [..., N]).

    * validity: the balanced majority partition must be a 2-means solution — every
      symbol strictly closer to its own centroid (paper: "we make sure that each
      cluster contains four symbols and the combination of TX phases allows the
      mapping to the majority result"). Invalid regions decode at chance: BER 0.5.
    * method "centroid": Eq. (1) on the centroid distance (paper-faithful).
    * method "symbol": refined per-symbol error — distance of each symbol to the
      decision boundary (perpendicular bisector of the centroids); tighter when the
      constellation is asymmetric. Used as a beyond-paper refinement.
    """
    m0 = (maj == 0)
    m1 = ~m0
    c0, c1 = majority_centroids(y, maj)
    d0 = jnp.abs(y - c0[..., None])
    d1 = jnp.abs(y - c1[..., None])
    own_closer = jnp.where(m0, d0 < d1, d1 < d0)
    valid = jnp.all(own_closer, axis=-1)

    if method == "centroid":
        d_c = jnp.abs(c1 - c0)
        ber = 0.5 * jax.scipy.special.erfc(0.5 * d_c / jnp.sqrt(n0))
    elif method == "symbol":
        ber = per_symbol_ber(y, c0, c1, maj, n0)
    else:
        raise ValueError(f"unknown method {method!r}")
    return jnp.where(valid, ber, 0.5), valid


# ---------------------------------------------------------------------------
# joint TX-phase optimization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OTAResult:
    phase_idx: jnp.ndarray   # [M, 2] chosen codebook indices
    ber_per_rx: jnp.ndarray  # [N]
    valid_per_rx: jnp.ndarray
    symbols: jnp.ndarray     # [N, B] constellation of the winner
    n0: float

    @property
    def avg_ber(self) -> jnp.ndarray:
        return jnp.mean(self.ber_per_rx)

    @property
    def max_ber(self) -> jnp.ndarray:
        return jnp.max(self.ber_per_rx)


@functools.partial(jax.jit, static_argnames=("method",))
def _score_assignments(h, phase_idx_batch, maj, n0, method):
    """phase_idx_batch: [A, M, 2] -> mean-over-RX BER [A].

    Jitted once per (shapes, method): the coordinate-descent search calls this
    from a sweeps x TX Python loop with a fixed [56, M, 2] candidate shape, so
    without the jit every iteration re-traced the whole scoring program and the
    M > 3 search paid compile time per step.
    """
    def one(pi):
        y = rx_constellations(h, pi)
        ber, _ = decision_metrics(y, maj, n0, method)
        return jnp.mean(ber)
    # batch_size= chunking only exists on newer 0.4.x pins — compat falls back
    # to a manual scan-of-vmap with identical results.
    return compat.lax_map_batched(one, phase_idx_batch, batch_size=256)


def optimize_phases_exhaustive(
    h: jnp.ndarray, n0: float, method: str = "centroid", chunk: int = 4096
) -> OTAResult:
    """Exhaustive gauge-reduced joint search (feasible for M <= 3).

    Gauge reduction: a global rotation of all TX phases by a codebook step rotates
    every constellation rigidly and leaves all distances (hence BERs) unchanged, so
    TX 0's bit-0 phase is pinned to index 0.
    """
    n, m = h.shape
    pairs = ordered_phase_pairs()  # [56, 2]
    maj = majority_labels(m)

    tx0 = jnp.stack([jnp.zeros(N_PHASES - 1, jnp.int32), jnp.arange(1, N_PHASES)], -1)  # [7, 2]
    spaces = [tx0] + [pairs] * (m - 1)
    sizes = [s.shape[0] for s in spaces]
    total = int(jnp.prod(jnp.array(sizes)))

    def assignment_at(flat_idx):
        idxs = []
        rem = flat_idx
        for s in reversed(sizes):
            idxs.append(rem % s)
            rem = rem // s
        idxs = list(reversed(idxs))
        return jnp.stack([spaces[k][idxs[k]] for k in range(m)], axis=0)  # [M, 2]

    # the running best stays ON DEVICE: `sc < best` / `int(flat[i])` here would
    # force a host round-trip per 4096-candidate chunk, serializing the async
    # dispatch of the whole search. One implicit sync when the winner is used.
    best_score = jnp.full((), jnp.inf, jnp.float32)
    best_flat = jnp.zeros((), jnp.int32)
    for start in range(0, total, chunk):
        flat = jnp.arange(start, min(start + chunk, total))
        batch = jax.vmap(assignment_at)(flat)
        scores = _score_assignments(h, batch, maj, n0, method)
        i = jnp.argmin(scores)
        better = scores[i] < best_score
        best_flat = jnp.where(better, flat[i].astype(jnp.int32), best_flat)
        best_score = jnp.where(better, scores[i], best_score)

    phase_idx = assignment_at(best_flat)
    y = rx_constellations(h, phase_idx)
    ber, valid = decision_metrics(y, maj, n0, method)
    return OTAResult(phase_idx=phase_idx, ber_per_rx=ber, valid_per_rx=valid, symbols=y, n0=n0)


def optimize_phases_coordinate(
    h: jnp.ndarray,
    n0: float,
    key: jax.Array,
    sweeps: int = 4,
    method: str = "centroid",
) -> OTAResult:
    """Coordinate-descent joint search for arbitrary M (used for M > 3).

    One TX's phase pair is optimized at a time (56 candidates) holding the others
    fixed; a few sweeps converge since each step can only lower the objective.
    """
    n, m = h.shape
    pairs = ordered_phase_pairs()
    maj = majority_labels(m)

    init = jax.random.randint(key, (m, 2), 0, N_PHASES)
    # ensure distinct phases per TX
    init = init.at[:, 1].set((init[:, 0] + 1 + init[:, 1] % (N_PHASES - 1)) % N_PHASES)
    phase_idx = init

    def score(pi):
        y = rx_constellations(h, pi)
        ber, _ = decision_metrics(y, maj, n0, method)
        return jnp.mean(ber)

    for _ in range(sweeps):
        for tx in range(m):
            cand = jnp.repeat(phase_idx[None], pairs.shape[0], axis=0)
            cand = cand.at[:, tx].set(pairs)
            scores = _score_assignments(h, cand, maj, n0, method)
            phase_idx = cand[jnp.argmin(scores)]

    y = rx_constellations(h, phase_idx)
    ber, valid = decision_metrics(y, maj, n0, method)
    return OTAResult(phase_idx=phase_idx, ber_per_rx=ber, valid_per_rx=valid, symbols=y, n0=n0)


# ---------------------------------------------------------------------------
# end-to-end OTA transmission (empirical cross-check of Eq. 1)
# ---------------------------------------------------------------------------

def awgn_decide(
    key: jax.Array, sym: jnp.ndarray, c0: jnp.ndarray, c1: jnp.ndarray, n0
) -> jnp.ndarray:
    """Physical receiver decode: complex AWGN + binary decision regions.

    sym: [...] complex noiseless received symbols; c0/c1 broadcastable
    majority-region centroids (`majority_centroids`). Complex noise with
    per-component variance n0/2 (Eq. 1's error model), then nearest-centroid
    decision. Returns uint8 bits. The ONE decode definition shared by the
    host-level `simulate_ota_bundle`, the batched classifier channel and the
    in-graph serve tier (re-exported as `phy.awgn_decide`) — they must agree
    or the analytic BER describes a different decoder than the one served.
    """
    kr, ki = jax.random.split(key)
    noise = jnp.sqrt(jnp.asarray(n0, jnp.float32) / 2.0) * (
        jax.random.normal(kr, sym.shape) + 1j * jax.random.normal(ki, sym.shape)
    )
    r = sym + noise
    return (jnp.abs(r - c1) < jnp.abs(r - c0)).astype(jnp.uint8)


def simulate_ota_bundle(
    key: jax.Array,
    queries: jnp.ndarray,   # [M, d] uint8 — the M hypervectors to bundle
    h: jnp.ndarray,         # [N, M] channel
    phase_idx: jnp.ndarray, # [M, 2]
    n0: float,
) -> jnp.ndarray:
    """Physically simulate the OTA majority: per dimension, all TXs transmit their
    bit simultaneously; each RX adds AWGN and decodes via its decision regions.

    Returns decoded [N, d] uint8 — each receiver's (noisy) view of maj(queries),
    ready to drive its local similarity search. This is the paper's Fig. 3b dataflow.
    """
    m, d = queries.shape
    n = h.shape[0]
    maj = majority_labels(m)
    y = rx_constellations(h, phase_idx)  # [N, B]
    c0, c1 = majority_centroids(y, maj)  # [N] each

    combo = jnp.sum(queries.astype(jnp.int32) * (2 ** jnp.arange(m))[:, None], axis=0)  # [d]
    sym = y[:, combo]  # [N, d] noiseless received symbols
    return awgn_decide(key, sym, c0[:, None], c1[:, None], n0)


def default_n0(h: jnp.ndarray, snr_db: float = 7.0) -> float:
    """Noise density yielding a given mean per-link SNR — calibration knob.

    The paper transmits at 0 dBm and lands at avg BER ~1e-2 / max ~0.1 over 64 RX
    (Fig. 8); with our parametric cavity channel the same operating point is hit at
    ~7 dB mean SNR (avg BER 0.010, max 0.04, half the RXs below 1e-5).
    """
    p_rx = float(jnp.mean(jnp.abs(h) ** 2))
    return p_rx / (10.0 ** (snr_db / 10.0))
