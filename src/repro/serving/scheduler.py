"""Continuous-batching request scheduler: submit/poll queue + age-fair
admission over a slot-ring engine.

``SlotScheduler`` is the backend-agnostic half: it owns the slot free-list,
the per-prompt-shape FIFO buckets, the completion table, and the step loop
(advance in-flight admissions → fill free slots → one multi-slot engine step →
collect finished slots). Backends specialize the admission and collection
hooks: the LM ``Scheduler`` admits via (optionally chunked) prefill and
finishes slots on EOS / ``max_new``; the HDC scheduler
(``repro.serving.hdc.HDCScheduler``) admits query batches into tenant slots
and finishes every running slot each step (one banked similarity launch
answers all of them).

Admission is age-fair: each free slot takes the globally oldest pending
request — re-evaluated per slot — rather than draining the oldest request's
whole bucket first. Same-shape requests still share one compiled prefill per
bucket, but a sustained stream of long prompts can no longer starve a short
prompt that arrived in between (the bucket-drain policy kept picking the long
bucket because its head stayed oldest while the drained entries were
refilled behind it).

Eviction is step-granular: a finished slot is freed immediately and refilled
on the next admission pass while the remaining slots keep going — no drain
barrier, no recompile.

Slot-leak guard: a request that never finishes (a decode loop that never hits
EOS under a huge ``max_new``, or a backend bug) used to pin its slot forever —
``run`` would spin until its wall-clock timeout raised with the slot still
held. ``max_slot_steps`` bounds the steps any single admission may consume;
an expired slot is force-evicted (freed + ``engine.on_evict``), and its
request is requeued at the head of its bucket up to ``max_requeues`` times
before being failed with an ``"evicted"`` completion — the queue always
drains.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import ChunkedPrefill, ContinuousEngine, _prompt_sig


@dataclasses.dataclass
class Request:
    rid: int
    batch: dict                  # B=1 model inputs incl. 'tokens' [1, S]
    prompt_len: int
    max_new: int
    key: Any
    t_submit: float


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list[int]            # generated tokens (incl. the final EOS, if any)
    finish_reason: str           # "length" | "eos"
    prompt_len: int
    t_submit: float
    t_admit: float
    t_finish: float

    @property
    def latency(self) -> float:
        """Submit-to-finish wall time (includes queueing)."""
        return self.t_finish - self.t_submit


class SlotScheduler:
    """Backend-agnostic queue + slot bookkeeping over a ``SlotRingEngine``.

    Subclasses implement:

    * ``_start_admission(req, slot) -> list[Completion]`` — begin serving
      ``req`` on ``slot``: either fully admit (register it in ``running``,
      possibly finishing immediately) or park an in-flight multi-step
      admission in ``self.admitting[slot]``;
    * ``_advance_admissions() -> list[Completion]`` — make one unit of
      progress on every in-flight admission (default: none exist);
    * ``_collect(emitted) -> list[Completion]`` — consume one engine step's
      per-slot emissions, finishing and freeing slots as the backend dictates;
    * ``_step_params()`` — the params pytree handed to ``engine.step``
      (default: the ``params`` given at construction).

    A backend whose admissions are cheap scatters (HDC) may instead override
    ``_admit_free_slots`` wholesale to fill every free slot in one batched
    engine call.
    """

    def __init__(self, engine, params, clock: Callable[[], float] = time.monotonic,
                 *, max_slot_steps: int | None = None, max_requeues: int = 1):
        if max_slot_steps is not None and max_slot_steps < 1:
            raise ValueError("max_slot_steps must be >= 1")
        self.engine = engine
        self.params = params
        self.clock = clock
        self.state = engine.init_state()
        self.free: list[int] = list(range(engine.num_slots))
        # slot -> backend-defined running record (LM: (request, tokens, t_admit))
        self.running: dict[int, Any] = {}
        # slot -> backend-defined in-flight admission (LM: (request, ChunkedPrefill))
        self.admitting: dict[int, Any] = {}
        self.buckets: dict[Any, collections.deque] = collections.defaultdict(
            collections.deque
        )
        self.results: dict[int, Completion] = {}
        self.steps = 0
        self._next_rid = 0
        self.max_slot_steps = max_slot_steps
        self.max_requeues = max_requeues
        self._slot_steps: dict[int, int] = {}   # slot -> steps consumed in-flight
        self._requeues: dict[int, int] = {}     # rid -> deadline evictions so far

    # -- queue ---------------------------------------------------------------

    def poll(self, rid: int) -> Completion | None:
        return self.results.get(rid)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.buckets.values())

    @property
    def active(self) -> int:
        return len(self.running) + len(self.admitting)

    # -- admission / eviction ------------------------------------------------

    def _pop_oldest(self) -> Any | None:
        """Pop the globally oldest pending request across all buckets."""
        live = [(q[0].t_submit, q[0].rid, s) for s, q in self.buckets.items() if q]
        if not live:
            return None
        return self.buckets[min(live)[2]].popleft()

    def _admit_free_slots(self) -> list[Completion]:
        finished = []
        while self.free:
            # age-fair: re-pick the globally oldest request for EACH free slot
            req = self._pop_oldest()
            if req is None:
                break
            slot = self.free.pop(0)
            finished.extend(self._start_admission(req, slot))
        return finished

    # -- backend hooks --------------------------------------------------------

    def _start_admission(self, req, slot: int) -> list[Completion]:
        raise NotImplementedError

    def _advance_admissions(self) -> list[Completion]:
        return []

    def _collect(self, emitted) -> list[Completion]:
        raise NotImplementedError

    def _step_params(self):
        return self.params

    def _bucket_key(self, req) -> Any:
        """Bucket a (re)queued request lands in — backends with shape buckets
        override (the LM scheduler keys on the prompt signature)."""
        return 0

    def _fail_eviction(self, slot: int, record) -> Completion:
        """Build the failure completion for a deadline-evicted slot record."""
        raise NotImplementedError

    # -- slot-leak guard ------------------------------------------------------

    def _evict_slot(self, slot: int) -> list[Completion]:
        """Force-evict a deadline-expired slot: free it, notify the engine,
        requeue the request at the HEAD of its bucket (it is the oldest — the
        age-fair pop must see it first) or fail it after ``max_requeues``."""
        record = self.running.pop(slot)
        req = record[0]
        self.free.append(slot)
        self._slot_steps.pop(slot, None)
        self.engine.on_evict(slot)
        n = self._requeues.get(req.rid, 0)
        if n < self.max_requeues:
            self._requeues[req.rid] = n + 1
            self.buckets[self._bucket_key(req)].appendleft(req)
            return []
        done = self._fail_eviction(slot, record)
        self.results[req.rid] = done
        return [done]

    def _enforce_deadlines(self, stepped: list[int]) -> list[Completion]:
        """Charge one step to every slot that ran and evict the expired ones."""
        finished = []
        for slot in stepped:
            if slot not in self.running:      # finished normally this step
                self._slot_steps.pop(slot, None)
                continue
            n = self._slot_steps.get(slot, 0) + 1
            self._slot_steps[slot] = n
            if n >= self.max_slot_steps:
                finished.extend(self._evict_slot(slot))
        return finished

    # -- drive ---------------------------------------------------------------

    def step(self) -> list[Completion]:
        """Advance in-flight admissions one unit, fill free slots, run one
        multi-slot engine step, collect finished slots. Returns the requests
        completed during this call."""
        finished = self._advance_admissions()
        finished.extend(self._admit_free_slots())
        if not self.running:
            return finished
        stepped = list(self.running)
        self.state, emitted = self.engine.step(self._step_params(), self.state)
        self.steps += 1
        finished.extend(self._collect(emitted))
        if self.max_slot_steps is not None:
            finished.extend(self._enforce_deadlines(stepped))
        return finished

    def run(self, timeout: float | None = None) -> dict[int, Completion]:
        """Step until the queue and all slots drain. Returns {rid: Completion}."""
        t0 = self.clock()
        while self.pending or self.running or self.admitting:
            self.step()
            if timeout is not None and self.clock() - t0 > timeout:
                raise TimeoutError(
                    f"scheduler did not drain within {timeout}s "
                    f"(pending={self.pending}, active={self.active})"
                )
        return self.results


class Scheduler(SlotScheduler):
    """LM request scheduler over a ``ContinuousEngine``.

    Short prompts admit with one whole-prompt prefill; prompts longer than the
    engine's ``prefill_chunk`` (when chunking is enabled) reserve their slot
    and run one prefill chunk per scheduler step, interleaved with the other
    slots' decode steps — the long admission no longer stalls the step loop
    for a whole-prompt prefill.
    """

    def __init__(self, engine: ContinuousEngine, params,
                 clock: Callable[[], float] = time.monotonic,
                 *, max_slot_steps: int | None = None, max_requeues: int = 1):
        super().__init__(engine, params, clock,
                         max_slot_steps=max_slot_steps,
                         max_requeues=max_requeues)

    def submit(self, tokens, *, extras: dict | None = None,
               max_new: int | None = None, key: jax.Array | None = None) -> int:
        """Queue one request. `tokens` [S] or [1, S]; `extras` holds additional
        B=1 model inputs (patch_embeds, positions, frames). Returns request id."""
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim == 1:
            tokens = tokens[None]
        batch = {"tokens": tokens, **(extras or {})}
        max_new = self.engine.cfg.max_new if max_new is None else max_new
        if not 1 <= max_new <= self.engine.cfg.max_new:
            raise ValueError(f"max_new must be in [1, {self.engine.cfg.max_new}]")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid, batch, tokens.shape[1], max_new,
            key if key is not None else jax.random.PRNGKey(rid), self.clock(),
        )
        self.buckets[_prompt_sig(batch)].append(req)
        return rid

    def _bucket_key(self, req: Request):
        return _prompt_sig(req.batch)

    def _fail_eviction(self, slot: int, record) -> Completion:
        req, toks, t_admit = record
        return Completion(
            req.rid, toks, "evicted", req.prompt_len, req.t_submit, t_admit,
            self.clock(),
        )

    def _finish(self, slot: int, reason: str) -> Completion:
        req, toks, t_admit = self.running.pop(slot)
        done = Completion(
            req.rid, toks, reason, req.prompt_len, req.t_submit, t_admit, self.clock()
        )
        self.results[req.rid] = done
        self.free.append(slot)
        return done

    def _register(self, req: Request, slot: int, tok0: int) -> list[Completion]:
        """Record a freshly admitted request; finish immediately on instant EOS
        or max_new == 1."""
        self.running[slot] = (req, [tok0], self.clock())
        eos = self.engine.cfg.eos_id
        if eos is not None and tok0 == eos:
            return [self._finish(slot, "eos")]
        if req.max_new <= 1:
            return [self._finish(slot, "length")]
        return []

    def _start_admission(self, req: Request, slot: int) -> list[Completion]:
        if self.engine.supports_chunked_prefill(req.batch):
            job = self.engine.begin_chunked_prefill(self.params, req.batch, req.key)
            # run the first chunk now so a reserved slot always has progress
            job = self.engine.advance_chunked_prefill(self.params, job)
            self.admitting[slot] = (req, job)
            return []
        self.state, tok0 = self.engine.prefill_into_slot(
            self.params, self.state, req.batch, slot, req.key
        )
        return self._register(req, slot, tok0)

    def _advance_admissions(self) -> list[Completion]:
        finished = []
        for slot in sorted(self.admitting):
            req, job = self.admitting[slot]
            if not job.done:
                job = self.engine.advance_chunked_prefill(self.params, job)
                self.admitting[slot] = (req, job)
            if job.done:
                del self.admitting[slot]
                self.state, tok0 = self.engine.admit_chunked(self.state, job, slot)
                finished.extend(self._register(req, slot, tok0))
        return finished

    def _collect(self, emitted) -> list[Completion]:
        finished = []
        em = np.asarray(emitted)    # device sync: this is the step barrier
        eos = self.engine.cfg.eos_id
        for slot in sorted(self.running):
            req, toks, _ = self.running[slot]
            tok = int(em[slot])
            toks.append(tok)
            if eos is not None and tok == eos:
                finished.append(self._finish(slot, "eos"))
            elif len(toks) >= req.max_new:
                finished.append(self._finish(slot, "length"))
        return finished
