"""Reproduce the paper's Table I interactively with configurable knobs.

  PYTHONPATH=src python examples/hdc_classifier.py --m 5 --bundling permuted
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax

from repro.core import classifier, em, ota


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=3, help="bundled hypervectors")
    ap.add_argument("--bundling", default="baseline", choices=["baseline", "permuted"])
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--classes", type=int, default=100)
    ap.add_argument("--trials", type=int, default=1000)
    ap.add_argument("--n-rx", type=int, default=64)
    ap.add_argument("--representation", default="unpacked",
                    choices=["unpacked", "packed"],
                    help="HV storage: packed = uint32 words + popcount "
                         "similarity (identical accuracy, d/8 the bytes)")
    ap.add_argument("--kernels", action="store_true",
                    help="Pallas similarity kernels (interpret mode on CPU)")
    args = ap.parse_args()

    h = em.channel_matrix(em.PackageGeometry(), 3, args.n_rx)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_exhaustive(h, n0)
    ber = float(res.avg_ber)
    print(f"wireless channel: {args.n_rx} RXs, avg BER {ber:.4f}")

    cfg = classifier.HDCTaskConfig(n_classes=args.classes, dim=args.dim,
                                   n_trials=args.trials)
    key = jax.random.PRNGKey(0)
    for channel, b in (("ideal", 0.0), ("wireless", ber)):
        acc = float(classifier.run_accuracy(
            key, cfg, args.m, b, args.bundling,
            representation=args.representation, use_kernels=args.kernels))
        print(f"M={args.m} {args.bundling:8s} {channel:8s} accuracy {acc:.4f} "
              f"[{args.representation}]")


if __name__ == "__main__":
    main()
