"""Public op: packed Hamming similarity search with padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.hamming.kernel import hamming_banked_pallas, hamming_pallas
from repro.kernels.hamming.ref import hamming_search_banked_ref, hamming_search_ref


def _blocked(ref_fn, protos, c_axis: int, bc: int, *args):
    """Evaluate a hamming ref in prototype chunks of `bc`.

    The plain refs broadcast a [..., C, W] XOR intermediate; past ~8 MiB that
    falls out of cache and the jnp fallback goes ~6x slower than the same math
    chunked (numerics are identical — integer ops). Used by the use_kernel=False
    dispatch; the refs themselves stay the canonical one-liners.
    """
    c = protos.shape[c_axis]
    if c <= bc:
        return ref_fn(*args, protos)
    chunks = [
        ref_fn(*args, jax.lax.slice_in_dim(protos, i, min(i + bc, c), axis=c_axis))
        for i in range(0, c, bc)
    ]
    return jnp.concatenate(chunks, axis=-1)


def hamming_search(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int = 8,
    bc: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Hamming distances between packed queries [.., W] and prototypes [C, W].

    Accepts arbitrary leading query dims; pads B to bq and C to bc (padding words are
    zero on both sides, so padded prototypes report distance 0 against padded queries
    only — padded rows/cols are sliced away before returning).
    """
    if interpret is None:
        interpret = common.default_interpret()
    lead = q.shape[:-1]
    w = q.shape[-1]
    qf = q.reshape((-1, w))
    b, c = qf.shape[0], protos.shape[0]
    if not use_kernel:
        return _blocked(hamming_search_ref, protos, 0, bc, qf).reshape(lead + (c,))
    qp = common.pad_dim(qf, 0, bq)
    pp = common.pad_dim(protos, 0, bc)
    out = hamming_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return out[:b, :c].reshape(lead + (c,))


def hamming_search_banked(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int = 8,
    bc: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Per-bank Hamming distances: q [G, B, W], protos [G, C, W] -> [G, B, C].

    Bank g searches only bank g's prototypes — the scale-out per-core associative
    search as ONE grid (G, B/bq, C/bc) kernel launch (instead of a vmap of G tiny
    calls). B and C are zero-padded to the block sizes and sliced away; zero
    padding is safe because padded rows/banks are dropped before returning.
    """
    if interpret is None:
        interpret = common.default_interpret()
    g, b, w = q.shape
    g2, c, w2 = protos.shape
    assert g == g2 and w == w2, (q.shape, protos.shape)
    if not use_kernel:
        return _blocked(hamming_search_banked_ref, protos, 1, bc, q)
    qp = common.pad_dim(q, 1, bq)
    pp = common.pad_dim(protos, 1, bc)
    out = hamming_banked_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return out[:, :b, :c]
