"""§Roofline table: three terms per (arch × shape) from the dry-run artifacts.

    compute    = per-device FLOPs / 197e12      (bf16 peak, v5e)
    memory     = per-device HBM bytes / 819e9
    collective = per-device collective bytes / 50e9

(The HLO is post-SPMD, i.e. already per-device, so no division by chip count.)
Also reports MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
The hdc-scaleout serve/train cells get their own byte-accounting section
(HBM + collective bytes per device and per trial — the EXPERIMENTS.md §Perf
wire-path numbers at dry-run scale). Run after
`python -m repro.launch.dryrun --all`.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ARTIFACTS, save

DRYRUN = os.path.join(ARTIFACTS, "dryrun")
CELL_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_all(mesh: str = "pod1") -> list[dict]:
    d = os.path.join(DRYRUN, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name)) as f:
            recs.append(json.load(f))
    return recs


def hdc_rows(mesh: str = "pod1") -> list[dict]:
    """Byte accounting of the hdc-scaleout dry-run cells: HBM + collective
    bytes per device and per trial for every serve/train cell x representation
    x collective (psum / psum_packed / rs_ag / wired)."""
    rows = []
    for r in load_all(mesh):
        if r["arch"] != "hdc-scaleout" or r.get("status") != "ok":
            continue
        hlo = r["hlo_per_device"]
        coll = hlo.get("collective", {})
        batch = r.get("config", {}).get("batch") or 1
        rows.append({
            "cell": r["cell"],
            "representation": r.get("config", {}).get("representation"),
            "collective": r.get("config", {}).get("collective"),
            "channel": r.get("config", {}).get("channel", "bsc"),
            "hbm_bytes": hlo.get("hbm_bytes"),
            "collective_bytes": coll.get("total", 0.0),
            "hbm_bytes_per_trial": hlo.get(
                "hbm_bytes_per_trial", (hlo.get("hbm_bytes") or 0.0) / batch),
            "collective_bytes_per_trial": hlo.get(
                "collective_bytes_per_trial", coll.get("total", 0.0) / batch),
        })
    return rows


def run(mesh: str = "pod1", quiet: bool = False) -> dict:
    recs = [r for r in load_all(mesh) if r["arch"] != "hdc-scaleout"]
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "cell": r["cell"], "status": "skipped",
                         "why": r["why"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "cell": r["cell"], "status": r["status"]})
            continue
        rl = r["roofline_s"]
        rows.append({
            "arch": r["arch"], "cell": r["cell"], "status": "ok",
            "params": r["params"],
            "compute_s": rl["compute"], "memory_s": rl["memory"],
            "collective_s": rl["collective"], "dominant": rl["dominant"],
            "model_flops": r["model_flops_global"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": rl["compute"] / max(
                rl["compute"], rl["memory"], rl["collective"]),
        })
    if not quiet:
        hdr = f"{'arch':22s} {'cell':12s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>9s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}"
        print(hdr)
        key = {c: i for i, c in enumerate(CELL_ORDER)}
        for row in sorted(rows, key=lambda x: (x["arch"], key.get(x["cell"], 9))):
            if row["status"] == "skipped":
                print(f"{row['arch']:22s} {row['cell']:12s} {'— skipped: ' + row['why'][:60]}")
            elif row["status"] != "ok":
                print(f"{row['arch']:22s} {row['cell']:12s} ERROR")
            else:
                print(f"{row['arch']:22s} {row['cell']:12s} {row['compute_s']:10.4f} "
                      f"{row['memory_s']:10.4f} {row['collective_s']:9.4f} "
                      f"{row['dominant']:>10s} {row['useful_ratio']:7.3f} "
                      f"{100*row['roofline_fraction']:6.1f}%")
    hdc = hdc_rows(mesh)
    if hdc and not quiet:
        print(f"\nhdc-scaleout wire path ({mesh}):")
        print(f"{'cell':26s} {'rep':9s} {'collective':12s} {'channel':8s} "
              f"{'HBM B/dev':>12s} {'coll B/dev':>11s} {'coll B/trial':>13s}")
        for row in sorted(hdc, key=lambda x: x["cell"]):
            print(f"{row['cell']:26s} {str(row['representation']):9s} "
                  f"{str(row['collective']):12s} {str(row['channel']):8s} "
                  f"{row['hbm_bytes']:12.3e} "
                  f"{row['collective_bytes']:11.0f} "
                  f"{row['collective_bytes_per_trial']:13.1f}")
    out = {"mesh": mesh, "rows": rows, "hdc": hdc}
    save(f"roofline_{mesh}", out)
    return out


if __name__ == "__main__":
    run()
