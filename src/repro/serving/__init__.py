from repro.serving.engine import ContinuousEngine, Engine, ServeConfig  # noqa: F401
from repro.serving.scheduler import Completion, Request, Scheduler  # noqa: F401
