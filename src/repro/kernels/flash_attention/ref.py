"""Oracle for the Pallas fused attention kernel: the scan-form flash impl
(itself validated against naive softmax attention in tests/test_models.py)."""
from __future__ import annotations

import jax

from repro.models.layers import _flash_fwd_impl


def flash_fwd_ref(q, k, v, *, causal=True, window=-1, block_q=128, block_k=128):
    out, _ = _flash_fwd_impl(q, k, v, window, causal, 0, block_q, block_k)
    return out
