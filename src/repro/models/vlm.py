"""Qwen2-VL-style VLM backbone: text decoder + M-RoPE + patch-embedding stub.

Per the assignment the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings [B, S_vis, d_model] (dynamic-resolution ViT output
after the merger). The language model is the standard dense GQA decoder; the only
VLM-specific machinery is (a) the vision prefix concatenated ahead of the token
embeddings and (b) M-RoPE 3-D positions [B, S, 3] (t, h, w) — supplied as an
input, since position layout depends on the (stubbed) image grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import (
    cache_from_kv,
    decoder_specs,
    embed_tokens,
    run_stack_train,
)


def vlm_specs(cfg: ModelConfig) -> dict:
    return decoder_specs(cfg)


def assemble_sequence(params, cfg: ModelConfig, tokens, patch_embeds):
    """[B, S_vis, d] vision prefix + embedded tokens -> [B, S, d]."""
    xt = embed_tokens(params, cfg, tokens)
    if patch_embeds is None or patch_embeds.shape[1] == 0:
        return xt
    return jnp.concatenate([patch_embeds.astype(cfg.dtype), xt], axis=1)


def default_positions(batch: int, s_vis: int, s_text: int, grid_hw: tuple[int, int]) -> jax.Array:
    """Build M-RoPE (t, h, w) position ids: one image of grid_hw patches, then text.

    Vision tokens: t=0, (h, w) from the grid; text tokens: t=h=w increasing from
    s_vis, i.e. text rope position == sequence index. (Qwen2-VL compresses text
    positions to start at max(grid)+1; we keep them aligned with the cache slot
    index so prefill and single-token decode agree — noted in DESIGN.md.)
    """
    gh, gw = grid_hw
    assert gh * gw == s_vis, (grid_hw, s_vis)
    hh = jnp.repeat(jnp.arange(gh), gw)
    ww = jnp.tile(jnp.arange(gw), gh)
    vis = jnp.stack([jnp.zeros(s_vis, jnp.int32), hh, ww], axis=-1)
    t = s_vis + jnp.arange(s_text)
    txt = jnp.stack([t, t, t], axis=-1)
    pos = jnp.concatenate([vis, txt], axis=0) if s_vis else txt
    return jnp.broadcast_to(pos[None], (batch, s_vis + s_text, 3)).astype(jnp.int32)


def run_vlm_train(params, cfg: ModelConfig, tokens, patch_embeds, positions, return_kv=False):
    """Returns (hidden-for-text [B, S_text, d], aux, kv)."""
    x = assemble_sequence(params, cfg, tokens, patch_embeds)
    h, aux, kv = run_stack_train(params, cfg, x, positions, return_kv)
    s_vis = 0 if patch_embeds is None else patch_embeds.shape[1]
    return h[:, s_vis:], aux, kv
