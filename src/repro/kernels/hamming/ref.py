"""Pure-jnp oracle for the packed Hamming similarity-search kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_search_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Packed-word Hamming distances via XOR + popcount.

    q: [B, W] uint32 (bit-packed queries), protos: [C, W] uint32 -> [B, C] int32.
    This is the operation an IMC associative-memory core performs in O(1); here it
    is the memory-bound digital realization used as the kernel oracle.
    """
    x = jnp.bitwise_xor(q[:, None, :], protos[None, :, :])  # [B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_search_banked_ref(q: jax.Array, protos: jax.Array) -> jax.Array:
    """Per-bank packed Hamming distances: q [G, B, W], protos [G, C, W] -> [G, B, C].

    Bank g's queries are compared only against bank g's prototypes — the
    per-IMC-core search of the scale-out serve step, as one batched op.
    """
    x = jnp.bitwise_xor(q[:, :, None, :], protos[:, None, :, :])  # [G, B, C, W]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_topk_banked_ref(
    q: jax.Array, protos: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused per-bank top-1: (min_dist, argmin), each [G, B] int32.

    `jnp.argmin` returns the FIRST minimum — the tie convention the fused
    kernel must reproduce (identical to `jnp.argmax` over similarities, since
    sim = d - 2*dist is strictly decreasing in dist).
    """
    dist = hamming_search_banked_ref(q, protos)
    return jnp.min(dist, axis=-1), jnp.argmin(dist, axis=-1).astype(jnp.int32)
