"""Version-portable mesh construction and ambient-mesh installation.

* ``make_mesh(shape, axes)`` — the one way this repo builds a Mesh.
  On JAX >= 0.6 it forwards ``axis_types=(AxisType.Auto,) * len(axes)`` so the
  mesh is explicitly all-auto (GSPMD decides placement); on 0.4.x, where
  ``AxisType`` does not exist and every mesh axis is implicitly auto, it calls
  plain ``jax.make_mesh`` (0.4.35+) or falls back to
  ``Mesh(mesh_utils.create_device_mesh(shape), axes)``.

* ``set_mesh(mesh)`` — context manager installing ``mesh`` as the ambient mesh
  (so bare-``PartitionSpec`` sharding constraints resolve against it). Prefers
  ``jax.set_mesh`` / ``jax.sharding.use_mesh``; on 0.4.x a ``Mesh`` is its own
  context manager and installs itself into the thread-local physical mesh.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.compat import version as _v


def make_mesh(shape: Sequence[int], axes: Sequence[str], *, devices=None) -> jax.sharding.Mesh:
    """Build a Mesh with all-auto axis types on any supported JAX version."""
    shape = tuple(shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} / axis names {axes} length mismatch")
    if _v.has_axis_type() and _v.make_mesh_takes_axis_types():
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        if devices is not None:
            return jax.make_mesh(shape, axes, axis_types=types, devices=devices)
        return jax.make_mesh(shape, axes, axis_types=types)
    if _v.has_make_mesh():
        if devices is not None:
            return jax.make_mesh(shape, axes, devices=devices)
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils

    if devices is None:
        # jax.make_mesh uses a prefix of jax.devices() for sub-meshes;
        # create_device_mesh insists on an exact device count — match the
        # prefix behavior so small (e.g. (1, 1)) test meshes build anywhere.
        n = 1
        for s in shape:
            n *= s
        devices = jax.devices()[:n]
    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(devs, axes)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    Usage: ``with compat.set_mesh(mesh): ...`` — inside the scope,
    ``compat.current_mesh()`` returns it and bare-PartitionSpec
    ``with_sharding_constraint`` resolves against it.
    """
    if _v.has_set_mesh():
        return jax.set_mesh(mesh)
    if _v.has_use_mesh():
        return jax.sharding.use_mesh(mesh)
    # 0.4.x: Mesh.__enter__ installs the thread-local physical mesh.
    return mesh
