"""Version-portable Pallas TPU compiler params.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` (0.4.x) to
``CompilerParams`` (newer JAX). ``tpu_compiler_params(...)`` builds whichever
class the runtime provides; kernels pass the result straight to
``pl.pallas_call(compiler_params=...)``.
"""
from __future__ import annotations


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:
        raise AttributeError(
            "jax.experimental.pallas.tpu has neither CompilerParams nor "
            "TPUCompilerParams on this JAX version"
        )
    return cls(**kwargs)
