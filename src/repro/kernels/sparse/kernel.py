"""Pallas kernels for sparse-query vs packed-prototype similarity search.

The hot operation of the ultra-sparse representation: a query is k_max sorted
bit indices (sentinel-padded), a prototype row stays bit-packed uint32 words
exactly as the IMC macro stores it. Overlap |q AND p| is a GATHER of the word
holding each query index plus a bit test — O(k_max) loads per (query, class)
pair instead of O(d/32) — and the Hamming distance follows from
``|q XOR p| = |q| + |p| - 2 |q AND p|`` with |p| a popcount of the prototype
tile. The dense [bq, d] query is never materialized, in VMEM or anywhere.

Two kernels, mirroring kernels/hamming/kernel.py:

* `sparse_search_pallas` — full distance tile [bq, bc] per grid step (the
  classifier's top-m decision needs every class's distance);
* `sparse_topk_banked_pallas` — fused per-bank top-1 with the same
  revisited-output-tile running (min, argmin) carry and FIRST-minimum tie
  convention as `hamming_topk_banked_pallas`, so the sparse serve path reuses
  the packed serve's downstream unchanged.

CPU runs use interpret mode (`common.default_interpret()`); the TPU-native
lowering of the in-kernel gather shares the hamming family's caveat that
real-TPU validation is still open (ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

# padded class columns get this distance so they never win the running min;
# a Python int on purpose — a module-level jnp scalar would be captured as a
# compile-time constant by every kernel body
_POISON = 2**30
# sentinel-padded query slots (must match repro.core.sparse.SENTINEL)
_SENTINEL = 2**31 - 1


def _overlap_tile(q: jax.Array, p: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(dist [bq, bc], valid-count [bq]) from q [bq, k] int32, p [bc, W] u32."""
    v = q != jnp.int32(_SENTINEL)
    w = jnp.where(v, q >> 5, 0)
    bit = jnp.where(v, q & 31, 0).astype(jnp.uint32)
    sel = jnp.take(p, w, axis=1)  # gather: [bc, bq, k]
    hit = ((sel >> bit[None]) & jnp.uint32(1)).astype(jnp.int32)
    ov = jnp.sum(hit * v[None].astype(jnp.int32), axis=-1)  # [bc, bq]
    cnt = jnp.sum(v, axis=-1).astype(jnp.int32)             # [bq]
    pop = jnp.sum(jax.lax.population_count(p).astype(jnp.int32), axis=-1)
    dist = cnt[:, None] + pop[None, :] - 2 * ov.T           # [bq, bc]
    return dist, cnt


def _search_kernel(q_ref, p_ref, out_ref):
    dist, _ = _overlap_tile(q_ref[...], p_ref[...])
    out_ref[...] = dist


@functools.partial(jax.jit, static_argnames=("bq", "bc", "interpret"))
def sparse_search_pallas(
    q: jax.Array, protos: jax.Array, *, bq: int, bc: int, interpret: bool
) -> jax.Array:
    """Full sparse-vs-packed distances: q [B, k], protos [C, W] -> [B, C] int32.

    B must be a multiple of bq and C of bc (callers pad; padded query rows are
    all-sentinel, padded class rows all-zero words — both sliced away after).
    """
    b, _ = q.shape
    c, w = protos.shape
    assert b % bq == 0 and c % bc == 0, (q.shape, protos.shape, bq, bc)
    return pl.pallas_call(
        _search_kernel,
        grid=(b // bq, c // bc),
        in_specs=[
            pl.BlockSpec((bq, q.shape[-1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(q, protos)


def _topk_banked_kernel(c_real, bc, q_ref, p_ref, val_ref, idx_ref):
    """Fused per-bank top-1 with a revisited output tile over the class grid.

    Same carry structure as the hamming `_topk_banked_kernel`: grid step j
    streams class block j through the running (min, argmin); strict `<` in
    the merge + FIRST-minimum `argmin` inside the block preserve the global
    first-minimum tie convention of the oracle.
    """
    j = pl.program_id(2)
    dist, _ = _overlap_tile(q_ref[0], p_ref[0])
    col = j * bc + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(col < c_real, dist, jnp.int32(_POISON))
    loc_v = jnp.min(dist, axis=-1)
    loc_i = j * bc + jnp.argmin(dist, axis=-1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        val_ref[0] = loc_v
        idx_ref[0] = loc_i

    @pl.when(j > 0)
    def _update():
        better = loc_v < val_ref[0]
        idx_ref[0] = jnp.where(better, loc_i, idx_ref[0])
        val_ref[0] = jnp.where(better, loc_v, val_ref[0])


@functools.partial(
    jax.jit, static_argnames=("c_real", "bq", "bc", "interpret")
)
def sparse_topk_banked_pallas(
    q: jax.Array, protos: jax.Array, *, c_real: int, bq: int, bc: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    """Fused per-bank sparse top-1: (min_dist, argmin), each [G, B] int32.

    q: [G, B, k] int32 sorted sentinel-padded; protos: [G, C, W] uint32.
    B must be a multiple of bq and C of bc; class columns >= c_real are
    poisoned so padding never wins.
    """
    g, b, k = q.shape
    _, c, w = protos.shape
    assert b % bq == 0 and c % bc == 0, (q.shape, protos.shape, bq, bc)
    kernel = functools.partial(_topk_banked_kernel, c_real, bc)
    return pl.pallas_call(
        kernel,
        grid=(g, b // bq, c // bc),
        in_specs=[
            pl.BlockSpec((1, bq, k), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bc, w), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bq), lambda g, i, j: (g, i))] * 2,
        out_shape=[jax.ShapeDtypeStruct((g, b), jnp.int32)] * 2,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, protos)
