"""Pure-jnp oracles for the sparse-query similarity-search kernels.

These are deliberately DENSE: each oracle densifies the sparse query back to
packed words and reuses the XOR+popcount Hamming path, so the sparse kernels
are pinned against an implementation that shares no code with the O(k)
gather-overlap mechanics they use (|q XOR p| = |q| + |p| - 2|q AND p| must
match XOR+popcount exactly, integer for integer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hypervector as hv, sparse
from repro.kernels.hamming.ref import (
    hamming_search_banked_ref,
    hamming_search_ref,
)


def _densify_packed(idx: jax.Array, words: int) -> jax.Array:
    """Sparse index lists [..., k_max] -> packed uint32 words [..., W]."""
    return hv.pack(sparse.densify(idx, words * hv.WORD))


def sparse_search_ref(idx: jax.Array, protos: jax.Array) -> jax.Array:
    """Full Hamming distances: idx [B, k_max], protos [C, W] -> [B, C] int32."""
    return hamming_search_ref(_densify_packed(idx, protos.shape[-1]), protos)


def sparse_search_banked_ref(idx: jax.Array, protos: jax.Array) -> jax.Array:
    """Per-bank distances: idx [G, B, k_max], protos [G, C, W] -> [G, B, C]."""
    return hamming_search_banked_ref(
        _densify_packed(idx, protos.shape[-1]), protos)


def sparse_topk_banked_ref(
    idx: jax.Array, protos: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused per-bank top-1: (min_dist, argmin), each [G, B].

    `jnp.argmin` returns the FIRST minimum — the same tie convention as the
    hamming family, so the sparse serve path is prediction-identical to the
    packed one on equal distances.
    """
    dist = sparse_search_banked_ref(idx, protos)
    return jnp.min(dist, axis=-1), jnp.argmin(dist, axis=-1).astype(jnp.int32)
