"""Public op: majority bundling with padding + backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels import common
from repro.kernels.majority.kernel import majority_pallas
from repro.kernels.majority.ref import majority_bundle_ref


def majority_bundle(
    hvs: jax.Array,
    *,
    bb: int = 32,
    bd: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Majority over axis 0 of [M, ..., d] uint8 -> [..., d] uint8.

    Zero padding of B/d is safe: padded lanes produce majority(0)=0 and are sliced
    away. Leading dims besides M are flattened into B.
    """
    if interpret is None:
        interpret = common.default_interpret()
    m = hvs.shape[0]
    mid = hvs.shape[1:-1]
    d = hvs.shape[-1]
    hf = hvs.reshape((m, -1, d))
    if not use_kernel:
        return majority_bundle_ref(hf).reshape(mid + (d,))
    b = hf.shape[1]
    hp = common.pad_dim(common.pad_dim(hf, 1, bb), 2, bd)
    out = majority_pallas(hp, bb=bb, bd=bd, interpret=interpret)
    return out[:b, :d].reshape(mid + (d,))
