"""OTA vs wired scale-out: the paper's interconnect claim, quantified from the
compiled dry-run HLO (1024 IMC cores, 2048-bit HVs, 4096-query batches).

The OTA serve step's only inter-core traffic is the int8 majority psum + the
tiny top-1 combine; the wired baseline all-gathers every encoder's query to
every core first (the NoC broadcast the paper eliminates). Reads the artifacts
produced by `python -m repro.launch.dryrun --arch hdc-scaleout --cell serve[_wired]`.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ARTIFACTS, save

DRYRUN = os.path.join(ARTIFACTS, "dryrun")


def run(quiet: bool = False) -> dict:
    out = {}
    for mesh in ("pod1", "pod2"):
        row = {}
        for cell in ("serve", "serve_wired"):
            path = os.path.join(DRYRUN, mesh, f"hdc-scaleout__{cell}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec["status"] != "ok":
                continue
            coll = rec["hlo_per_device"]["collective"]
            row[cell] = {
                "collective_bytes_per_device": coll.get("total", 0),
                "by_type": {k: v for k, v in coll.items() if k not in ("total", "count")},
                "hbm_bytes": rec["hlo_per_device"]["hbm_bytes"],
            }
        if "serve" in row and "serve_wired" in row:
            ota_b = max(row["serve"]["collective_bytes_per_device"], 1)
            wired_b = row["serve_wired"]["collective_bytes_per_device"]
            row["wired_over_ota"] = wired_b / ota_b
            if not quiet:
                print(f"[{mesh}] OTA collective bytes/device: {ota_b:.3e}  "
                      f"wired: {wired_b:.3e}  ratio {row['wired_over_ota']:.1f}x")
        out[mesh] = row
    save("ota_vs_wired", out)
    return out


if __name__ == "__main__":
    run()
