"""HLO cost analyzer: validated against cost_analysis() and analytic counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_cost, roofline
from repro.compat import normalized_cost_analysis


def test_plain_matmul_matches_xla_cost_analysis():
    def f(a, b):
        return a @ b

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32),
    ).compile()
    c = hlo_cost.analyze(comp.as_text())
    ca = normalized_cost_analysis(comp)  # canonical dict on every JAX version
    assert isinstance(ca, dict)
    assert c.flops == ca["flops"]
    assert abs(c.hbm_bytes - ca["bytes accessed"]) / ca["bytes accessed"] < 0.05
    # the compiled-object entry points agree with the text/raw paths
    assert hlo_cost.analyze_compiled(comp).flops == c.flops
    assert hlo_cost.xla_reported_cost(comp)["flops"] == ca["flops"]


def test_roofline_from_compiled_matches_terms():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32),
    ).compile()
    ca = normalized_cost_analysis(comp)
    r1 = roofline.roofline_from_compiled(comp, chips=1)
    r2 = roofline.roofline_terms(
        ca["flops"], ca["bytes accessed"],
        roofline.collective_bytes(comp.as_text())["total"], chips=1,
    )
    assert r1 == r2
    assert r1.compute_s > 0 and r1.memory_s > 0 and r1.collective_s == 0.0


def test_scan_flops_scaled_by_trip_count():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    c = hlo_cost.analyze(comp.as_text())
    assert c.flops == 7 * 2 * 128**3
    # raw (single-trip) is what XLA's own cost_analysis reports
    assert abs(c.raw_flops - 2 * 128**3) / (2 * 128**3) < 0.01


def test_nested_scan_multipliers_compose():
    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    c = hlo_cost.analyze(comp.as_text())
    assert c.flops == 15 * 2 * 64**3


def test_collective_bytes_in_scan():
    import os
    # collective ops only appear under a real multi-device mesh; use shard_map
    # on however many devices exist (1 is fine — psum of 1 still emits all-reduce
    # only if >1 participant; so guard)
    if len(jax.devices()) < 2:
        import pytest
        pytest.skip("needs >1 device; covered by tests/test_distributed.py subprocess")


def test_roofline_terms_and_dominant():
    r = roofline.roofline_terms(197e12 * 2, 819e9, 50e9 * 3, chips=1)
    assert abs(r.compute_s - 2.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 3.0) < 1e-9
    assert r.dominant == "collective"


def test_model_flops_moe_active_params():
    from repro import configs
    from repro.configs.shapes import CELLS
    from repro.models import get_model
    from repro.models.base import count_params

    cfg = configs.get_config("mixtral-8x22b")
    model = get_model(cfg)
    n = count_params(model.specs)
    n_act = roofline.active_params(cfg, n)
    # 8 experts top-2: active ~= total - 6/8 of expert params
    expert_params = cfg.n_layers * cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_expert
    assert abs((n - n_act) - expert_params * 6 / 8) / n < 1e-6
    f_train = roofline.model_flops(cfg, CELLS["train_4k"], n)
    f_dec = roofline.model_flops(cfg, CELLS["decode_32k"], n)
    assert f_train == 6.0 * n_act * 256 * 4096
    assert f_dec == 2.0 * n_act * 128


def test_total_param_counts_sane():
    """Declared configs land near their published parameter counts."""
    from repro import configs
    from repro.models import get_model
    from repro.models.base import count_params

    expect = {
        "smollm_360m": (0.30e9, 0.45e9),
        "gemma3_1b": (0.9e9, 1.6e9),
        "tinyllama_1_1b": (1.0e9, 1.2e9),
        "deepseek_coder_33b": (30e9, 36e9),
        "qwen2_vl_7b": (6.5e9, 8.5e9),
        "whisper_tiny": (0.02e9, 0.08e9),
        "falcon_mamba_7b": (6.5e9, 8e9),
        "zamba2_2_7b": (2.2e9, 3.2e9),
        "mixtral_8x22b": (130e9, 150e9),
        "kimi_k2": (0.95e12, 1.15e12),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_model(configs.get_config(arch)).specs)
        assert lo <= n <= hi, (arch, f"{n:.3e}")
