"""Dense decoder-only transformer stack (llama/gemma/qwen family).

Layers are stacked along a leading L axis and executed with ``lax.scan`` so the
HLO stays one-block-sized for 62-layer 33B configs; per-layer heterogeneity
(sliding window, dual RoPE theta) rides along as scanned scalar arrays. The same
stack underlies the VLM wrapper (M-RoPE positions + patch-embedding prefix) and
the MoE models (block MLP swapped for ``moe.apply``).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as _L


def _pet32():
    return jnp.bfloat16 if _L.REDUCE_BF16 else jnp.float32

from repro.distributed.sharding import shard
from repro.models import moe as moe_lib
from repro.models.base import ParamSpec
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    decode_attention,
    dense,
    flash_attention,
    gated_mlp,
    rmsnorm,
)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    l = cfg.n_layers if layers is None else layers
    hd, h, kh, d = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    lead = () if l == 0 else (l,)
    la = () if l == 0 else (None,)
    p = {
        "wq": ParamSpec(lead + (d, h, hd), la + ("embed", "heads", "head_dim"), "fan_in", dtype=cfg.dtype),
        "wk": ParamSpec(lead + (d, kh, hd), la + ("embed", "kv_heads", "head_dim"), "fan_in", dtype=cfg.dtype),
        "wv": ParamSpec(lead + (d, kh, hd), la + ("embed", "kv_heads", "head_dim"), "fan_in", dtype=cfg.dtype),
        "wo": ParamSpec(lead + (h, hd, d), la + ("heads", "head_dim", "embed"), "fan_in", dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec(lead + (hd,), la + (None,), "zeros", dtype=cfg.dtype)
        p["k_norm"] = ParamSpec(lead + (hd,), la + (None,), "zeros", dtype=cfg.dtype)
    return p


def mlp_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    l = cfg.n_layers if layers is None else layers
    d, f = cfg.d_model, cfg.d_ff
    lead = () if l == 0 else (l,)
    la = () if l == 0 else (None,)
    return {
        "wg": ParamSpec(lead + (d, f), la + ("embed", "mlp"), "fan_in", dtype=cfg.dtype),
        "wu": ParamSpec(lead + (d, f), la + ("embed", "mlp"), "fan_in", dtype=cfg.dtype),
        "wd": ParamSpec(lead + (f, d), la + ("mlp", "embed"), "fan_in", dtype=cfg.dtype),
    }


def decoder_specs(cfg: ModelConfig) -> dict:
    l = cfg.n_layers
    d = cfg.d_model
    blocks: dict[str, Any] = {
        "attn": attn_specs(cfg),
        "ln1": ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype),
        "ln2": ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype),
    }
    if cfg.sandwich_norm:
        blocks["ln1_post"] = ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype)
        blocks["ln2_post"] = ParamSpec((l, d), (None, "embed"), "zeros", dtype=cfg.dtype)
    blocks["mlp"] = moe_lib.moe_specs(cfg) if cfg.moe else mlp_specs(cfg)
    specs = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), "normal", 0.02, cfg.dtype),
        "blocks": blocks,
        "final_norm": ParamSpec((d,), ("embed",), "zeros", dtype=cfg.dtype),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"), "fan_in", dtype=cfg.dtype)
    return specs


# ---------------------------------------------------------------------------
# per-layer scanned arrays (window / rope theta)
# ---------------------------------------------------------------------------

def layer_meta(cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    windows = jnp.asarray(cfg.windows, jnp.int32)
    if cfg.local_rope_theta is not None:
        thetas = jnp.where(
            windows > 0,
            jnp.float32(cfg.local_rope_theta),
            jnp.float32(cfg.rope_theta),
        )
    else:
        thetas = jnp.full((cfg.n_layers,), cfg.rope_theta, jnp.float32)
    return windows, thetas


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_heads(blk: dict, cfg: ModelConfig, x: jax.Array, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, blk["wq"], preferred_element_type=_pet32()).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, blk["wk"], preferred_element_type=_pet32()).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, blk["wv"], preferred_element_type=_pet32()).astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, blk["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, blk["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta, cfg.mrope_sections)
    k = apply_rope(k, positions, theta, cfg.mrope_sections)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    return q, k, v


def attn_block_train(blk, cfg: ModelConfig, x, positions, window, theta, return_kv: bool = False):
    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
    q, k, v = _attn_heads(blk["attn"], cfg, h, positions, theta)
    o = flash_attention(
        q, k, v, causal=True, window=window,
        block_q=cfg.flash_block_q, block_k=cfg.flash_block_k,
    )
    o = jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"], preferred_element_type=_pet32()).astype(x.dtype)
    if cfg.sandwich_norm:
        o = rmsnorm(o, blk["ln1_post"], cfg.norm_eps)
    x = x + o
    h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
    if cfg.moe:
        m, aux = moe_lib.apply(blk["mlp"], cfg, h)
    else:
        m, aux = gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act), 0.0
    if cfg.sandwich_norm:
        m = rmsnorm(m, blk["ln2_post"], cfg.norm_eps)
    return x + m, aux, ((k, v) if return_kv else None)


def attn_block_decode(blk, cfg: ModelConfig, x, pos, window, theta, kc, vc, slot_pos, slot):
    """x [B, 1, d]; kc/vc [B, Sc, KH, hd]. Returns (x, kc, vc)."""
    b = x.shape[0]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos, (b, 1))[..., None].repeat(len(cfg.mrope_sections), -1)
    else:
        positions = jnp.broadcast_to(pos, (b, 1))
    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
    q, k, v = _attn_heads(blk["attn"], cfg, h, positions, theta)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
    o = decode_attention(q, kc, vc, slot_pos, pos, window=window)
    o = jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"], preferred_element_type=_pet32()).astype(x.dtype)
    if cfg.sandwich_norm:
        o = rmsnorm(o, blk["ln1_post"], cfg.norm_eps)
    x = x + o
    h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
    if cfg.moe:
        m, _ = moe_lib.apply(blk["mlp"], cfg, h)
    else:
        m = gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act)
    if cfg.sandwich_norm:
        m = rmsnorm(m, blk["ln2_post"], cfg.norm_eps)
    return x + m, kc, vc


# ---------------------------------------------------------------------------
# stack runners
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    return shard(x.astype(cfg.dtype), "batch", "seq", "embed")


def logits_head(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", h, w, preferred_element_type=_pet32())
    return shard(logits, "batch", "seq", "vocab")


def run_stack_train(params, cfg: ModelConfig, x: jax.Array, positions, return_kv: bool = False):
    """Full-sequence causal stack; returns (hidden [B,S,d], aux loss, kv or None)."""
    windows, thetas = layer_meta(cfg)

    def body(carry, xs):
        x, aux = carry
        blk, window, theta = xs
        x, a, kv = attn_block_train(blk, cfg, x, positions, window, theta, return_kv)
        return (x, aux + a), kv

    body_fn = jax.checkpoint(body) if cfg.remat and not return_kv else body
    (x, aux), kv = jax.lax.scan(body_fn, (x, 0.0), (params["blocks"], windows, thetas))
    return x, aux, kv


def cache_from_kv(cfg: ModelConfig, kv, seq: int, pad_to: int | None = None) -> dict:
    """Build a decode cache from prefill K/V stacks [L, B, S, KH, hd].

    For pure sliding-window models the cache is a ring of the largest window
    (slot = pos % window; further decodes wrap correctly). Otherwise the cache is
    full-length, optionally padded to `pad_to` capacity so decode can extend
    beyond the prompt without evicting position 0.
    """
    k, v = kv
    sc = seq if cfg.max_window < 0 else min(seq, cfg.max_window)
    if sc < seq:  # ring buffer holds the last sc positions at slot = pos % sc
        k = jnp.roll(k[:, :, seq - sc :], seq % sc, axis=2)
        v = jnp.roll(v[:, :, seq - sc :], seq % sc, axis=2)
        pos = jnp.arange(seq - sc, seq, dtype=jnp.int32)
        slot_pos = jnp.roll(pos, seq % sc)
        return {"k": k, "v": v, "slot_pos": slot_pos}
    slot_pos = jnp.arange(seq, dtype=jnp.int32)
    return pad_kv_cache({"k": k, "v": v, "slot_pos": slot_pos}, pad_to)


def pad_kv_cache(cache: dict, pad_to: int | None) -> dict:
    """Grow a full-length cache's capacity (axis 2 of k/v) to `pad_to` slots."""
    seq = cache["k"].shape[2]
    if pad_to is None or pad_to <= seq:
        return cache
    extra = pad_to - seq
    pad = [(0, 0)] * cache["k"].ndim
    pad[2] = (0, extra)
    return dict(
        cache,
        k=jnp.pad(cache["k"], pad),
        v=jnp.pad(cache["v"], pad),
        slot_pos=jnp.concatenate(
            [cache["slot_pos"], jnp.full((extra,), -1, jnp.int32)]
        ),
    )


def run_stack_decode(params, cfg: ModelConfig, x, pos, cache):
    windows, thetas = layer_meta(cfg)
    slot = pos % cache["k"].shape[2]
    slot_pos = cache["slot_pos"].at[slot].set(pos)

    def body(x, xs):
        blk, window, theta, kc, vc = xs
        x, kc, vc = attn_block_decode(
            blk, cfg, x, pos, window, theta, kc, vc, slot_pos, slot
        )
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], windows, thetas, cache["k"], cache["v"])
    )
    new_cache = dict(cache, k=k_new, v=v_new, slot_pos=slot_pos)
    return x, new_cache


def run_stack_chunk(params, cfg: ModelConfig, x, positions, cache, start: int):
    """One prefill chunk: positions [start, start+cs) of a prompt, attending
    over the cache prefix written by earlier chunks plus itself.

    ``start`` is a static python int (jit with static_argnums), so the cache
    update and the ``[:, :stop]`` attention slice are static-shape — one
    compiled program per (start, chunk_len) pair. Chunks fill the cache
    front-to-back, so plain causal masking with ``q_offset=start`` over keys
    ``[0, stop)`` reproduces full-prefill attention exactly. The cache must be
    full-capacity (no ring), which the engine enforces; dense MLP only — MoE
    routes over the token axis, so chunk boundaries would change its drops.
    """
    assert cfg.moe is None, "chunked prefill is dense-decoder only"
    windows, thetas = layer_meta(cfg)
    cs = x.shape[1]
    stop = start + cs
    slot_pos = cache["slot_pos"].at[start:stop].set(
        jnp.arange(start, stop, dtype=jnp.int32)
    )

    def body(x, xs):
        blk, window, theta, kc, vc = xs
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _attn_heads(blk["attn"], cfg, h, positions, theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, start, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, start, axis=1)
        o = flash_attention(
            q, kc[:, :stop], vc[:, :stop], causal=True, window=window,
            q_offset=start, block_q=cfg.flash_block_q, block_k=cfg.flash_block_k,
        )
        o = jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"], preferred_element_type=_pet32()).astype(x.dtype)
        if cfg.sandwich_norm:
            o = rmsnorm(o, blk["ln1_post"], cfg.norm_eps)
        x = x + o
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        m = gated_mlp(h, blk["mlp"]["wg"], blk["mlp"]["wu"], blk["mlp"]["wd"], cfg.act)
        if cfg.sandwich_norm:
            m = rmsnorm(m, blk["ln2_post"], cfg.norm_eps)
        return x + m, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], windows, thetas, cache["k"], cache["v"])
    )
    return x, dict(cache, k=k_new, v=v_new, slot_pos=slot_pos)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq: int, layers: int | None = None) -> dict:
    """KV cache shape specs. For pure sliding-window models the cache is a ring
    buffer of the largest window; otherwise full length."""
    l = layers if layers is not None else cfg.n_layers
    sc = seq if cfg.max_window < 0 else min(seq, cfg.max_window)
    kv = (l, batch, sc, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, cfg.dtype),
        "v": jnp.zeros(kv, cfg.dtype),
        "slot_pos": jnp.full((sc,), -1, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, seq: int, layers: int | None = None) -> dict:
    """ShapeDtypeStructs + logical axes for the cache (dry-run path)."""
    l = layers if layers is not None else cfg.n_layers
    sc = seq if cfg.max_window < 0 else min(seq, cfg.max_window)
    kv = (l, batch, sc, cfg.n_kv_heads, cfg.hd)
    kv_axes = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    shapes = {
        "k": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "slot_pos": jax.ShapeDtypeStruct((sc,), jnp.int32),
    }
    axes = {"k": kv_axes, "v": kv_axes, "slot_pos": (None,)}
    return shapes, axes
