"""Fig. 8: per-receiver BER in the 3-TX / 64-RX system (+ the Eq. 1 vs
per-symbol analytic gap — our beyond-paper refinement of the error model)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save
from repro.core import em, ota


def run(quiet: bool = False) -> dict:
    h = em.channel_matrix(em.PackageGeometry(), 3, 64)
    n0 = ota.default_n0(h)
    res = ota.optimize_phases_exhaustive(h, n0)
    maj = ota.majority_labels(3)
    ber_sym, _ = ota.decision_metrics(res.symbols, maj, n0, method="symbol")
    ber = np.asarray(res.ber_per_rx)
    out = {
        "ber_per_rx_eq1": ber.tolist(),
        "ber_per_rx_symbol": np.asarray(ber_sym).tolist(),
        "avg_eq1": float(ber.mean()),
        "max_eq1": float(ber.max()),
        "avg_symbol": float(np.asarray(ber_sym).mean()),
        "phases": np.asarray(res.phase_idx).tolist(),
        "n0": float(n0),
    }
    if not quiet:
        print(f"avg BER (Eq.1) {out['avg_eq1']:.4f}  max {out['max_eq1']:.4f}  "
              f"(paper: avg <0.01, max ~0.1)")
        print(f"avg BER (per-symbol, tight) {out['avg_symbol']:.4f}")
        print(f"RXs below 1e-5: {(ber < 1e-5).sum()}/64")
    save("fig8", out)
    return out


if __name__ == "__main__":
    run()
