"""CI perf-regression gate for the packed fast path.

  PYTHONPATH=src python -m benchmarks.check_regression

Compares the freshly generated ``benchmarks/artifacts/packed.json`` against the
checked-in ``BENCH_BASELINE.json`` and exits non-zero when the fast path
regressed:

* **bytes regress** — per (collective x representation) serve cell, the
  per-device HBM bytes and collective bytes must not exceed the baseline by
  more than ``bytes_max_factor`` (byte counts are deterministic for a given
  JAX/XLA pin; the small headroom absorbs pin drift);
* **wire-cut / ratio floors** — the psum_packed wire cut and the per-cell
  HBM ratio must not drop below ``ratio_min_factor`` x baseline;
* **trials/s drops >20%** — measured trials/s must stay above
  ``trials_min_factor`` (0.8) x the baseline figures. CI runners vary ~2x in
  absolute speed, so the baseline records *conservative floors* (see the
  ``_comment`` in BENCH_BASELINE.json), and the 20% rule applies to those
  floors: the gate catches structural collapses (e.g. the packed path silently
  falling back to an unpacked dataflow), not machine jitter.

When the baseline carries a ``serving_hdc`` section, the multi-tenant HDC
serving artifact (``benchmarks/artifacts/serving_hdc.json``, produced by
``benchmarks.serving --hdc``) is gated too: per-tenant prediction identity
must hold, continuous trials/s must clear its floor, and the
continuous-over-static speedup must stay above ``speedup_min`` (set below the
recorded ~1.7x so machine jitter doesn't flake the gate, but well above 1.0 so
losing the batched-admission or single-launch amortization fails CI).

When the baseline carries a ``serving_adaptive`` section, the closed-loop
living-channel artifact (``benchmarks/artifacts/serving_adaptive.json``,
produced by ``benchmarks.serving --drift``) is gated too: the drift scenario
must still cost the open-loop serve >= ``min_static_drop_pts`` accuracy
points AND the adaptive controller must recover to within
``max_adaptive_gap_pts`` of the no-drift baseline — both trial-exact (seeded),
so they are hard thresholds, not jitter-padded floors.

When the baseline carries a ``serving_faults`` section, the chaos artifact
(``benchmarks/artifacts/serving_faults.json``, produced by
``benchmarks.faults``) is gated too: the zero-fault fault-aware serve must
stay bit-identical to the plain serve, the fault-unaware path must still lose
>= ``min_unaware_drop_pts`` accuracy points at the pinned dead-core scenario
(otherwise the chaos scenario went toothless), and the failover path must
stay within ``max_aware_gap_pts`` of the fault-free baseline — trial-exact,
hard thresholds.

When the baseline carries a ``serving_topk`` section, the coarse-to-fine
C-sweep artifact (``benchmarks/artifacts/topk.json``, produced by
``benchmarks.topk``) is gated too: every sweep row must report ZERO
prediction mismatches against the flat scan (the comparison is RNG-exact, so
this is a hard assertion, not a floor), and the pinned ``gate_c`` row must
keep its coarse-over-flat speedup above ``speedup_min`` and its coarse
trials/s above the conservative floor — losing either means the two-level
screen stopped paying for itself at the scale it exists for.

When the baseline carries a ``sparse_crossover`` section, the ultra-sparse
artifact (``benchmarks/artifacts/sparse.json``, produced by
``benchmarks.sparse --fast``) is gated too: sparse-vs-packed prediction
identity must hold (RNG-exact, hard failure), the d=10^6 headline must keep
its sparse-over-packed speedup above ``speedup_min`` and its sparse trials/s
above the conservative floor, the index_ag wire bytes must not exceed the
baseline (byte counts are deterministic), and the fitted crossover density
must not collapse below ``ratio_min_factor`` x the recorded fit — a shrinking
crossover means sparse stopped paying at densities it used to win.

Regenerate the baseline after an intentional perf change with:
  PYTHONPATH=src python -m benchmarks.packed --fast
  PYTHONPATH=src python -m benchmarks.serving --hdc
  PYTHONPATH=src python -m benchmarks.serving --drift
  PYTHONPATH=src python -m benchmarks.faults
  PYTHONPATH=src python -m benchmarks.topk --fast
  PYTHONPATH=src python -m benchmarks.sparse --fast
  PYTHONPATH=src python -m benchmarks.check_regression --rebaseline
(then review + commit BENCH_BASELINE.json; keep trials/s floors conservative).

To refresh exactly ONE baseline row after a change that only moves one
benchmark (e.g. a sparse-kernel tweak), regenerate that benchmark's artifact
and run:
  PYTHONPATH=src python -m benchmarks.check_regression --rebaseline-row sparse_crossover
Only the named top-level row of BENCH_BASELINE.json is rewritten; every other
byte of the file stays identical, so the diff review is a single section.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import ARTIFACTS

BASELINE = os.path.join(os.path.dirname(__file__), "..", "BENCH_BASELINE.json")

POLICY = {
    "bytes_max_factor": 1.05,
    "ratio_min_factor": 0.8,
    "trials_min_factor": 0.8,
}

# the three vote collectives plus the physical channel="symbol" PHY-tier cell
# (structurally the same row: unpacked/packed bytes + trials/s + hbm_ratio)
SERVE_COLLS = ("psum", "psum_packed", "rs_ag", "symbol")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check(artifact: dict, baseline: dict) -> list[str]:
    pol = dict(POLICY) | baseline.get("policy", {})
    fails: list[str] = []

    # the comparison is only meaningful on the identical workload: a full-size
    # artifact (no --fast) vs the --fast baseline would report bogus ~4x byte
    # "regressions", and rebaselining from it would mask real ones.
    drop_timing = lambda c: {k: v for k, v in c.items() if k != "reps"}
    if drop_timing(artifact.get("config", {})) != drop_timing(
            baseline.get("config", {})):
        return [
            "benchmark config mismatch — regenerate the artifact with the "
            f"baseline's sizes (baseline: {baseline.get('config')}, "
            f"artifact: {artifact.get('config')})"
        ]

    def bytes_ok(name: str, cur: float, base: float):
        if cur > base * pol["bytes_max_factor"]:
            fails.append(f"{name}: {cur:.0f} B > {base:.0f} B "
                         f"x {pol['bytes_max_factor']} (bytes regressed)")

    def floor_ok(name: str, cur: float, base: float, factor: float):
        if cur < base * factor:
            fails.append(f"{name}: {cur:.2f} < {base:.2f} x {factor}")

    for coll in SERVE_COLLS:
        cur_row = artifact["serve"].get(coll)
        base_row = baseline["serve"].get(coll)
        if cur_row is None or base_row is None:
            fails.append(f"serve/{coll}: missing from "
                         f"{'artifact' if cur_row is None else 'baseline'}")
            continue
        for rep in ("unpacked", "packed"):
            for metric in ("hbm_bytes_per_device", "collective_bytes_per_device"):
                bytes_ok(f"serve/{coll}/{rep}/{metric}",
                         cur_row[rep][metric], base_row[rep][metric])
            floor_ok(f"serve/{coll}/{rep}/trials_per_s",
                     cur_row[rep]["trials_per_s"], base_row[rep]["trials_per_s"],
                     pol["trials_min_factor"])
        floor_ok(f"serve/{coll}/hbm_ratio", cur_row["hbm_ratio"],
                 base_row["hbm_ratio"], pol["ratio_min_factor"])
    for rep in ("unpacked", "packed"):
        k = f"psum_packed_wire_cut_{rep}"
        floor_ok(f"serve/{k}", artifact["serve"][k], baseline["serve"][k],
                 pol["ratio_min_factor"])
    if not artifact["serve"].get("prediction_identical", False):
        fails.append("serve/prediction_identical is False")
    floor_ok("classifier/packed/trials_per_s",
             artifact["classifier"]["packed"]["trials_per_s"],
             baseline["classifier"]["packed"]["trials_per_s"],
             pol["trials_min_factor"])
    return fails


SERVING_CFG_KEYS = ("n_requests", "slots", "tenants", "batch", "n_classes",
                    "dim", "representation")


def check_serving(artifact: dict, baseline: dict) -> list[str]:
    """Gate the multi-tenant HDC serving artifact against its baseline row."""
    pol = dict(POLICY) | baseline.get("policy", {})
    base = baseline["serving_hdc"]
    got = {k: artifact.get(k) for k in SERVING_CFG_KEYS}
    want = base["config"]
    if got != want:
        return [
            "serving_hdc config mismatch — regenerate with the baseline's "
            f"sizes (baseline: {want}, artifact: {got})"
        ]
    fails: list[str] = []
    if not artifact.get("prediction_identical", False):
        fails.append("serving_hdc/prediction_identical is False")
    cur = artifact["continuous"]["trials_per_s"]
    floor = base["continuous_trials_per_s"]
    if cur < floor * pol["trials_min_factor"]:
        fails.append(f"serving_hdc/continuous_trials_per_s: {cur:.1f} < "
                     f"{floor:.1f} x {pol['trials_min_factor']}")
    if artifact["speedup"] < base["speedup_min"]:
        fails.append(f"serving_hdc/speedup: {artifact['speedup']:.2f}x < "
                     f"{base['speedup_min']}x (continuous batching no longer "
                     "beats static per-tenant serves)")
    return fails


def check_adaptive(artifact: dict, baseline: dict) -> list[str]:
    """Gate the closed-loop living-channel artifact against its baseline row.

    The accuracy side is trial-exact (seeded keys, deterministic channel
    evolution), so the drop/gap thresholds are hard assertions, not floors:
    the drift scenario must still COST the open-loop serve >=
    ``min_static_drop_pts`` accuracy points (otherwise the scenario went
    toothless and the closed loop is untested), and the adaptive controller
    must recover to within ``max_adaptive_gap_pts`` of the no-drift baseline
    (otherwise the monitor/re-fit loop broke). Only the serving trials/s is
    machine-dependent and gets the conservative-floor treatment."""
    pol = dict(POLICY) | baseline.get("policy", {})
    base = baseline["serving_adaptive"]
    if artifact.get("scenario") != base["scenario"]:
        return [
            "serving_adaptive scenario mismatch — regenerate with the "
            f"baseline's scenario (baseline: {base['scenario']}, "
            f"artifact: {artifact.get('scenario')})"
        ]
    fails: list[str] = []
    drop = artifact["static_drop_pts"]
    if drop < base["min_static_drop_pts"]:
        fails.append(
            f"serving_adaptive/static_drop_pts: {drop:.1f} < "
            f"{base['min_static_drop_pts']} (drift no longer hurts the "
            "open-loop serve — the closed-loop claim is untested)")
    gap = artifact["adaptive_gap_pts"]
    if gap > base["max_adaptive_gap_pts"]:
        fails.append(
            f"serving_adaptive/adaptive_gap_pts: {gap:.1f} > "
            f"{base['max_adaptive_gap_pts']} (controller no longer recovers "
            "the drift-induced accuracy loss)")
    cur = artifact["serving"]["trials_per_s"]
    floor = base["serving_trials_per_s"]
    if cur < floor * pol["trials_min_factor"]:
        fails.append(f"serving_adaptive/serving/trials_per_s: {cur:.1f} < "
                     f"{floor:.1f} x {pol['trials_min_factor']}")
    return fails


def check_faults(artifact: dict, baseline: dict) -> list[str]:
    """Gate the chaos (fault-injection) artifact against its baseline row.

    Accuracy is seeded + trial-exact, so all three conditions are hard
    assertions: the zero-fault fault-aware serve must be bit-identical to
    the plain serve (fault awareness is free or it is a bug), the
    fault-unaware path must still LOSE >= ``min_unaware_drop_pts`` at the
    pinned K-dead-cores + stuck-at scenario (a toothless scenario tests
    nothing), and the failover path must hold within ``max_aware_gap_pts``
    of fault-free. Serving trials/s gets the conservative-floor treatment."""
    pol = dict(POLICY) | baseline.get("policy", {})
    base = baseline["serving_faults"]
    if artifact.get("scenario") != base["scenario"]:
        return [
            "serving_faults scenario mismatch — regenerate with the "
            f"baseline's scenario (baseline: {base['scenario']}, "
            f"artifact: {artifact.get('scenario')})"
        ]
    fails: list[str] = []
    if not artifact.get("zero_fault_identical", False):
        fails.append("serving_faults/zero_fault_identical is False (the "
                     "fault-aware serve diverged from the plain serve with "
                     "zero faults injected)")
    drop = artifact["unaware_drop_pts"]
    if drop < base["min_unaware_drop_pts"]:
        fails.append(
            f"serving_faults/unaware_drop_pts: {drop:.1f} < "
            f"{base['min_unaware_drop_pts']} (dead cores no longer hurt the "
            "fault-unaware serve — the failover claim is untested)")
    gap = artifact["aware_gap_pts"]
    if gap > base["max_aware_gap_pts"]:
        fails.append(
            f"serving_faults/aware_gap_pts: {gap:.1f} > "
            f"{base['max_aware_gap_pts']} (failover no longer recovers the "
            "dead cores' class banks)")
    cur = artifact["serving"]["trials_per_s"]
    floor = base["serving_trials_per_s"]
    if cur < floor * pol["trials_min_factor"]:
        fails.append(f"serving_faults/serving/trials_per_s: {cur:.1f} < "
                     f"{floor:.1f} x {pol['trials_min_factor']}")
    return fails


def check_topk(artifact: dict, baseline: dict) -> list[str]:
    """Gate the coarse-to-fine C-sweep artifact against its baseline row.

    Parity is RNG-exact (flat and coarse serves consume the identical noise
    stream), so ANY mismatch on ANY sweep row is a hard failure. The perf
    side gates only the pinned ``gate_c`` row — small-C rows are in the
    identity/warm-up regime where coarse ~ flat and machine jitter dominates;
    ``gate_c`` is the scale the two-level screen exists for."""
    pol = dict(POLICY) | baseline.get("policy", {})
    base = baseline["serving_topk"]
    drop_timing = lambda c: {k: v for k, v in c.items() if k != "reps"}
    if drop_timing(artifact.get("config", {})) != drop_timing(base["config"]):
        return [
            "serving_topk config mismatch — regenerate with the baseline's "
            f"sizes (baseline: {base['config']}, "
            f"artifact: {artifact.get('config')})"
        ]
    fails: list[str] = []
    gate_row = None
    for row in artifact.get("sweep", []):
        if row["mismatches"]:
            fails.append(
                f"serving_topk/C={row['c']}: {row['mismatches']} prediction "
                "mismatches vs the flat scan (coarse-to-fine must be "
                "RNG-exact at the swept screen margins)")
        if row["c"] == base["gate_c"]:
            gate_row = row
    if gate_row is None:
        fails.append(f"serving_topk: gate row C={base['gate_c']} missing "
                     "from the sweep")
        return fails
    if gate_row["speedup"] < base["speedup_min"]:
        fails.append(
            f"serving_topk/C={base['gate_c']}/speedup: "
            f"{gate_row['speedup']:.2f}x < {base['speedup_min']}x (the "
            "two-level screen no longer pays for itself at scale)")
    cur = gate_row["coarse_trials_per_s"]
    floor = base["coarse_trials_per_s"]
    if cur < floor * pol["trials_min_factor"]:
        fails.append(f"serving_topk/C={base['gate_c']}/coarse_trials_per_s: "
                     f"{cur:.1f} < {floor:.1f} x {pol['trials_min_factor']}")
    return fails


def check_sparse(artifact: dict, baseline: dict) -> list[str]:
    """Gate the ultra-sparse crossover artifact against its baseline row.

    Identity is RNG-exact (sparse and packed serves consume the same codebook
    bits and noise stream), so a False is a hard failure. The headline speedup
    gate is a hard threshold too — it IS the perf claim the sparse path exists
    for — while the sparse trials/s floor gets the conservative-floor
    treatment. Wire bytes are compiled-HLO counts (deterministic for a pin),
    and the fitted crossover density may wiggle with machine jitter but must
    not collapse: sparse losing at densities it used to win means the O(k)
    path got structurally slower."""
    pol = dict(POLICY) | baseline.get("policy", {})
    base = baseline["sparse_crossover"]
    drop_timing = lambda c: {k: v for k, v in c.items() if k != "reps"}
    if drop_timing(artifact.get("config", {})) != drop_timing(base["config"]):
        return [
            "sparse_crossover config mismatch — regenerate with the "
            f"baseline's sizes (baseline: {base['config']}, "
            f"artifact: {artifact.get('config')})"
        ]
    fails: list[str] = []
    if not artifact["serve"].get("prediction_identical", False):
        fails.append("sparse_crossover/prediction_identical is False (the "
                     "index_ag sparse serve diverged from the packed serve "
                     "on the same bits)")
    h = artifact.get("headline")
    hb = base["headline"]
    if h is None or (h["dim"], h["density"], h["k_max"]) != (
            hb["dim"], hb["density"], hb["k_max"]):
        fails.append("sparse_crossover/headline: missing or operating point "
                     f"changed (baseline {hb}, artifact "
                     f"{h and {k: h[k] for k in ('dim', 'density', 'k_max')}})")
        return fails
    if h["speedup"] < base["speedup_min"]:
        fails.append(
            f"sparse_crossover/headline/speedup: {h['speedup']:.2f}x < "
            f"{base['speedup_min']}x (sparse no longer beats packed at "
            f"d={hb['dim']}, density={hb['density']})")
    cur_bytes = h["sparse"]["collective_bytes_per_device"]
    base_bytes = hb["sparse_collective_bytes_per_device"]
    if cur_bytes > base_bytes * pol["bytes_max_factor"]:
        fails.append(
            f"sparse_crossover/headline/sparse_collective_bytes: "
            f"{cur_bytes:.0f} B > {base_bytes:.0f} B x "
            f"{pol['bytes_max_factor']} (the index wire grew)")
    if cur_bytes >= h["packed"]["collective_bytes_per_device"]:
        fails.append(
            "sparse_crossover/headline: index_ag wire bytes no longer "
            "smaller than the packed vote field "
            f"({cur_bytes:.0f} B vs "
            f"{h['packed']['collective_bytes_per_device']:.0f} B)")
    cur = h["sparse"]["trials_per_s"]
    floor = hb["sparse_trials_per_s"]
    if cur < floor * pol["trials_min_factor"]:
        fails.append(f"sparse_crossover/headline/sparse_trials_per_s: "
                     f"{cur:.1f} < {floor:.1f} x {pol['trials_min_factor']}")
    fitted = artifact["crossover"]["density"]
    if fitted < base["crossover_density"] * pol["ratio_min_factor"]:
        fails.append(
            f"sparse_crossover/crossover_density: {fitted:.4g} < "
            f"{base['crossover_density']:.4g} x {pol['ratio_min_factor']} "
            "(sparse stopped winning at densities it used to win)")
    return fails


def _build_baseline(artifact: dict, floor_factor: float = 0.1,
                    serving: dict | None = None, adaptive: dict | None = None,
                    faults: dict | None = None, topk: dict | None = None,
                    sparse: dict | None = None) -> dict:
    """Assemble a fresh baseline dict: bytes/ratios as measured, trials/s
    scaled down to `floor_factor` as the documented conservative floor.
    Optional sections appear only when their artifact was provided."""
    base: dict = {
        "_comment": (
            "Perf floors/ceilings for benchmarks/check_regression.py (fed by "
            "benchmarks/packed.py --fast). Byte counts are measured and "
            "deterministic; trials_per_s entries are CONSERVATIVE FLOORS "
            f"({floor_factor}x a local run) because CI runners can be several "
            "times slower than the authoring machine — the >20%-drop gate "
            "applies to these floors and catches structural collapses (the "
            "packed path silently going unpacked-speed), not machine jitter."
        ),
        "policy": POLICY,
        "config": artifact["config"],
        "serve": {},
        "classifier": {},
    }
    for coll in SERVE_COLLS:
        row = artifact["serve"][coll]
        base["serve"][coll] = {
            rep: {
                "hbm_bytes_per_device": row[rep]["hbm_bytes_per_device"],
                "collective_bytes_per_device": row[rep]["collective_bytes_per_device"],
                "trials_per_s": round(row[rep]["trials_per_s"] * floor_factor, 1),
            }
            for rep in ("unpacked", "packed")
        }
        base["serve"][coll]["hbm_ratio"] = round(row["hbm_ratio"], 2)
    for rep in ("unpacked", "packed"):
        k = f"psum_packed_wire_cut_{rep}"
        base["serve"][k] = round(artifact["serve"][k], 2)
    base["classifier"] = {
        "packed": {"trials_per_s": round(
            artifact["classifier"]["packed"]["trials_per_s"] * floor_factor, 1)},
    }
    if serving is not None:
        base["serving_hdc"] = {
            "config": {k: serving.get(k) for k in SERVING_CFG_KEYS},
            "continuous_trials_per_s": round(
                serving["continuous"]["trials_per_s"] * floor_factor, 1),
            # well under the recorded speedup (jitter headroom), well over
            # 1.0x (a collapse to per-request dispatch cost must fail)
            "speedup_min": 1.25,
        }
    if adaptive is not None:
        base["serving_adaptive"] = {
            "scenario": adaptive["scenario"],
            # the accuracy side is seeded + trial-exact, so these are HARD
            # thresholds (well inside the recorded drop/gap), not floors
            "min_static_drop_pts": 3.0,
            "max_adaptive_gap_pts": 1.0,
            "serving_trials_per_s": round(
                adaptive["serving"]["trials_per_s"] * floor_factor, 1),
        }
    if faults is not None:
        base["serving_faults"] = {
            "scenario": faults["scenario"],
            # trial-exact chaos gates: the recorded unaware drop is ~12.8 pts
            # and the aware gap ~0, so these thresholds have wide margin while
            # still catching a broken failover or a toothless scenario
            "min_unaware_drop_pts": 5.0,
            "max_aware_gap_pts": 1.0,
            "serving_trials_per_s": round(
                faults["serving"]["trials_per_s"] * floor_factor, 1),
        }
    if topk is not None:
        gate_c = topk["config"]["gate_c"]
        gate_row = next(r for r in topk["sweep"] if r["c"] == gate_c)
        base["serving_topk"] = {
            "config": topk["config"],
            "gate_c": gate_c,
            # well under the recorded coarse-over-flat speedup at gate_c
            # (jitter headroom), well over 1.0x: the screen must still WIN
            "speedup_min": 3.0,
            "coarse_trials_per_s": round(
                gate_row["coarse_trials_per_s"] * floor_factor, 1),
        }
    if sparse is not None:
        h = sparse["headline"]
        base["sparse_crossover"] = {
            "config": sparse["config"],
            "headline": {
                "dim": h["dim"],
                "density": h["density"],
                "k_max": h["k_max"],
                "sparse_collective_bytes_per_device":
                    h["sparse"]["collective_bytes_per_device"],
                "sparse_trials_per_s": round(
                    h["sparse"]["trials_per_s"] * floor_factor, 1),
            },
            # the headline perf claim itself (benchmarks.sparse asserts the
            # same bound at generation time) — hard threshold, not a floor
            "speedup_min": 5.0,
            "crossover_density": round(sparse["crossover"]["density"], 6),
        }
    return base


def _dump_baseline(base: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(base, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")


def rebaseline(artifact: dict, path: str, floor_factor: float = 0.1,
               **artifacts) -> None:
    """Write a fresh baseline from the provided artifacts (full rewrite)."""
    _dump_baseline(_build_baseline(artifact, floor_factor, **artifacts), path)


def rebaseline_row(name: str, artifact: dict, path: str,
                   floor_factor: float = 0.1, **artifacts) -> None:
    """Refresh exactly one top-level row of the baseline file.

    Rebuilds the named row from the freshly generated artifacts and splices
    it into the existing baseline, leaving every other byte of the file
    identical — the review diff after a single-benchmark perf change is then
    one section, not a wall of re-rounded floors."""
    fresh = _build_baseline(artifact, floor_factor, **artifacts)
    if name not in fresh:
        raise SystemExit(
            f"--rebaseline-row {name}: no such row (available: "
            f"{sorted(k for k in fresh if k != '_comment')}) — is the "
            "producing artifact present?")
    current = _load(path)
    current[name] = fresh[name]
    _dump_baseline(current, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=os.path.join(ARTIFACTS, "packed.json"))
    ap.add_argument("--serving-artifact",
                    default=os.path.join(ARTIFACTS, "serving_hdc.json"))
    ap.add_argument("--adaptive-artifact",
                    default=os.path.join(ARTIFACTS, "serving_adaptive.json"))
    ap.add_argument("--faults-artifact",
                    default=os.path.join(ARTIFACTS, "serving_faults.json"))
    ap.add_argument("--topk-artifact",
                    default=os.path.join(ARTIFACTS, "topk.json"))
    ap.add_argument("--sparse-artifact",
                    default=os.path.join(ARTIFACTS, "sparse.json"))
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--rebaseline", action="store_true",
                    help="write the current artifact as the new baseline "
                         "(trials/s floors at 0.1x measured) instead of checking")
    ap.add_argument("--rebaseline-row", metavar="NAME",
                    help="refresh exactly one top-level baseline row (e.g. "
                         "sparse_crossover) from the fresh artifacts, leaving "
                         "every other byte of the baseline file identical")
    args = ap.parse_args()

    artifact = _load(args.artifact)
    serving = (_load(args.serving_artifact)
               if os.path.exists(args.serving_artifact) else None)
    adaptive = (_load(args.adaptive_artifact)
                if os.path.exists(args.adaptive_artifact) else None)
    faults = (_load(args.faults_artifact)
              if os.path.exists(args.faults_artifact) else None)
    topk = (_load(args.topk_artifact)
            if os.path.exists(args.topk_artifact) else None)
    sparse = (_load(args.sparse_artifact)
              if os.path.exists(args.sparse_artifact) else None)
    if args.rebaseline and args.rebaseline_row:
        raise SystemExit("--rebaseline and --rebaseline-row are exclusive")
    if args.rebaseline:
        rebaseline(artifact, args.baseline, serving=serving, adaptive=adaptive,
                   faults=faults, topk=topk, sparse=sparse)
        return
    if args.rebaseline_row:
        rebaseline_row(args.rebaseline_row, artifact, args.baseline,
                       serving=serving, adaptive=adaptive, faults=faults,
                       topk=topk, sparse=sparse)
        return
    baseline = _load(args.baseline)
    fails = check(artifact, baseline)
    if "serving_hdc" in baseline:
        if serving is None:
            fails.append(f"serving_hdc baseline set but {args.serving_artifact}"
                         " missing — run benchmarks.serving --hdc first")
        else:
            fails.extend(check_serving(serving, baseline))
    if "serving_adaptive" in baseline:
        if adaptive is None:
            fails.append("serving_adaptive baseline set but "
                         f"{args.adaptive_artifact} missing — run "
                         "benchmarks.serving --drift first")
        else:
            fails.extend(check_adaptive(adaptive, baseline))
    if "serving_faults" in baseline:
        if faults is None:
            fails.append("serving_faults baseline set but "
                         f"{args.faults_artifact} missing — run "
                         "benchmarks.faults first")
        else:
            fails.extend(check_faults(faults, baseline))
    if "serving_topk" in baseline:
        if topk is None:
            fails.append("serving_topk baseline set but "
                         f"{args.topk_artifact} missing — run "
                         "benchmarks.topk --fast first")
        else:
            fails.extend(check_topk(topk, baseline))
    if "sparse_crossover" in baseline:
        if sparse is None:
            fails.append("sparse_crossover baseline set but "
                         f"{args.sparse_artifact} missing — run "
                         "benchmarks.sparse --fast first")
        else:
            fails.extend(check_sparse(sparse, baseline))
    if fails:
        print("PERF REGRESSION vs BENCH_BASELINE.json:")
        for f in fails:
            print("  -", f)
        sys.exit(1)
    print("perf gate OK: no byte regressions, trials/s above baseline floors")


if __name__ == "__main__":
    main()
