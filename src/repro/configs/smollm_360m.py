"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — llama-arch small dense GQA.

32L d_model=960 15H (GQA kv=5, head_dim 64) d_ff=2560 vocab=49152, tied embeddings.
Sharding: 15 heads don't divide the 16-way model axis -> FSDP (embed dim over
"data") + TP on the MLP/vocab dims.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
    rules_override={"embed": "data", "kv_seq": "model"},
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_ff=256,
        vocab=512, loss_chunk=64, remat=False,
    )
