from repro.serving.engine import (  # noqa: F401
    ChunkedPrefill,
    ContinuousEngine,
    Engine,
    ServeConfig,
)
from repro.serving.hdc import (  # noqa: F401
    AdaptiveHDCEngine,
    FaultController,
    FaultControllerConfig,
    FaultTolerantHDCEngine,
    HDCCompletion,
    HDCEngine,
    HDCRequest,
    HDCScheduler,
    LinkController,
    LinkControllerConfig,
    TenantRegistry,
)
from repro.serving.scheduler import (  # noqa: F401
    Completion,
    Request,
    Scheduler,
    SlotScheduler,
)
from repro.serving.slotring import SlotRingEngine, slot_update  # noqa: F401
