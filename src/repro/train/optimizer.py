"""Optimizers: AdamW (ZeRO-1 shardable, configurable state dtype) and
majority-vote signSGD — the paper's OTA bundling applied to gradients.

`sign_majority_momentum` consumes gradients that were already majority-voted
across the data axes by `distributed.collectives.sign_allreduce` (values in
{-1, 0, +1}); it applies momentum + sign update (signum). This is the
beyond-paper integration: the 1-bit lossy reduce-broadcast collective of the
wireless HDC chip, re-targeted at DP gradient synchronization (32× less DP
traffic, BER-tolerant like the HDC classifier).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | sign_majority
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    state_dtype: Any = jnp.float32  # bf16 for 1T-param configs (kimi-k2)
    momentum: float = 0.9           # sign_majority
    grad_clip: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(cfg: OptConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(cfg.state_dtype),
            v32.astype(cfg.state_dtype),
        )

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "gnorm": gnorm}


# ---------------------------------------------------------------------------
# majority-vote signSGD (signum)
# ---------------------------------------------------------------------------

def sign_init(cfg: OptConfig, params):
    return {
        "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.state_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def sign_update(cfg: OptConfig, votes, state, params):
    """votes: majority-voted gradient signs in {-1, 0, +1} (post sign_allreduce)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)

    def upd(g, m, p):
        m32 = cfg.momentum * m.astype(jnp.float32) + (1 - cfg.momentum) * g.astype(jnp.float32)
        delta = jnp.sign(m32) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(cfg.state_dtype))

    out = jax.tree.map(upd, votes, state["mom"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mom": new_m, "step": step}, {"lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1: shard optimizer state over the data axes
# ---------------------------------------------------------------------------

def zero1_axes(param_axes):
    """Optimizer-state logical axes: param axes with the first replicated dim of
    every >=2-D tensor remapped to the 'fsdp' (pod+data) axes. Non-dividing dims
    are dropped automatically by the rules engine, so this is always safe."""

    def one(axes):
        axes = list(axes)
        for i, a in enumerate(axes):
            if a is None and len(axes) >= 2:
                axes[i] = "fsdp"
                break
        else:
            if all(a is not None for a in axes) and len(axes) >= 2:
                return tuple(axes)  # fully sharded already
        return tuple(axes)

    return jax.tree.map(
        one, param_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
