"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def save(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def timed(fn, *args, **kw):
    """Wall-time fn(*args, **kw), blocking on any device results first —
    without the block, JAX's async dispatch makes this measure enqueue time."""
    t0 = time.time()
    out = fn(*args, **kw)
    import jax

    jax.block_until_ready(out)
    return out, time.time() - t0


def timed_reps(fn, reps: int, *args, **kw):
    """`reps` back-to-back timed calls -> per-rep variance statistics.

    Returns (out_of_last_rep, stats) with stats = {"mean_s", "min_s", "max_s",
    "std_s", "reps"} over the individual rep wall-times. Regression gates
    compare against mean_s; min/max/std travel in the artifact so a noisy
    host (max >> min) is visible when a gate trips, instead of masquerading
    as a real slowdown.
    """
    times = []
    out = None
    for _ in range(reps):
        out, dt = timed(fn, *args, **kw)
        times.append(dt)
    mean = sum(times) / len(times)
    var = sum((t - mean) ** 2 for t in times) / len(times)
    return out, {
        "mean_s": mean,
        "min_s": min(times),
        "max_s": max(times),
        "std_s": var ** 0.5,
        "reps": reps,
    }
