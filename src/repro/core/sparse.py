"""Ultra-sparse hypervectors as fixed-capacity sorted index lists.

At d up to 10^6 and ~0.1% density the dense representations stop making sense:
an unpacked HV is d bytes, a packed one d/32 words, but only k = density*d
(~10^3) bits are ever set. This module stores such an HV as the sorted int32
list of its SET indices, padded to a fixed capacity ``k_max`` with
``SENTINEL`` (2^31 - 1) — fixed shape, so it jits, vmaps, and shards through
``shard_map`` exactly like a dense array, while every algebra op below is
O(k_max log k_max) independent of d:

* **bind**   — sorted-merge symmetric difference (XOR semantics on index sets);
* **bundle** — sorted-union run counts + strict-majority threshold
  (``count*2 > m``), matching `hv.majority`'s repo-wide tie convention;
* **permute**— index add mod d + re-sort (cyclic shift rho^s);
* **flip_bits_sparse** — BSC noise as per-index drop + fresh-index insertion,
  with an RNG-matched DENSE reference (`flip_bits_sparse_ref`) in this module:
  the sparse path and the reference consume the identical PRNG draws, so the
  property tests pin them bit-exact (a reference against `hv.flip_bits` is
  structurally impossible in O(k): a faithful BSC inserts ~ber*d fresh bits,
  which at d=10^6 exceeds any useful k_max — the sparse channel model is the
  drop+insert process itself, and the dense oracle replays it).

**Saturation** is defined canonically everywhere: whenever a result has more
than k_max set indices, the k_max SMALLEST survive (== `sparsify`'s
truncation), so sparse ops compose deterministically and the dense references
can reproduce the truncation exactly. The empty HV is all-SENTINEL.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Padding value for unused capacity slots. A Python int on purpose: a
# module-level jnp scalar would be closed over as a compile-time constant
# array and break donation/caching in surprising ways.
SENTINEL = 2**31 - 1


def valid(idx: jax.Array) -> jax.Array:
    """Boolean mask of live entries (True where the slot holds a real index)."""
    return idx != jnp.int32(SENTINEL)


def count(idx: jax.Array) -> jax.Array:
    """Number of set indices per HV: int32 [...] from idx [..., k_max]."""
    return jnp.sum(valid(idx), axis=-1).astype(jnp.int32)


def sparsify(bits: jax.Array, k_max: int) -> jax.Array:
    """Dense uint8 {0,1} [..., d] -> sorted index list int32 [..., k_max].

    Keeps the k_max smallest set indices when the HV has more than k_max set
    bits — the canonical saturation rule every op in this module follows.
    """
    d = bits.shape[-1]
    iota = jnp.arange(d, dtype=jnp.int32)
    masked = jnp.where(bits != 0, iota, jnp.int32(SENTINEL))
    return jnp.sort(masked, axis=-1)[..., :k_max]


def densify(idx: jax.Array, d: int) -> jax.Array:
    """Sorted index list int32 [..., k_max] -> dense uint8 {0,1} [..., d]."""
    k_max = idx.shape[-1]
    lead = idx.shape[:-1]
    # route sentinels to a scratch column d, sliced away after the scatter
    pos = jnp.minimum(idx, jnp.int32(d))
    flat = pos.reshape(-1, k_max)

    def one(p):
        return jnp.zeros((d + 1,), jnp.uint8).at[p].set(1)[:d]

    return jax.vmap(one)(flat).reshape(lead + (d,))


def random_sparse(key: jax.Array, num: int, dim: int, k_max: int,
                  density: float) -> jax.Array:
    """`num` i.i.d. sparse HVs: each bit set i.i.d. w.p. `density`, sparsified.

    The O(d) dense draw happens ONCE at setup (codebook construction); the
    serve/classify hot paths never touch a [*, d] tensor again.
    """
    bits = jax.random.bernoulli(key, density, (num, dim)).astype(jnp.uint8)
    return sparsify(bits, k_max)


def _compact(idx: jax.Array, keep: jax.Array, k_max: int) -> jax.Array:
    """Keep masked entries, push the rest to SENTINEL, re-sort, truncate."""
    cleaned = jnp.where(keep, idx, jnp.int32(SENTINEL))
    return jnp.sort(cleaned, axis=-1)[..., :k_max]


def bind(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sparse bind (XOR semantics): symmetric difference of the index sets.

    a, b: int32 [..., k_max] sorted sentinel-padded -> [..., k_max]. An index
    present in both operands cancels; one present in exactly one survives.
    O(k_max log k_max); saturation keeps the k_max smallest survivors.
    """
    k_max = a.shape[-1]
    merged = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
    prev = jnp.concatenate(
        [jnp.full(merged.shape[:-1] + (1,), -1, jnp.int32), merged[..., :-1]],
        axis=-1)
    nxt = jnp.concatenate(
        [merged[..., 1:],
         jnp.full(merged.shape[:-1] + (1,), -2, jnp.int32)], axis=-1)
    # within one HV indices are unique, so a value appears at most twice in
    # the merge: exactly-once == differs from both neighbours
    keep = (merged != prev) & (merged != nxt) & valid(merged)
    return _compact(merged, keep, k_max)


def bundle(stack: jax.Array, m: int | jax.Array | None = None) -> jax.Array:
    """Sparse majority bundling over the second-to-last axis.

    stack: int32 [..., M, k_max] sorted sentinel-padded -> [..., k_max]. An
    index survives iff it appears in a strict majority of the `m` voters
    (``count*2 > m``, the repo-wide even-tie -> 0 convention of
    `hv.majority` / the serve path's ``tally > 0``). `m` defaults to the
    stacked voter count M; pass a smaller (possibly traced) `m` when some
    slots abstain — abstaining voters must be all-SENTINEL (empty) lists,
    which is exactly a dense all-zero vote.

    Run counting is a sort + two O(n) scans (no searchsorted, so it batches
    over arbitrary leading dims): after sorting the flattened union, each
    run's length is last_pos - first_pos + 1, computed with a forward cummax
    of run starts and a backward cummin of run ends.
    """
    m_stack = stack.shape[-2]
    k_max = stack.shape[-1]
    if m is None:
        m = m_stack
    n = m_stack * k_max
    s = jnp.sort(stack.reshape(stack.shape[:-2] + (n,)), axis=-1)
    prev = jnp.concatenate(
        [jnp.full(s.shape[:-1] + (1,), -1, jnp.int32), s[..., :-1]], axis=-1)
    nxt = jnp.concatenate(
        [s[..., 1:], jnp.full(s.shape[:-1] + (1,), -2, jnp.int32)], axis=-1)
    start = s != prev
    end = s != nxt
    pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), s.shape)
    first = jax.lax.cummax(jnp.where(start, pos, jnp.int32(-1)), axis=s.ndim - 1)
    last = jnp.flip(
        jax.lax.cummin(
            jnp.flip(jnp.where(end, pos, jnp.int32(n)), axis=-1),
            axis=s.ndim - 1),
        axis=-1)
    cnt = last - first + 1
    keep = start & valid(s) & (cnt * 2 > jnp.asarray(m, jnp.int32))
    return _compact(s, keep, k_max)


def permute(idx: jax.Array, shift: int | jax.Array, d: int) -> jax.Array:
    """Cyclic permutation rho^shift: index add mod d, re-sorted.

    Equals sparsify(hv.permute(densify(idx, d), shift), k_max) whenever the HV
    is unsaturated (a full cyclic shift never changes the set-bit count).
    """
    k_max = idx.shape[-1]
    shifted = jnp.where(valid(idx), (idx + jnp.asarray(shift, jnp.int32)) % d,
                        jnp.int32(SENTINEL))
    return jnp.sort(shifted, axis=-1)[..., :k_max]


def _union(a: jax.Array, b: jax.Array, k_max: int) -> jax.Array:
    """Sorted set union of two sentinel-padded lists, truncated to k_max."""
    merged = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
    prev = jnp.concatenate(
        [jnp.full(merged.shape[:-1] + (1,), -1, jnp.int32), merged[..., :-1]],
        axis=-1)
    keep = (merged != prev) & valid(merged)
    return _compact(merged, keep, k_max)


def _noise_draws(key: jax.Array, shape: tuple, ber, d: int, k_max: int):
    """The shared PRNG schedule of the sparse BSC and its dense reference."""
    kd, kp, ka = jax.random.split(key, 3)
    drop = jax.random.bernoulli(kd, ber, shape)
    pos = jax.random.randint(kp, shape, 0, d, dtype=jnp.int32)
    # each of the k_max insertion candidates is accepted w.p. p_ins so the
    # expected fresh-bit count matches the BSC's ber * (d - k) ~= ber * d
    # zero->one flips, capacity permitting
    p_ins = jnp.minimum(
        jnp.asarray(ber, jnp.float32) * (d / max(k_max, 1)), 1.0)
    acc = jax.random.bernoulli(ka, p_ins, shape)
    return drop, pos, acc


def flip_bits_sparse(key: jax.Array, idx: jax.Array, ber, d: int) -> jax.Array:
    """Sparse BSC: drop each set index w.p. `ber`, insert fresh ones.

    idx: int32 [..., k_max] -> [..., k_max]. The one->zero leg is exact
    (per-slot Bernoulli drop at `ber`); the zero->one leg draws k_max uniform
    candidate positions, each accepted w.p. ``min(1, ber*d/k_max)`` so the
    expected insertion count matches the dense BSC's ~ber*d fresh bits until
    capacity saturates. A candidate landing on a surviving index is absorbed
    (set union is idempotent); one landing on a just-dropped index re-inserts
    it. Bit-exact against `flip_bits_sparse_ref` on the same key (property
    tested), including saturation and the empty HV.
    """
    k_max = idx.shape[-1]
    drop, pos, acc = _noise_draws(key, idx.shape, ber, d, k_max)
    survivors = jnp.where(valid(idx) & ~drop, idx, jnp.int32(SENTINEL))
    inserts = jnp.where(acc, pos, jnp.int32(SENTINEL))
    return _union(survivors, inserts, k_max)


def flip_bits_sparse_ref(key: jax.Array, bits: jax.Array, ber,
                         k_max: int) -> jax.Array:
    """Dense oracle for `flip_bits_sparse`: same PRNG draws, scatter mechanics.

    bits: uint8 {0,1} [..., d] -> [..., d] with
    ``densify(flip_bits_sparse(key, sparsify(bits, k_max), ber, d), d)``
    equal bit-for-bit (the final sparsify/densify round-trip applies the
    canonical keep-smallest truncation when the result exceeds k_max).
    """
    d = bits.shape[-1]
    idx = sparsify(bits, k_max)
    drop, pos, acc = _noise_draws(key, idx.shape, ber, d, k_max)
    kept = densify(jnp.where(valid(idx) & ~drop, idx, jnp.int32(SENTINEL)), d)
    inserted = densify(jnp.where(acc, pos, jnp.int32(SENTINEL)), d)
    out = jnp.bitwise_or(kept, inserted)
    # canonical truncation: keep the k_max smallest set indices
    return densify(sparsify(out, k_max), d)


def overlap(idx: jax.Array, words: jax.Array) -> jax.Array:
    """|q AND p| between sparse queries and packed prototypes, O(k) per pair.

    idx: int32 [..., k_max]; words: uint32 [C, W] -> int32 [..., C]. Gathers
    the word holding each query index and tests its bit — the pure-jnp oracle
    for kernels/sparse (never materializes a dense [..., d] query).
    """
    v = valid(idx)
    w = jnp.where(v, idx // 32, 0)
    b = jnp.where(v, idx % 32, 0).astype(jnp.uint32)
    sel = jnp.take(words, w, axis=-1)  # [C, ..., k_max]
    hit = ((sel >> b) & jnp.uint32(1)).astype(jnp.int32) * v.astype(jnp.int32)
    ov = jnp.sum(hit, axis=-1)  # [C, ...]
    return jnp.moveaxis(ov, 0, -1)


def hamming_from_overlap(idx: jax.Array, words: jax.Array,
                         ov: jax.Array) -> jax.Array:
    """Hamming distance |q XOR p| = |q| + |p| - 2|q AND p|: int32 [..., C]."""
    pop = jnp.sum(jax.lax.population_count(words).astype(jnp.int32), axis=-1)
    return count(idx)[..., None] + pop - 2 * ov
