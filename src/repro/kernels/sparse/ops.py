"""Dispatch layer for the sparse-query similarity search.

Mirrors kernels/hamming/ops.py: each public op routes to the Pallas kernel
(interpret mode off-TPU) or to a streamed pure-jnp fallback that chunks the
class axis and keeps the running (min, argmin) carry chunk-local — the full
[G, B, C] distance tensor never exists, and neither does a dense [B, d]
query (the fallback's overlap is the same O(k_max) gather the kernel does,
via `repro.core.sparse.overlap`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparse
from repro.kernels import common
from repro.kernels.sparse.kernel import (
    sparse_search_pallas,
    sparse_topk_banked_pallas,
)

_SENTINEL = sparse.SENTINEL


def _pad_queries(q: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Pad the batch axis with all-sentinel (empty) query rows."""
    return common.pad_dim(q, axis, multiple, fill=_SENTINEL)


def _dist_chunk(q: jax.Array, chunk: jax.Array) -> jax.Array:
    """Distances of one class chunk: q [..., k], chunk [C', W] -> [..., C']."""
    return sparse.hamming_from_overlap(q, chunk, sparse.overlap(q, chunk))


def sparse_search(
    q: jax.Array, protos: jax.Array, *, bq: int | None = None,
    bc: int | None = None, interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Full sparse-vs-packed Hamming distances: q [B, k_max] int32 sorted
    sentinel-padded, protos [C, W] uint32 -> [B, C] int32.

    Integer-identical to `hamming_search(pack(densify(q)), protos)` — the
    classifier's top-m decision consumes these distances in place of the
    packed ones with no downstream change.
    """
    b, _ = q.shape
    c, _ = protos.shape
    if interpret is None:
        interpret = common.default_interpret()
    bq, bc = common.hamming_blocks(b, c, bq, bc)
    if not use_kernel:
        out = [
            _dist_chunk(q, protos[start:start + bc])
            for start in range(0, c, bc)
        ]
        return jnp.concatenate(out, axis=-1)
    qp = _pad_queries(q, 0, bq)
    pp = common.pad_dim(protos, 0, bc)
    dist = sparse_search_pallas(qp, pp, bq=bq, bc=bc, interpret=interpret)
    return dist[:b, :c]


def _streamed_topk_banked(
    q: jax.Array, protos: jax.Array, bc: int, key_encode: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Chunked per-bank top-1 without the kernel OR a [G, B, C] tensor.

    Same merge structure as the hamming streamed fallback: when the int32 key
    ``dist * C + col`` cannot overflow, one running min over encoded keys gives
    the exact first-minimum tie order; otherwise a two-reduction (value, index)
    carry with a strict `<` merge does.
    """
    g, b, _ = q.shape
    _, c, _ = protos.shape
    d = protos.shape[-1] * 32
    if key_encode is None:
        key_encode = (d + 1) * c < 2**31

    chunk_dist = jax.vmap(_dist_chunk)

    if key_encode:
        best_key = None
        for start in range(0, c, bc):
            chunk = protos[:, start:start + bc]
            dist = chunk_dist(q, chunk)  # [G, B, C']
            cols = start + jnp.arange(chunk.shape[1], dtype=jnp.int32)
            key = jnp.min(dist * c + cols, axis=-1)
            best_key = key if best_key is None else jnp.minimum(best_key, key)
        return best_key // c, best_key % c

    best_v = best_i = None
    for start in range(0, c, bc):
        chunk = protos[:, start:start + bc]
        dist = chunk_dist(q, chunk)
        cols = start + jnp.arange(chunk.shape[1], dtype=jnp.int32)
        v = jnp.min(dist, axis=-1)
        i = jnp.take_along_axis(
            jnp.broadcast_to(cols, dist.shape),
            jnp.argmin(dist, axis=-1)[..., None], -1
        )[..., 0]
        if best_v is None:
            best_v, best_i = v, i
        else:
            better = v < best_v
            best_i = jnp.where(better, i, best_i)
            best_v = jnp.where(better, v, best_v)
    return best_v, best_i


def sparse_topk_banked(
    q: jax.Array, protos: jax.Array, *, bq: int | None = None,
    bc: int | None = None, interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused per-bank sparse top-1: q [G, B, k_max] int32, protos [G, C, W]
    uint32 -> (min_dist, argmin), each [G, B] int32.

    Integer- and tie-identical to ``hamming_topk_banked`` on the densified
    queries (FIRST minimum wins), so the sparse serve path shares the packed
    serve's downstream — core/argmin/index arithmetic — unchanged.
    """
    _, b, _ = q.shape
    _, c, _ = protos.shape
    if interpret is None:
        interpret = common.default_interpret()
    bq, bc = common.hamming_blocks(b, c, bq, bc)
    if not use_kernel:
        return _streamed_topk_banked(q, protos, bc)
    qp = _pad_queries(q, 1, bq)
    pp = common.pad_dim(protos, 1, bc)
    val, idx = sparse_topk_banked_pallas(
        qp, pp, c_real=c, bq=bq, bc=bc, interpret=interpret)
    return val[:, :b], idx[:, :b]
