"""Distributed scale-out of IMC-based HDC similarity search (paper Fig. 3b).

Mapping of the paper's architecture onto the production TPU mesh:

* **encoders (TXs)** — the ``model`` mesh axis carries the encoder slots; encoder
  *g* lives co-located with model column ``g // e_per`` (``e_per = ceil(m_tx /
  model_size)`` encoders per column, so any M up to the paper's 11 TXs fits any
  mesh). Unoccupied slots abstain (vote 0).
* **OTA majority bundling** — one ``psum`` of int8 bipolar votes over the ``model``
  axis (`distributed.collectives.majority_allreduce`): the all-to-one reduction and
  one-to-all broadcast collapse into a single collective, exactly the paper's
  over-the-air computation. Payload is 1 byte/element (conceptually 1 bit);
  ``collective="psum_packed"`` shrinks it further with guard-bit field packing
  (`collectives.packed_vote_allreduce` — several votes per uint32 lane, ONE
  uint32 psum, bit-identical tally).
* **N IMC cores (RXs)** — the associative memory (C prototype hypervectors) is
  sharded over ``model``; each shard subdivides its classes among
  ``cores_per_shard`` IMC cores, and *each core decodes its own noisy copy* of the
  bundled query through the pluggable PHY tier (``repro.phy``): ``bsc`` flips at
  the pre-characterized BER of the EM + constellation pipeline (``core.em`` /
  ``core.ota`` — the paper's Eq. 1 abstraction, the default), ``symbol`` runs the
  actual constellation + AWGN + decision-region physics in-graph, ``ideal`` is
  error-free — "each RX receives a slightly different version of Q". The
  precharacterization travels as a ``phy.ChannelState`` pytree sharded with the
  cores.
* **similarity search** — local bipolar dot products (the IMC crossbar MVM;
  Pallas ``assoc_matmul`` on TPU) + a tiny all-gather of per-shard (value, index)
  pairs for the global top-1.
* trials are batched over the ``data`` (and ``pod``) axes.

``make_wired_serve`` implements the *wired-baseline* dataflow the paper argues
against: queries are all-gathered to every core (the NoC broadcast), then bundled
locally — same math, M·(model_size)× the collective bytes. The roofline benchmark
contrasts the two HLOs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat, phy
from repro import faults as faultlib
from repro.core import em, hypervector as hv, ota, sparse
from repro.distributed import collectives
from repro.kernels.assoc_matmul import assoc_matmul
from repro.kernels.hamming import hamming_search, hamming_topk_banked
from repro.kernels.majority import majority_bundle
from repro.kernels.sparse import sparse_topk_banked


@dataclasses.dataclass(frozen=True)
class ScaleOutConfig:
    n_classes: int = 6400        # total classes across all IMC cores
    dim: int = 512               # hypervector dimensionality
    m_tx: int = 3                # simultaneous transmitters (<= model mesh size)
    n_rx_cores: int = 64         # physical IMC cores (multiple of model mesh size)
    snr_db: float = 7.0          # OTA operating point (see ota.default_n0)
    permuted: bool = False       # permuted bundling (per-TX cyclic signature)
    use_kernels: bool = True     # Pallas fast path (interpret on CPU)
    batch: int = 256             # global trial batch
    collective: str = "psum"     # OTA realization: "psum" (paper-faithful single
    #   fused collective, int8 all-reduce) | "psum_packed" (same single
    #   all-reduce with guard-bit field packing: votes biased non-negative,
    #   k = 32 // ceil(log2(2*S*e_per + 1)) per uint32 lane, ONE uint32 psum —
    #   bit-identical tally, ~2x less wire traffic at M=3 on a 4-wide model
    #   axis) | "rs_ag" (beyond-paper: reduce-scatter the votes (guard-bit
    #   packed when d tiles into lanes), threshold the local d/S shard,
    #   bit-pack to uint8, all-gather d/8 bytes; see EXPERIMENTS.md §Perf)
    representation: str = "unpacked"  # HV storage on the serve path: "unpacked"
    #   (uint8 {0,1}, fp32 bipolar MXU similarity) | "packed" (uint32 words,
    #   XOR+popcount similarity — how the IMC macro actually stores a row; d/8
    #   bytes per HV, prediction-identical to unpacked on the same RNG stream)
    #   | "sparse" (ultra-sparse index lists, `core.sparse`: queries travel as
    #   k_max sorted int32 bit indices — 4*k_max bytes per HV regardless of d,
    #   the regime d ~ 10^6 at ~0.1% density where dense words blow VMEM and
    #   wire; prototypes stay packed words and the top-1 is the gather-overlap
    #   kernels/sparse family, distance-identical to the packed scan) | "auto"
    #   (resolve_representation picks sparse vs packed per (dim, k_max) from
    #   the measured density crossover, cached per workload)
    noise: str = "exact"         # packed-path BSC mask source: "exact" (pack the
    #   same Bernoulli draw as the unpacked path — bit-identical, used for the
    #   parity tests) | "bitplane" (draw uint32 mask words directly via a
    #   bit-sliced comparator — `noise_planes` random bits per mask bit instead
    #   of the 32 the unpacked Bernoulli pays). Unpacked representation always
    #   draws the plain Bernoulli mask.
    noise_planes: int = 16       # bitplane-mode mask precision: BER quantized to
    #   2^-planes. 8 is plenty for the paper's operating points (BER 1e-2..1e-1
    #   against an accuracy curve that is flat out to BER 0.26, Fig. 10) and
    #   halves the mask-generation traffic again; 16 is the conservative default.
    channel: str = "bsc"         # PHY fidelity tier (repro.phy): "ideal" (error-
    #   free link) | "bsc" (default: per-core BSC at the precharacterized Eq. 1
    #   BER — the paper's abstraction, bit-identical to the historical serve
    #   noise on the same RNG stream) | "symbol" (full physics in-graph: ONE
    #   int32 psum of the per-dimension TX bit-combo == the constellation
    #   superposition, then per-core AWGN + decision-region decode; requires a
    #   real ChannelState from `precharacterize_state` and collective="psum")
    coarse_group: int = 0        # two-level coarse-to-fine search (0 = flat
    #   scan). >0 groups each core's class rows into contiguous blocks of
    #   `coarse_group` and summarizes every block with its strict-majority
    #   bundle; the serve screens the C_core/coarse_group summaries first
    #   (fused top-k kernel / lax.top_k), keeps the best `coarse_keep` groups
    #   per (core, query), and runs the exact scan ONLY on the survivors —
    #   the per-core class-axis work drops from C_core to
    #   C_core/coarse_group + coarse_keep*coarse_group. Summaries are
    #   recomputed in-graph from the (post-stuck-mask) resident rows each
    #   step (C x W word-ops, negligible against the B x C x W search), so
    #   the coarse path composes with faults/tenant onboarding with no new
    #   serve inputs and no recompile. Baseline bundling only (permuted banks
    #   would need one summary set per TX signature); must divide
    #   n_classes/n_rx_cores.
    coarse_keep: int = 8         # surviving groups per (core, query) — the
    #   screen's recall knob (clamped to the group count; keep == group count
    #   is bit-identical to the flat scan). Survivors are rescored in
    #   ascending class order, so whenever the flat winner survives the screen
    #   the prediction AND maxsim are bit-identical to the flat scan.
    k_max: int = 0               # sparse index-list capacity (sparse/auto
    #   representations only): each HV carries at most k_max set-bit indices
    #   (sorted int32, SENTINEL-padded — `core.sparse`). Pick k_max with
    #   headroom over density*dim (the bundle of M sparse HVs can hold up to
    #   the union of their indices before majority thresholding); results
    #   saturate to the k_max smallest indices, deterministically.
    m_active: int | None = None  # link-adaptation M-drop: only the first
    #   m_active TXs transmit (others abstain); None = all m_tx. Must be odd
    #   (majority ties) and needs a vote-wire tier — the symbol tier's
    #   constellation assumes all M TXs superpose. A single-TX bundle (M=1)
    #   IS the class hypervector: maximum per-bit noise margin, the
    #   controller's deepest fallback under a degraded link. Query/prediction
    #   SHAPES are unchanged (compile-once across M switches); in permuted
    #   mode only the first m_active prediction columns are meaningful.

    @property
    def packed(self) -> bool:
        return self.representation == "packed"

    @property
    def sparse(self) -> bool:
        return self.representation == "sparse"

    @property
    def m_act(self) -> int:
        return self.m_tx if self.m_active is None else self.m_active

    @property
    def words(self) -> int:
        assert self.dim % hv.WORD == 0, (self.dim, hv.WORD)
        return self.dim // hv.WORD

    def __post_init__(self):
        # unsupported combos fail HERE with a clear message, not deep inside a
        # kernel trace (mirrors the coarse-vs-permuted rejection)
        if self.representation in ("sparse", "auto"):
            if self.k_max <= 0:
                raise ValueError(
                    f"representation={self.representation!r} needs k_max > 0 "
                    "(the sparse index-list capacity); got "
                    f"k_max={self.k_max}"
                )
            if self.permuted:
                raise ValueError(
                    "representation='sparse' requires baseline bundling "
                    "(permuted TX signatures would need per-bank sparse "
                    "searches); set permuted=False"
                )
            if self.coarse_group:
                raise ValueError(
                    "representation='sparse' does not compose with the "
                    "coarse-to-fine screen (group summaries are dense "
                    "majority bundles); set coarse_group=0"
                )
            if self.collective not in ("index_ag", "psum", "psum_packed"):
                raise ValueError(
                    f"collective={self.collective!r} has no sparse wire "
                    "format; sparse serves use 'index_ag' (index-coded "
                    "all-gather) or the dense fallbacks 'psum'/'psum_packed'"
                )
            if self.channel not in ("ideal", "bsc"):
                raise ValueError(
                    f"channel={self.channel!r} is not available for the "
                    "sparse representation (the symbol tier decodes dense "
                    "per-dimension fields); use 'ideal' or 'bsc'"
                )
        elif self.collective == "index_ag":
            raise ValueError(
                "collective='index_ag' is the sparse index-list wire; "
                f"representation={self.representation!r} has no index lists "
                "to gather (use representation='sparse' or a vote collective)"
            )


# ---------------------------------------------------------------------------
# density-crossover autotuner (representation="auto")
# ---------------------------------------------------------------------------

# Built-in sparse-vs-packed crossover: sparse wins below this query density
# (k_max / dim). The analytic wire-parity point is density 1/32 (k_max int32
# indices == d/32 packed words == the guard-bit field); the MEASURED compute
# crossover from benchmarks/sparse.py (EXPERIMENTS.md §Sparse-crossover) sits
# at the same order, so the shipped default is the conservative wire-parity
# density. `set_crossover_table` installs a freshly fitted table.
DEFAULT_CROSSOVER = {"density": 1.0 / 32.0}
_crossover_table = dict(DEFAULT_CROSSOVER)
_AUTO_CACHE: dict[tuple[int, int], str] = {}


def set_crossover_table(table: dict | None) -> None:
    """Install a measured crossover fit ({"density": float}); None restores
    the built-in DEFAULT_CROSSOVER. Clears the per-workload cache."""
    global _crossover_table
    _crossover_table = dict(DEFAULT_CROSSOVER if table is None else table)
    _AUTO_CACHE.clear()


def resolve_representation(cfg: ScaleOutConfig) -> ScaleOutConfig:
    """Materialize ``representation="auto"`` into "sparse" or "packed".

    Decision rule: sparse wins when the query density ceiling ``k_max / dim``
    is below the fitted crossover density; cached per (dim, k_max) so repeat
    builds of the same workload never re-decide. The resolved config also
    carries the representation's native wire — ``index_ag`` (4*k_max bytes/HV)
    for sparse, ``psum_packed`` (guard-bit field) for packed. Non-auto configs
    pass through untouched.
    """
    if cfg.representation != "auto":
        return cfg
    key = (cfg.dim, cfg.k_max)
    rep = _AUTO_CACHE.get(key)
    if rep is None:
        rep = ("sparse" if cfg.k_max / cfg.dim < _crossover_table["density"]
               else "packed")
        _AUTO_CACHE[key] = rep
    coll = "index_ag" if rep == "sparse" else "psum_packed"
    return dataclasses.replace(cfg, representation=rep, collective=coll)


def precharacterize_state(
    cfg: ScaleOutConfig, geom: em.PackageGeometry | None = None
) -> phy.ChannelState:
    """Full channel precharacterization -> `phy.ChannelState` pytree.

    This is the paper's offline CST + MATLAB step: deterministic given the
    package geometry ("quasi-static and known a priori"). The returned state
    carries everything every PHY tier needs — Eq. 1 per-RX BER + validity for
    ``bsc``, the channel matrix / phase assignment / constellation / decision
    centroids / N0 for ``symbol``.
    """
    geom = geom or em.PackageGeometry()
    h = em.channel_matrix(geom, cfg.m_tx, cfg.n_rx_cores)
    n0 = ota.default_n0(h, cfg.snr_db)
    if cfg.m_tx <= 3:
        res = ota.optimize_phases_exhaustive(h, n0)
    else:
        res = ota.optimize_phases_coordinate(h, n0, jax.random.PRNGKey(0))
    return phy.state_from_ota(res, h)


def precharacterize(cfg: ScaleOutConfig) -> jnp.ndarray:
    """Per-IMC-core BER [n_rx_cores] — the Eq. 1 summary of
    `precharacterize_state` (kept for BER-only consumers; the serve steps take
    the full ChannelState)."""
    return precharacterize_state(cfg).ber


# ---------------------------------------------------------------------------
# mesh-level serve steps
# ---------------------------------------------------------------------------

def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _local_search(q: jax.Array, protos: jax.Array, use_kernels: bool) -> jax.Array:
    """Bipolar similarity dots [B_l, C_l] — the IMC crossbar MVM."""
    return assoc_matmul(q, protos, use_kernel=use_kernels, bm=8)


# ---------------------------------------------------------------------------
# serve-step stages (shared by the standalone and multi-tenant serves)
#
# Each stage runs INSIDE the shard_map body on one model shard. They are the
# verbatim standalone dataflow, generalized to arbitrary leading row dims
# (axis=-2 encoder sums, shape[:-1] reshapes) so the multi-tenant serve can
# flatten its [N_slots, B] rows through the same collectives — elementwise
# over rows, hence bit-identical per row to a standalone serve of that row.
# ---------------------------------------------------------------------------

def _tx_ids(cfg: ScaleOutConfig, e_per: int):
    """This column's encoder slots: (column index, global encoder ids [e_per],
    live-voter count — slots with gid >= m_act abstain, which folds the
    link-adaptation M-drop into the same abstention mechanism as the unused
    mesh slots)."""
    tx = jax.lax.axis_index("model")
    gids = tx * e_per + jnp.arange(e_per)
    n_act_local = jnp.clip(cfg.m_act - tx * e_per, 0, e_per)
    return tx, gids, n_act_local


def _dpos(mesh: Mesh, dp: tuple[str, ...]):
    """Flat data-parallel position (pod-major) — the per-shard RNG fold."""
    if not dp:
        return jnp.int32(0)
    if len(dp) == 1:
        return jax.lax.axis_index(dp[0])
    return (
        jax.lax.axis_index(dp[0]) * mesh.axis_sizes[mesh.axis_names.index(dp[1])]
        + jax.lax.axis_index(dp[1])
    )


def _ota_bundle(cfg: ScaleOutConfig, chan, model_size: int, e_per: int,
                q_mine, gids, n_act_local, fstate=None):
    """The OTA collective over the encoder/model axis.

    q_mine [..., e_per, d|W] (any leading row dims) -> bundled query
    [..., d|W] (or [..., d] int32 combo index for wire == "combo"). Elementwise
    over the leading rows, so flattened multi-slot batches tally bit-identically
    to per-row standalone calls.

    ``fstate`` (a `faults.FaultState`, TX-side leaves replicated) erases dead
    or dropped encoder slots from the superposition. Vote wire: the erased
    slot votes exact 0 (the abstention mechanism), the live local/total voter
    counts become traced (`total_active` re-bias of the guard-bit
    collectives), and ``tally > 0`` is automatically the live majority.
    Combo wire: the erased encoder is a stuck carrier radiating its bit-0
    phase, so its combo bit is forced 0 — the received symbol is still an
    exact constellation row (see `faults.recenter_state` for the decoder-side
    refit). With the all-healthy state every adjustment is a value identity.
    """
    d = cfg.dim
    packed = cfg.packed
    active = (gids < cfg.m_act)[:, None]
    q_bits = hv.unpack(q_mine, d) if packed else q_mine
    total_active = None
    if fstate is not None:
        erased = (fstate.dead_tx | fstate.vote_drop)[gids]      # [e_per]
        if chan.wire == "combo":
            q_bits = jnp.where(erased[:, None], jnp.uint8(0), q_bits)
        else:
            live = (gids < cfg.m_act) & ~erased
            active = active & ~erased[:, None]
            n_act_local = jnp.sum(live.astype(jnp.int32))
            slots = jnp.arange(fstate.m_slots)
            live_all = (slots < cfg.m_act) & ~(fstate.dead_tx | fstate.vote_drop)
            total_active = jnp.sum(live_all.astype(jnp.int32))
    if chan.wire == "combo":
        # physical superposition: the summed combo index IS the received
        # field (phy.channel module docstring) — ONE psum, the same
        # single-collective shape as the paper's OTA reduction. Columns
        # contribute disjoint bit ranges, so the sum stays < 2^M and the
        # wire dtype is the smallest int that fits it: at the paper's
        # M <= 7 the combo psum costs the SAME bytes as the int8 votes.
        weights = jnp.where(
            gids < cfg.m_tx, jnp.int32(1) << jnp.minimum(gids, 30), 0
        )
        partial = jnp.sum(
            q_bits.astype(jnp.int32) * weights[:, None], axis=-2
        )
        cdt = (jnp.int8 if cfg.m_tx <= 7
               else jnp.int16 if cfg.m_tx <= 15 else jnp.int32)
        return jax.lax.psum(partial.astype(cdt), "model").astype(
            jnp.int32)  # [..., d] combo index
    # bipolar majority votes; abstaining slots (g >= m_tx) vote exact 0
    votes = jnp.sum(
        jnp.where(active, 2 * q_bits.astype(jnp.int8) - 1, 0), axis=-2
    ).astype(jnp.int8)
    if cfg.collective in ("psum", "psum_packed"):
        if cfg.collective == "psum":  # paper-faithful: ONE all-reduce
            tally = jax.lax.psum(votes, "model")
        else:  # guard-bit packed votes sized by the M live voters:
            # ONE uint32 psum, bit-identical tally
            tally = collectives.packed_vote_allreduce(
                votes, "model", group_size=model_size, e_per=e_per,
                n_active=cfg.m_act, local_active=n_act_local,
                total_active=total_active,
            )
        bundled_bits = (tally > 0).astype(jnp.uint8)  # even-M ties -> 0
        return hv.pack(bundled_bits) if packed else bundled_bits
    elif cfg.collective == "rs_ag":
        # reduce-scatter the votes (guard-bit packed lanes when d tiles
        # evenly — each core tallies a d/S shard), threshold locally,
        # bit-pack, all-gather d/8 packed bytes.
        if packed:
            # the gathered uint32 words ARE the bundled packed query —
            # no unpack/repack round-trip after the collective.
            assert d % (model_size * hv.WORD) == 0, (d, model_size)
            part = collectives.packed_vote_psum_scatter(
                votes, "model", group_size=model_size, e_per=e_per,
                n_active=cfg.m_act, local_active=n_act_local,
                total_active=total_active,
            )
            words = hv.pack((part > 0).astype(jnp.uint8))  # [..., W/S]
            return jax.lax.all_gather(
                words, "model", axis=words.ndim - 1, tiled=True
            )
        assert d % (model_size * 8) == 0, (d, model_size)
        part = collectives.packed_vote_psum_scatter(
            votes, "model", group_size=model_size, e_per=e_per,
            n_active=cfg.m_act, local_active=n_act_local,
            total_active=total_active,
        )
        bits = (part > 0).astype(jnp.uint8)          # [..., d/S]
        w = bits.reshape(bits.shape[:-1] + (-1, 8))
        packed8 = jnp.sum(w << jnp.arange(8, dtype=jnp.uint8), axis=-1).astype(jnp.uint8)
        allbytes = jax.lax.all_gather(
            packed8, "model", axis=packed8.ndim - 1, tiled=True
        )
        return (
            (allbytes[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
        ).reshape(bits.shape[:-1] + (d,)).astype(jnp.uint8)
    raise ValueError(cfg.collective)


def _rx_fanout(cfg: ScaleOutConfig, chan, cores_per_shard: int, tx,
               q_bundled, state, kq):
    """Per-core decode through the PHY tier: each of this shard's IMC cores
    receives its own noisy copy of the bundled query."""
    return chan.rx_copies(
        kq, q_bundled, state, rx_base=tx * cores_per_shard,
        n_cores=cores_per_shard, packed=cfg.packed, dim=cfg.dim,
        noise=cfg.noise, planes=cfg.noise_planes,
    )


def _sparse_bundle(cfg: ScaleOutConfig, chan, model_size: int, e_per: int,
                   q_mine, gids, n_act_local):
    """The OTA collective for sparse index-list queries.

    q_mine [..., e_per, k_max] int32 -> bundled [..., k_max] int32.

    ``index_ag``: each column all-gathers its slots' raw index lists
    (`collectives.sparse_index_allgather` — 4*k_max bytes per slot per HV,
    independent of d, the whole point at d ~ 10^6), then every shard runs the
    identical O(k log k) sparse majority locally. Abstaining slots (gid >=
    m_act and the sentinel-padded mesh slots) are emptied to all-SENTINEL —
    exactly a dense all-zero vote — and the strict threshold runs at
    m = m_act, so the surviving index set equals the dense ``tally > 0``
    majority wherever the union fits k_max (saturation keeps the k_max
    smallest, the canonical rule).

    ``psum``/``psum_packed``: dense fallback for the crossover benchmark —
    densify, run the verbatim `_ota_bundle` vote wire, re-sparsify.
    """
    if cfg.collective == "index_ag":
        stack = collectives.sparse_index_allgather(q_mine, "model")
        # [..., S*e_per, k_max]; slot s holds global encoder id s's list
        n_slots = model_size * e_per
        active = (jnp.arange(n_slots) < cfg.m_act)[:, None]
        stack = jnp.where(active, stack, jnp.int32(sparse.SENTINEL))
        return sparse.bundle(stack, m=cfg.m_act)
    q_bits = sparse.densify(q_mine, cfg.dim)
    bits = _ota_bundle(cfg, chan, model_size, e_per, q_bits, gids,
                       n_act_local, None)
    return sparse.sparsify(bits, cfg.k_max)


def _sparse_rx_fanout(cfg: ScaleOutConfig, cores_per_shard: int, tx,
                      q_bundled, state, kq):
    """Per-core sparse decode — the index-list analogue of `_rx_fanout`.

    ``ideal`` broadcasts the bundled list; ``bsc`` applies the O(k)
    drop+insert channel (`sparse.flip_bits_sparse`) at each core's
    precharacterized Eq. 1 BER, on the SAME per-core key schedule as
    `phy.BSCChannel.rx_copies` (``fold_in(kq, rx_base + i)``) — so switching
    a workload between dense and sparse never perturbs any OTHER core's RNG
    stream.
    """
    if cfg.channel == "ideal":
        return jnp.broadcast_to(
            q_bundled[None], (cores_per_shard,) + q_bundled.shape)
    rx_base = tx * cores_per_shard

    def one(i, ber):
        k = jax.random.fold_in(kq, rx_base + i)
        return sparse.flip_bits_sparse(k, q_bundled, ber, cfg.dim)

    return jax.vmap(one)(jnp.arange(cores_per_shard), state.ber)


def _apply_stuck(rows_arr, stuck, d: int, packed: bool, core_axis: int):
    """Force stuck prototype bits to their rail, per physical core.

    rows_arr: stored rows with the core axis at ``core_axis`` and the
    dimension words/bits last; stuck = (stuck0, stuck1) [n_core, W] packed
    column masks (a stuck crossbar column hits every row the core stores —
    including all permuted banks, which is why callers apply this AFTER
    permuting: the masks live in physical array coordinates). Zero masks are
    a value identity, preserving the zero-fault bit-identity invariant.
    """
    if stuck is None:
        return rows_arr
    s0, s1 = stuck
    shape = [1] * rows_arr.ndim
    shape[core_axis] = s0.shape[0]
    shape[-1] = s0.shape[-1]
    if packed:
        return (rows_arr & ~s0.reshape(shape)) | s1.reshape(shape)
    shape[-1] = d
    m0 = hv.unpack(s0, d).astype(bool).reshape(shape)
    m1 = hv.unpack(s1, d).astype(bool).reshape(shape)
    return jnp.where(m1, jnp.uint8(1), jnp.where(m0, jnp.uint8(0), rows_arr))


def _apply_rx_faults(fstate, tx, cores_per_shard: int, q_rx, qmask,
                     core_axis: int):
    """Dead-RX zeroing + failover query remap + fault bank masking.

    A dead core's received copy is zeroed (it answers nothing), then bank i's
    search query is gathered from physical core ``serve_rows[i]`` (global ids,
    same-shard by the `faults.plan_failover` contract; identity = no remap) —
    the query-side dual of the ``bank_rows`` prototype indirection, equally
    recompile-free. ``rx_mask`` joins the PHY quarantine mask so banks with
    no healthy server can never win the top-1. All-healthy state: zero mask,
    identity gather, all-False qmask — value-identical to no faults at all.
    """
    shape = [1] * q_rx.ndim
    shape[core_axis] = cores_per_shard
    q_rx = jnp.where(fstate.dead_rx.reshape(shape),
                     jnp.zeros((), q_rx.dtype), q_rx)
    srl = fstate.serve_rows - tx * cores_per_shard
    q_rx = jnp.take(q_rx, srl, axis=core_axis)
    qmask = fstate.rx_mask if qmask is None else (qmask | fstate.rx_mask)
    return q_rx, qmask


def _group_summaries(cfg: ScaleOutConfig, banks: jax.Array) -> jax.Array:
    """Per-bank coarse summaries: banks [T, C_core, d|W] -> [T, n_grp, d|W].

    Each contiguous `coarse_group`-row block collapses to its strict-majority
    bundle — the block's centroid in Hamming space. Computed in-graph from the
    resident rows (after stuck-at masks / tenant onboarding), so the screen
    always sees what the exact scan sees.
    """
    gs = cfg.coarse_group
    t, c_core, last = banks.shape
    grp = banks.reshape(t, c_core // gs, gs, last)
    members = jnp.moveaxis(grp, 2, 0)                 # [gs, T, n_grp, last]
    return hv.majority_packed(members) if cfg.packed else hv.majority(members)


def _coarse_fine_packed(cfg: ScaleOutConfig, banks, q, bank_rows=None):
    """Two-level packed search: coarse top-keep screen over the group
    summaries (ONE fused top-k launch), exact rescore over only the
    surviving rows. banks [T, C_core, W] (T == G when ``bank_rows`` is None),
    q [G, B, W] -> (dist, row) of each bank's winner, both [G, B] int32.

    Survivor groups are re-sorted ascending and the rescore minimizes ONE
    ``dist*c_core + row`` int32 key, so ties break toward the lowest class
    row exactly like the flat scan — predictions match the flat path whenever
    the screen recalls the true winner, and keep == n_grp is bit-identical.
    With ``bank_rows`` the survivor rows are gathered straight from the bank
    table (advanced indexing), so the expanded [G, C_core, W] view never
    materializes — the same indirection contract as `hamming_topk_banked`.
    """
    gs = cfg.coarse_group
    t, c_core, w = banks.shape
    g, b_l = q.shape[0], q.shape[1]
    n_grp = c_core // gs
    keep = min(cfg.coarse_keep, n_grp)
    summ = _group_summaries(cfg, banks)               # [T, n_grp, W]
    _, gidx = hamming_topk_banked(
        q, summ, k=keep, bank_rows=bank_rows, use_kernel=cfg.use_kernels
    )                                                 # [G, B, keep]
    gidx = jnp.sort(gidx, axis=-1)
    rows = (
        gidx[..., None] * gs + jnp.arange(gs, dtype=jnp.int32)
    ).reshape(g, b_l, keep * gs)
    bidx = jnp.arange(g, dtype=jnp.int32) if bank_rows is None else bank_rows
    cand = banks[bidx[:, None, None], rows]           # [G, B, keep*gs, W]
    x = jnp.bitwise_xor(q[:, :, None, :], cand)
    dist = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    key = jnp.min(dist * c_core + rows, axis=-1)      # single-key first-min
    return key // c_core, key % c_core


def _coarse_fine_unpacked(cfg: ScaleOutConfig, banks, q, bank_rows=None):
    """Unpacked (fp32 bipolar MXU) coarse-to-fine: screen via the summary
    dots, rescore only the surviving rows. banks [T, C_core, d] uint8,
    q [G, B, d] -> (val f32, row i32) of each bank's winner, both [G, B].

    `lax.top_k` is stable (ties keep the lower group) and survivors are
    rescored in ascending row order through the same integer-valued fp32
    bipolar dots as the flat scan, so the (max, argmax) tail reproduces the
    flat first-maximum tie order whenever the winner survives the screen;
    keep == n_grp is bit-identical.
    """
    gs = cfg.coarse_group
    t, c_core, d = banks.shape
    g, b_l = q.shape[0], q.shape[1]
    n_grp = c_core // gs
    keep = min(cfg.coarse_keep, n_grp)
    summ = _group_summaries(cfg, banks)               # [T, n_grp, d]
    summ_g = summ if bank_rows is None else jnp.take(summ, bank_rows, axis=0)
    csims = jax.vmap(
        lambda qc, sc: _local_search(qc, sc, cfg.use_kernels)
    )(q, summ_g)                                      # [G, B, n_grp]
    gidx = jnp.sort(jax.lax.top_k(csims, keep)[1].astype(jnp.int32), axis=-1)
    rows = (
        gidx[..., None] * gs + jnp.arange(gs, dtype=jnp.int32)
    ).reshape(g, b_l, keep * gs)
    bidx = jnp.arange(g, dtype=jnp.int32) if bank_rows is None else bank_rows
    cand = banks[bidx[:, None, None], rows]           # [G, B, keep*gs, d]
    qb = 2.0 * q.astype(jnp.float32) - 1.0
    cb = 2.0 * cand.astype(jnp.float32) - 1.0
    sims = jnp.einsum("gbd,gbrd->gbr", qb, cb)        # integer-valued f32
    val = jnp.max(sims, -1)
    star = jnp.argmax(sims, -1)                       # first max among survivors
    row = jnp.take_along_axis(rows, star[..., None], -1)[..., 0]
    return val, row.astype(jnp.int32)


def _shard_top1(cfg: ScaleOutConfig, cores_per_shard: int, tx, q_rx, protos,
                qmask=None, stuck=None):
    """This shard's local top-1: each core searches its class sub-shard (with
    the M permuted banks when cfg.permuted). Returns (val, idx) — similarity
    value and GLOBAL class index of the shard winner, [B_l] or [B_l, M].

    ``qmask`` [cores_per_shard] bool quarantines cores (True = excluded): a
    quarantined core's candidates are masked BEFORE the core reduction
    (distance -> d + 1 / similarity -> -2d), so a degraded receiver can never
    win the vote for its own classes. An all-False mask is value-identical to
    qmask=None — the controller's release action costs nothing.

    ``stuck`` = (stuck0, stuck1) [cores_per_shard, W] packed column masks:
    stored bits forced to their rail in physical array coordinates
    (`_apply_stuck` — after permuting, so every bank a core stores shares
    its column faults)."""
    c_l = protos.shape[0]
    d = cfg.dim
    b_l = q_rx.shape[1]
    packed = cfg.packed
    assert c_l % cores_per_shard == 0
    c_core = c_l // cores_per_shard
    protos_c = protos.reshape(cores_per_shard, c_core, protos.shape[-1])

    if cfg.permuted:
        # expand each core's memory with the M permuted banks (paper Sec. IV)
        if packed:
            # fused top-1 over all (core, bank) pairs: the grid reduces the
            # class axis in VMEM (and spans the M bank axis too) — the
            # [G, B_l, c_core] distances never reach HBM; the in-memory
            # argmax of the IMC macro. argmin == first-max of sims exactly.
            banks = jnp.stack(
                [hv.permute_packed(protos_c, m) for m in range(cfg.m_tx)], 1
            )  # [n_core, M, c_core, W]
            banks = _apply_stuck(banks, stuck, d, True, 0)
            g = cores_per_shard * cfg.m_tx
            q_rep = jnp.broadcast_to(
                q_rx[:, None], (cores_per_shard, cfg.m_tx) + q_rx.shape[1:]
            ).reshape(g, b_l, -1)
            dmin, amin = hamming_topk_banked(
                q_rep, banks.reshape(g, c_core, -1), use_kernel=cfg.use_kernels
            )  # each [g, B_l]
            dmin = jnp.moveaxis(
                dmin.reshape(cores_per_shard, cfg.m_tx, b_l), 2, 0
            )  # [B_l, n_core, M]
            amin = jnp.moveaxis(
                amin.reshape(cores_per_shard, cfg.m_tx, b_l), 2, 0
            )
            if qmask is not None:
                dmin = jnp.where(qmask[None, :, None], d + 1, dmin)
            val = d - 2 * jnp.min(dmin, 1)                # [B_l, M]
            core_star = jnp.argmin(dmin, 1)               # [B_l, M]
            idx_in_core = jnp.take_along_axis(amin, core_star[:, None, :], 1)[:, 0, :]
        else:
            banks = jnp.stack([hv.permute(protos_c, m) for m in range(cfg.m_tx)], 1)
            # banks: [n_core, M, c_core, d]
            banks = _apply_stuck(banks, stuck, d, False, 0)
            sims = jax.vmap(
                lambda qc, pc: jax.vmap(
                    lambda bank: _local_search(qc, bank, cfg.use_kernels)
                )(pc)
            )(q_rx, banks)  # [n_core, M, B_l, c_core]
            sims = jnp.moveaxis(sims, 2, 0)  # [B_l, n_core, M, c_core]
            val_c = jnp.max(sims, -1)
            idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
            if qmask is not None:
                val_c = jnp.where(qmask[None, :, None], -2.0 * d, val_c)
            val = jnp.max(val_c, 1)                       # [B_l, M]
            core_star = jnp.argmax(val_c, 1)              # [B_l, M]
            idx_in_core = jnp.take_along_axis(idx_c, core_star[:, None, :], 1)[:, 0, :]
        idx = (tx * c_l + core_star * c_core + idx_in_core).astype(jnp.int32)
    else:
        protos_c = _apply_stuck(protos_c, stuck, d, packed, 0)
        if packed or cfg.sparse:
            if cfg.sparse:
                # gather-overlap kernel on the raw index lists — integer- and
                # tie-identical to hamming_topk_banked on the densified
                # queries, so the packed downstream below is shared verbatim
                dmin, amin = sparse_topk_banked(
                    q_rx, protos_c, use_kernel=cfg.use_kernels
                )
            elif cfg.coarse_group:
                dmin, amin = _coarse_fine_packed(cfg, protos_c, q_rx)
            else:
                dmin, amin = hamming_topk_banked(
                    q_rx, protos_c, use_kernel=cfg.use_kernels
                )  # each [n_core, B_l] — distances reduced in VMEM, not HBM
            dmin = jnp.moveaxis(dmin, 1, 0)               # [B_l, n_core]
            amin = jnp.moveaxis(amin, 1, 0)
            if qmask is not None:
                dmin = jnp.where(qmask[None, :], d + 1, dmin)
            val = d - 2 * jnp.min(dmin, -1)               # [B_l]
            core_star = jnp.argmin(dmin, -1)
            idx_in_core = jnp.take_along_axis(amin, core_star[:, None], 1)[:, 0]
        else:
            if cfg.coarse_group:
                vg, rg = _coarse_fine_unpacked(cfg, protos_c, q_rx)
                val_c = jnp.moveaxis(vg, 1, 0)            # [B_l, n_core]
                idx_c = jnp.moveaxis(rg, 1, 0)
            else:
                sims = jax.vmap(
                    lambda qc, pc: _local_search(qc, pc, cfg.use_kernels)
                )(q_rx, protos_c)  # [n_core, B_l, c_core]
                sims = jnp.moveaxis(sims, 1, 0)  # [B_l, n_core, c_core]
                val_c = jnp.max(sims, -1)
                idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
            if qmask is not None:
                val_c = jnp.where(qmask[None, :], -2.0 * d, val_c)
            val = jnp.max(val_c, -1)                      # [B_l]
            core_star = jnp.argmax(val_c, -1)
            idx_in_core = jnp.take_along_axis(idx_c, core_star[:, None], 1)[:, 0]
        idx = (tx * c_l + core_star * c_core + idx_in_core).astype(jnp.int32)
    return val, idx


def _gather_top1(cfg: ScaleOutConfig, val, idx):
    """Global top-1: tiny (value, index) all-gather over the cores."""
    vals = jax.lax.all_gather(val, "model")           # [S_tx, ...]
    idxs = jax.lax.all_gather(idx, "model")
    shard_star = jnp.argmax(vals, 0)
    pred = jnp.take_along_axis(idxs, shard_star[None], 0)[0]
    maxsim = jnp.max(vals, 0) / (2.0 * cfg.dim) + 0.5  # normalize to [0,1]
    return pred, maxsim


def _validate_channel(cfg: ScaleOutConfig, chan) -> None:
    """Shared serve-build validation: combo-wire and M-drop constraints."""
    if chan.wire == "combo":
        if cfg.collective != "psum":
            raise ValueError(
                f"channel={cfg.channel!r} replaces the vote reduction with the "
                f"combo-index psum; collective={cfg.collective!r} does not "
                "apply (use collective='psum')"
            )
        assert cfg.m_tx <= 16, (cfg.m_tx, "constellation table is [N, 2^M]")
    if cfg.m_act != cfg.m_tx:
        if chan.wire == "combo":
            raise ValueError(
                f"m_active={cfg.m_act} needs a vote-wire tier; "
                f"channel={cfg.channel!r} transmits the full {cfg.m_tx}-TX "
                "combo field (its constellation assumes every TX superposes)"
            )
        if not 1 <= cfg.m_act <= cfg.m_tx:
            raise ValueError(f"m_active={cfg.m_act} outside [1, {cfg.m_tx}]")
        if cfg.m_act % 2 == 0:
            raise ValueError(
                f"m_active={cfg.m_act} must be odd (majority votes tie)"
            )


def _validate_coarse(cfg: ScaleOutConfig) -> None:
    """Serve-build validation for the two-level coarse-to-fine search."""
    if not cfg.coarse_group:
        return
    if cfg.permuted:
        raise ValueError(
            "coarse_group requires baseline bundling (permuted banks would "
            "need one summary set per TX signature)"
        )
    if cfg.n_classes % cfg.n_rx_cores:
        raise ValueError(
            f"coarse search needs n_classes ({cfg.n_classes}) divisible by "
            f"n_rx_cores ({cfg.n_rx_cores})"
        )
    c_core = cfg.n_classes // cfg.n_rx_cores
    if cfg.coarse_group < 2 or c_core % cfg.coarse_group:
        raise ValueError(
            f"coarse_group={cfg.coarse_group} must be >= 2 and divide the "
            f"per-core class count {c_core}"
        )
    if cfg.coarse_keep < 1:
        raise ValueError(f"coarse_keep={cfg.coarse_keep} must be >= 1")
    if (cfg.dim + 1) * c_core >= 2**31:
        raise ValueError(
            f"rescore key (dim+1)*c_core = {(cfg.dim + 1) * c_core} would "
            "overflow int32 — shard wider (more RX cores) or shrink dim"
        )


def make_ota_serve(
    mesh: Mesh, cfg: ScaleOutConfig, process=None, faults=None
) -> Callable[..., tuple[jax.Array, ...]]:
    """Build the jitted OTA serve step.

    fn(protos [C, dim] u8, queries [B, S_tx, e_per, dim] u8,
       state phy.ChannelState, key)
      -> (pred, maxsim); pred [B] int32 (baseline) or [B, m_tx] (permuted).
    S_tx = model mesh size; e_per = ceil(m_tx / S_tx) encoders per column; global
    encoder g = column * e_per + j; slots with g >= cfg.m_tx abstain.

    The OTA link itself is the pluggable PHY tier ``cfg.channel``
    (`repro.phy`): ``bsc`` (default) keeps the historical dataflow — vote
    tally over the model axis (psum / guard-bit psum_packed / rs_ag), then a
    per-core BSC at ``state.ber`` — bit-identical to pre-phy serves on the
    same RNG stream; ``ideal`` skips the noise; ``symbol`` replaces the
    psum+BSC pair with the physical channel: ONE int32 psum of the
    per-dimension TX bit-combo (== the constellation superposition, see
    `phy.channel`), then per-core constellation lookup + AWGN +
    decision-region decode from the same ChannelState the analytic BER came
    from. ``state`` is sharded with the cores (`phy.state_spec`).

    With ``cfg.representation == "packed"`` protos/queries are uint32 word arrays
    ([C, dim/32] / [B, S_tx, e_per, dim/32], see `hv.pack`); the bundled query,
    the per-core channel noise, the prototype shards and the local search all
    stay packed (the symbol tier decodes bits, then packs): the top-1 is the
    fused `hamming_topk_banked` Pallas kernel — one launch over all cores (and
    permuted banks) that reduces the class axis in VMEM, so the [G, B, C]
    distance tensor never reaches HBM. The vote tally itself shrinks with
    ``cfg.collective == "psum_packed"`` (guard-bit field packing sized by the
    cfg.m_tx ACTIVE voters, ONE uint32 psum, bit-identical to the int8 psum).
    Predictions and maxsim are bit-identical to the unpacked path on the same
    RNG stream (cfg.noise="exact") across all collective modes.

    ``process`` (a `phy.ChannelProcess`) switches the serve to the LIVING
    channel: the built fn becomes

        fn(protos, queries, pstate phy.ProcessState, key, process_key)
          -> (pred, maxsim, pstate')

    Each call first advances the channel one process step (the per-row RNG is
    ``fold_in(fold_in(process_key, pstate.t), rx)`` — hold ``process_key``
    FIXED across steps and the state sequence is reproducible from
    `phy.rollout` on any mesh), then serves through the evolved
    ``pstate.chan`` with ``pstate.quarantine`` masking quarantined cores out
    of the top-1. The carried pytree structure is fixed, so an N-step serve
    loop compiles ONCE; with `phy.StaticProcess` predictions are bit-identical
    to the process-free fn on the same keys.

    ``faults`` (a `faults.FaultModel`) threads a `faults.FaultState` through
    the step — injected hard faults (dead encoders/cores, stuck prototype
    cells, per-step vote erasures) plus the tolerance machinery (live-voter
    re-bias, ``serve_rows`` failover, ``rx_mask`` bank exclusion; see
    `repro.faults`). The built fn appends ``(fstate, fault_key)`` inputs and
    an evolved ``fstate'`` output after the process arguments:

        fn(protos, queries, state, key, fstate, fault_key)
          -> (pred, maxsim, fstate')                       # process=None
        fn(protos, queries, pstate, key, pkey, fstate, fault_key)
          -> (pred, maxsim, pstate', fstate')              # both

    With `faults.healthy_state` (and any model whose step leaves it healthy)
    predictions are bit-identical to the faults-free fn on the same keys —
    fault evolution consumes only ``fault_key``, never the serve stream.

    ``cfg.representation == "sparse"`` serves ultra-sparse queries as sorted
    int32 index lists ([B, S_tx, e_per, k_max], `core.sparse`): the OTA wire
    becomes `collectives.sparse_index_allgather` + a local O(k log k) sparse
    majority (``collective="index_ag"``; psum/psum_packed remain as dense
    fallbacks for the crossover benchmark), the per-core BSC is the O(k)
    drop+insert channel, and the top-1 is the gather-overlap
    `sparse_topk_banked` kernel over the UNCHANGED packed prototype shards
    [C, dim/32] — predictions are bit-identical to the packed serve at
    channel="ideal" whenever no bundle saturates k_max. "auto" picks sparse
    vs packed per (dim, k_max) from the fitted density crossover
    (`resolve_representation`).
    """
    cfg = resolve_representation(cfg)
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    assert cfg.n_rx_cores % model_size == 0, (cfg.n_rx_cores, model_size)
    cores_per_shard = cfg.n_rx_cores // model_size
    e_per = -(-cfg.m_tx // model_size)
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    packed = cfg.packed
    chan = phy.get_channel(cfg.channel)
    _validate_channel(cfg, chan)
    _validate_coarse(cfg)
    if cfg.sparse and (process is not None or faults is not None):
        raise ValueError(
            "representation='sparse' does not compose with living-channel "
            "processes or fault injection (stuck-at / failover state is "
            "word-addressed dense machinery); use representation='packed'"
        )

    def serve_core(protos, queries, state, key, qmask, fstate=None):
        # protos: [C_l, d|W]; queries: [B_l, 1, e_per, d|W];
        # state: local ChannelState shard (RX-leading leaves [cores_per_shard])
        tx, gids, n_act_local = _tx_ids(cfg, e_per)
        q_mine = queries[:, 0]                      # [B_l, e_per, d|W]
        if cfg.permuted:  # TX g transmits rho^g(q_g) — its signature
            rho = hv.permute_packed if packed else hv.permute
            q_mine = jax.vmap(lambda q, g: rho(q, g), in_axes=(1, 0), out_axes=1)(
                q_mine, gids
            )
        # --- the OTA collective over the encoder/model axis ---
        if cfg.sparse:
            q_bundled = _sparse_bundle(cfg, chan, model_size, e_per, q_mine,
                                       gids, n_act_local)
        else:
            q_bundled = _ota_bundle(cfg, chan, model_size, e_per, q_mine,
                                    gids, n_act_local, fstate)
        # --- per-core decode through the PHY tier ---
        kq = jax.random.fold_in(key, _dpos(mesh, dp))
        if cfg.sparse:
            q_rx = _sparse_rx_fanout(cfg, cores_per_shard, tx, q_bundled,
                                     state, kq)
        else:
            q_rx = _rx_fanout(cfg, chan, cores_per_shard, tx, q_bundled,
                              state, kq)
        # [n_core, B_l, d|W] -> each core searches its class sub-shard
        stuck = None
        if fstate is not None:
            q_rx, qmask = _apply_rx_faults(fstate, tx, cores_per_shard, q_rx,
                                           qmask, 0)
            stuck = (fstate.stuck0, fstate.stuck1)
        val, idx = _shard_top1(cfg, cores_per_shard, tx, q_rx, protos, qmask,
                               stuck)
        # --- global top-1: tiny (value, index) all-gather over the cores ---
        return _gather_top1(cfg, val, idx)

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if process is None and faults is None:
        def body(protos, queries, state, key):
            return serve_core(protos, queries, state, key, None)

        in_specs = (
            P("model", None),                 # prototype shards (the IMC cores)
            P(dp_spec, "model", None, None),  # per-encoder queries
            phy.state_spec("model"),          # per-core channel state
            P(),                              # key
        )
        out_specs = (P(dp_spec), P(dp_spec))
    elif faults is None:
        def body(protos, queries, pstate, key, pkey):
            tx = jax.lax.axis_index("model")
            # evolve the channel one step, THEN serve through the live state
            pstate = process.step(pkey, pstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(protos, queries, pstate.chan, key,
                                      pstate.quarantine)
            return pred, maxsim, pstate

        in_specs = (
            P("model", None),
            P(dp_spec, "model", None, None),
            phy.pstate_spec("model"),         # per-core process state
            P(),                              # serve key
            P(),                              # process key (fixed across steps)
        )
        out_specs = (P(dp_spec), P(dp_spec), phy.pstate_spec("model"))
    elif process is None:
        def body(protos, queries, state, key, fstate, fkey):
            tx = jax.lax.axis_index("model")
            # evolve the faults one step, THEN serve through the live state
            fstate = faults.step(fkey, fstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(protos, queries, state, key, None,
                                      fstate)
            return pred, maxsim, fstate

        in_specs = (
            P("model", None),
            P(dp_spec, "model", None, None),
            phy.state_spec("model"),
            P(),                              # serve key
            faultlib.fstate_spec("model"),    # per-core fault state
            P(),                              # fault key (fixed across steps)
        )
        out_specs = (P(dp_spec), P(dp_spec), faultlib.fstate_spec("model"))
    else:
        def body(protos, queries, pstate, key, pkey, fstate, fkey):
            tx = jax.lax.axis_index("model")
            pstate = process.step(pkey, pstate, rx_base=tx * cores_per_shard)
            fstate = faults.step(fkey, fstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(protos, queries, pstate.chan, key,
                                      pstate.quarantine, fstate)
            return pred, maxsim, pstate, fstate

        in_specs = (
            P("model", None),
            P(dp_spec, "model", None, None),
            phy.pstate_spec("model"),
            P(),                              # serve key
            P(),                              # process key
            faultlib.fstate_spec("model"),
            P(),                              # fault key
        )
        out_specs = (P(dp_spec), P(dp_spec), phy.pstate_spec("model"),
                     faultlib.fstate_spec("model"))

    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


def _shard_top1_slots(cfg: ScaleOutConfig, cores_per_shard: int, tx,
                      q_rx, store, rows, qmask=None, stuck=None):
    """Slot-batched local top-1: slot s searches tenant bank ``rows[s]`` of the
    resident store. ONE `hamming_topk_banked` launch covers every
    (slot, core[, permuted bank]) — the G axis of the kernel grid — via the
    ``bank_rows`` indirection (packed) or a row gather (unpacked MXU path);
    never a vmap over the kernel (its revisited-tile running-min is not
    vmap-safe). Per-slot reductions keep the standalone [B, core(, M), class]
    axis order, so ties break identically to `_shard_top1` on that slot alone.

    q_rx [N, n_core, B_l, d|W]; store [T, C_l, d|W]; rows [N] int32.
    ``qmask`` [cores_per_shard] bool quarantines cores exactly as in
    `_shard_top1` (masked before the core reduction; all slots share the one
    physical link, so one mask covers them all). ``stuck`` applies the
    per-core stuck-at column masks to the resident store (one physical
    crossbar per core — every tenant's rows on it share the core's faults).
    Returns (val, idx) [N, B_l] or [N, B_l, M].
    """
    t, c_l = store.shape[0], store.shape[1]
    last = store.shape[-1]
    d = cfg.dim
    n, b_l = q_rx.shape[0], q_rx.shape[2]
    packed = cfg.packed
    assert c_l % cores_per_shard == 0
    c_core = c_l // cores_per_shard
    core_ids = jnp.arange(cores_per_shard)
    store_c = store.reshape(t, cores_per_shard, c_core, last)

    if cfg.permuted:
        if packed:
            # permute the T-tenant store ONCE (not per slot); bank g of the
            # single launch is (slot, core, m) -> store row rows[slot]
            banks = jnp.stack(
                [hv.permute_packed(store_c, m) for m in range(cfg.m_tx)], 2
            )  # [T, n_core, M, c_core, W]
            banks = _apply_stuck(banks, stuck, d, True, 1)
            bank_rows = (
                (rows[:, None] * cores_per_shard + core_ids[None])[:, :, None]
                * cfg.m_tx + jnp.arange(cfg.m_tx)[None, None]
            ).reshape(-1)
            g = n * cores_per_shard * cfg.m_tx
            q_rep = jnp.broadcast_to(
                q_rx[:, :, None], (n, cores_per_shard, cfg.m_tx) + q_rx.shape[2:]
            ).reshape(g, b_l, last)
            dmin, amin = hamming_topk_banked(
                q_rep, banks.reshape(t * cores_per_shard * cfg.m_tx, c_core, last),
                bank_rows=bank_rows, use_kernel=cfg.use_kernels,
            )  # each [g, B_l]
            dmin = jnp.moveaxis(
                dmin.reshape(n, cores_per_shard, cfg.m_tx, b_l), 3, 1
            )  # [N, B_l, n_core, M]
            amin = jnp.moveaxis(
                amin.reshape(n, cores_per_shard, cfg.m_tx, b_l), 3, 1
            )
            if qmask is not None:
                dmin = jnp.where(qmask[None, None, :, None], d + 1, dmin)
            val = d - 2 * jnp.min(dmin, 2)                # [N, B_l, M]
            core_star = jnp.argmin(dmin, 2)
            idx_in_core = jnp.take_along_axis(
                amin, core_star[:, :, None, :], 2
            )[:, :, 0, :]
        else:
            banks = jnp.stack(
                [hv.permute(store_c, m) for m in range(cfg.m_tx)], 2
            )  # [T, n_core, M, c_core, d]
            banks = _apply_stuck(banks, stuck, d, False, 1)
            banks_n = jnp.take(banks, rows, axis=0)  # [N, n_core, M, c_core, d]
            sims = jax.vmap(jax.vmap(
                lambda qc, pc: jax.vmap(
                    lambda bank: _local_search(qc, bank, cfg.use_kernels)
                )(pc)
            ))(q_rx, banks_n)  # [N, n_core, M, B_l, c_core]
            sims = jnp.moveaxis(sims, 3, 1)  # [N, B_l, n_core, M, c_core]
            val_c = jnp.max(sims, -1)
            idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
            if qmask is not None:
                val_c = jnp.where(qmask[None, None, :, None], -2.0 * d, val_c)
            val = jnp.max(val_c, 2)                       # [N, B_l, M]
            core_star = jnp.argmax(val_c, 2)
            idx_in_core = jnp.take_along_axis(
                idx_c, core_star[:, :, None, :], 2
            )[:, :, 0, :]
    else:
        store_c = _apply_stuck(store_c, stuck, d, packed, 1)
        if packed:
            bank_rows = (
                rows[:, None] * cores_per_shard + core_ids[None]
            ).reshape(-1)
            q_flat = q_rx.reshape(n * cores_per_shard, b_l, last)
            if cfg.coarse_group:
                dmin, amin = _coarse_fine_packed(
                    cfg, store_c.reshape(t * cores_per_shard, c_core, last),
                    q_flat, bank_rows=bank_rows,
                )  # each [N*n_core, B_l]
            else:
                dmin, amin = hamming_topk_banked(
                    q_flat, store_c.reshape(t * cores_per_shard, c_core, last),
                    bank_rows=bank_rows, use_kernel=cfg.use_kernels,
                )  # each [N*n_core, B_l]
            dmin = jnp.moveaxis(dmin.reshape(n, cores_per_shard, b_l), 2, 1)
            amin = jnp.moveaxis(amin.reshape(n, cores_per_shard, b_l), 2, 1)
            if qmask is not None:
                dmin = jnp.where(qmask[None, None, :], d + 1, dmin)
            val = d - 2 * jnp.min(dmin, -1)               # [N, B_l]
            core_star = jnp.argmin(dmin, -1)
            idx_in_core = jnp.take_along_axis(
                amin, core_star[..., None], -1
            )[..., 0]
        else:
            if cfg.coarse_group:
                core_rows = (
                    rows[:, None] * cores_per_shard + core_ids[None]
                ).reshape(-1)
                vg, rg = _coarse_fine_unpacked(
                    cfg, store_c.reshape(t * cores_per_shard, c_core, last),
                    q_rx.reshape(n * cores_per_shard, b_l, last),
                    bank_rows=core_rows,
                )  # each [N*n_core, B_l]
                val_c = jnp.moveaxis(vg.reshape(n, cores_per_shard, b_l), 2, 1)
                idx_c = jnp.moveaxis(rg.reshape(n, cores_per_shard, b_l), 2, 1)
            else:
                protos_n = jnp.take(store_c, rows, axis=0)
                # protos_n: [N, n_core, c_core, d]
                sims = jax.vmap(jax.vmap(
                    lambda qc, pc: _local_search(qc, pc, cfg.use_kernels)
                ))(q_rx, protos_n)  # [N, n_core, B_l, c_core]
                sims = jnp.moveaxis(sims, 2, 1)  # [N, B_l, n_core, c_core]
                val_c = jnp.max(sims, -1)
                idx_c = jnp.argmax(sims, -1).astype(jnp.int32)
            if qmask is not None:
                val_c = jnp.where(qmask[None, None, :], -2.0 * d, val_c)
            val = jnp.max(val_c, -1)                      # [N, B_l]
            core_star = jnp.argmax(val_c, -1)
            idx_in_core = jnp.take_along_axis(
                idx_c, core_star[..., None], -1
            )[..., 0]
    idx = (tx * c_l + core_star * c_core + idx_in_core).astype(jnp.int32)
    return val, idx


def make_mt_ota_serve(mesh: Mesh, cfg: ScaleOutConfig, process=None,
                      faults=None) -> Callable:
    """Build the multi-tenant slot-batched OTA serve step.

    fn(store [T, C, d|W], queries [N, B, S_tx, e_per, d|W], rows [N] i32,
       state phy.ChannelState, keys [N, 2] u32)
      -> (pred, maxsim), each [N, B] (baseline) or [N, B, M] (permuted).

    One launch serves N resident slots against a T-tenant prototype store
    (class axis sharded over ``model`` exactly like the standalone serve —
    every tenant's bank lives on the same IMC cores); slot s searches tenant
    bank ``rows[s]``. Onboarding/eviction edit the store outside this fn
    (``dynamic_update_slice`` of one tenant row — no recompile here).

    Per-slot prediction identity with `make_ota_serve`: the bundle collective
    runs on the slot-flattened [N*B] rows through the SAME stage code
    (elementwise over rows), the PHY fan-out vmaps over slots with slot s's
    own key (vmapped counter-based RNG == the standalone draw for that key),
    and the slot-batched search keeps standalone per-slot reduction order. So
    row s of the output is bit-identical to a standalone serve of slot s's
    queries against its tenant's codebook with key ``keys[s]`` — the lifecycle
    tests pin this across representations and channels.

    ``process`` switches to the living-channel form (see `make_ota_serve`):

        fn(store, queries, rows, pstate, keys, process_key)
          -> (pred, maxsim, pstate')

    ONE process step per serve step — every slot shares the one physical
    link, evolved before the batched decode and searched under the shared
    ``pstate.quarantine`` mask.

    ``faults`` threads a shared `faults.FaultState` exactly as in
    `make_ota_serve` (one fault step per serve step — every slot rides the
    same hardware): the fn appends ``(fstate, fault_key)`` inputs and a
    ``fstate'`` output after the process arguments, and with the all-healthy
    state stays bit-identical to the faults-free build.
    """
    if cfg.representation in ("sparse", "auto"):
        raise ValueError(
            "the multi-tenant serve does not support the sparse "
            "representation (slot-batched bank indirection is a dense-store "
            "contract); use representation='packed'"
        )
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    assert cfg.n_rx_cores % model_size == 0, (cfg.n_rx_cores, model_size)
    cores_per_shard = cfg.n_rx_cores // model_size
    e_per = -(-cfg.m_tx // model_size)
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    packed = cfg.packed
    chan = phy.get_channel(cfg.channel)
    _validate_channel(cfg, chan)
    _validate_coarse(cfg)

    def serve_core(store, queries, rows, state, keys, qmask, fstate=None):
        # store: [T, C_l, d|W]; queries: [N, B_l, 1, e_per, d|W]; rows: [N];
        # keys: [N, 2] — slot s serves with its request's own RNG stream
        n, b_l = queries.shape[0], queries.shape[1]
        tx, gids, n_act_local = _tx_ids(cfg, e_per)
        q_mine = queries[:, :, 0]                   # [N, B_l, e_per, d|W]
        q_flat = q_mine.reshape((n * b_l,) + q_mine.shape[2:])
        if cfg.permuted:  # TX g transmits rho^g(q_g) — its signature
            rho = hv.permute_packed if packed else hv.permute
            q_flat = jax.vmap(lambda q, g: rho(q, g), in_axes=(1, 0), out_axes=1)(
                q_flat, gids
            )
        # --- ONE OTA collective for all slots: elementwise over the flattened
        # [N*B] rows, so each row tallies exactly as its standalone serve ---
        q_bundled = _ota_bundle(cfg, chan, model_size, e_per, q_flat, gids,
                                n_act_local, fstate)
        q_bundled = q_bundled.reshape((n, b_l) + q_bundled.shape[1:])
        # --- PHY fan-out per slot with the slot's own key (RNG identity) ---
        dpos = _dpos(mesh, dp)
        kqs = jax.vmap(lambda k: jax.random.fold_in(k, dpos))(keys)
        q_rx = jax.vmap(
            lambda qb, kq: _rx_fanout(cfg, chan, cores_per_shard, tx, qb,
                                      state, kq)
        )(q_bundled, kqs)  # [N, n_core, B_l, d|W]
        stuck = None
        if fstate is not None:
            q_rx, qmask = _apply_rx_faults(fstate, tx, cores_per_shard, q_rx,
                                           qmask, 1)
            stuck = (fstate.stuck0, fstate.stuck1)
        # --- slot-batched search: one banked launch over (slot, core, bank) ---
        val, idx = _shard_top1_slots(cfg, cores_per_shard, tx, q_rx, store,
                                     rows, qmask, stuck)
        return _gather_top1(cfg, val, idx)

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if process is None and faults is None:
        def body(store, queries, rows, state, keys):
            return serve_core(store, queries, rows, state, keys, None)

        in_specs = (
            P(None, "model", None),                 # tenant store (class-sharded)
            P(None, dp_spec, "model", None, None),  # per-slot encoder queries
            P(),                                    # slot -> store row
            phy.state_spec("model"),                # per-core channel state
            P(),                                    # per-slot keys
        )
        out_specs = (P(None, dp_spec), P(None, dp_spec))
    elif faults is None:
        def body(store, queries, rows, pstate, keys, pkey):
            tx = jax.lax.axis_index("model")
            pstate = process.step(pkey, pstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(store, queries, rows, pstate.chan, keys,
                                      pstate.quarantine)
            return pred, maxsim, pstate

        in_specs = (
            P(None, "model", None),
            P(None, dp_spec, "model", None, None),
            P(),
            phy.pstate_spec("model"),               # per-core process state
            P(),                                    # per-slot keys
            P(),                                    # process key (fixed)
        )
        out_specs = (P(None, dp_spec), P(None, dp_spec),
                     phy.pstate_spec("model"))
    elif process is None:
        def body(store, queries, rows, state, keys, fstate, fkey):
            tx = jax.lax.axis_index("model")
            fstate = faults.step(fkey, fstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(store, queries, rows, state, keys, None,
                                      fstate)
            return pred, maxsim, fstate

        in_specs = (
            P(None, "model", None),
            P(None, dp_spec, "model", None, None),
            P(),
            phy.state_spec("model"),
            P(),                                    # per-slot keys
            faultlib.fstate_spec("model"),          # per-core fault state
            P(),                                    # fault key (fixed)
        )
        out_specs = (P(None, dp_spec), P(None, dp_spec),
                     faultlib.fstate_spec("model"))
    else:
        def body(store, queries, rows, pstate, keys, pkey, fstate, fkey):
            tx = jax.lax.axis_index("model")
            pstate = process.step(pkey, pstate, rx_base=tx * cores_per_shard)
            fstate = faults.step(fkey, fstate, rx_base=tx * cores_per_shard)
            pred, maxsim = serve_core(store, queries, rows, pstate.chan, keys,
                                      pstate.quarantine, fstate)
            return pred, maxsim, pstate, fstate

        in_specs = (
            P(None, "model", None),
            P(None, dp_spec, "model", None, None),
            P(),
            phy.pstate_spec("model"),
            P(),                                    # per-slot keys
            P(),                                    # process key (fixed)
            faultlib.fstate_spec("model"),          # per-core fault state
            P(),                                    # fault key (fixed)
        )
        out_specs = (P(None, dp_spec), P(None, dp_spec),
                     phy.pstate_spec("model"), faultlib.fstate_spec("model"))

    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


def make_wired_serve(
    mesh: Mesh, cfg: ScaleOutConfig
) -> Callable[[jax.Array, jax.Array, phy.ChannelState, jax.Array], tuple[jax.Array, jax.Array]]:
    """Wired-baseline dataflow: queries all-gathered over the NoC, bundled at every
    core (broadcast M·d bytes/trial instead of the OTA psum). Error-free wires —
    the ChannelState rides along for signature parity with `make_ota_serve`
    (matched-physics wired-vs-OTA comparisons thread the same state through
    both) but no PHY noise applies on the NoC.
    Same outputs as `make_ota_serve` (baseline bundling only). Packed
    representation: the NoC broadcast moves d/8 bytes per HV, bundling runs the
    bit-sliced carry-save majority, similarity is XOR+popcount."""
    if cfg.representation in ("sparse", "auto"):
        raise ValueError(
            "the wired baseline has no sparse dataflow (the comparison the "
            "paper draws is dense-field NoC broadcast vs OTA); use "
            "representation='packed'"
        )
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    cores_per_shard = cfg.n_rx_cores // model_size
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    packed = cfg.packed

    e_per = -(-cfg.m_tx // model_size)

    def body(protos, queries, state, key):
        c_l = protos.shape[0]
        d = cfg.dim
        last = queries.shape[-1]
        tx = jax.lax.axis_index("model")
        # --- wired pattern: explicit all-gather (the NoC broadcast bottleneck) ---
        q_all = jax.lax.all_gather(queries[:, 0], "model", axis=0)  # [S_tx, B_l, e, d|W]
        q_act = jnp.moveaxis(q_all, 2, 1).reshape(-1, q_all.shape[1], last)[: cfg.m_tx]
        if packed:
            q_bundled = hv.majority_packed(q_act)
            sims = d - 2 * hamming_search(q_bundled, protos, use_kernel=cfg.use_kernels)
        else:
            q_bundled = majority_bundle(q_act, use_kernel=cfg.use_kernels)
            sims = _local_search(q_bundled, protos, cfg.use_kernels)  # [B_l, C_l]
        val = jnp.max(sims, -1)
        idx = (jnp.argmax(sims, -1) + tx * c_l).astype(jnp.int32)
        vals = jax.lax.all_gather(val, "model")
        idxs = jax.lax.all_gather(idx, "model")
        shard_star = jnp.argmax(vals, 0)
        pred = jnp.take_along_axis(idxs, shard_star[None], 0)[0]
        maxsim = jnp.max(vals, 0) / (2.0 * cfg.dim) + 0.5
        return pred, maxsim

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P("model", None), P(dp_spec, "model", None, None),
                  phy.state_spec("model"), P()),
        out_specs=(P(dp_spec), P(dp_spec)),
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


def make_hdc_train(
    mesh: Mesh, cfg: ScaleOutConfig
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """One-shot HDC 'training': bundle every class's examples into its prototype.

    fn(examples [B, dim] u8, labels [B] i32) -> protos [C, dim] u8 (sharded over
    model). Bipolar per-class sums are psum'd over the data axes (the learning
    analogue of the OTA reduction), then thresholded — majority bundling of all
    examples of a class. Packed representation: examples/protos are uint32 word
    arrays [.., dim/32]; the per-bit tally unpacks transiently, the learned
    prototype shards are stored packed (what the IMC macro would write).
    """
    dp = _dp_axes(mesh)
    manual = set(dp) | {"model"}
    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    assert cfg.n_classes % model_size == 0
    c_l = cfg.n_classes // model_size
    packed = cfg.packed

    def body(examples, labels):
        tx = jax.lax.axis_index("model")
        lo = tx * c_l
        onehot = (labels[:, None] == (lo + jnp.arange(c_l))[None, :]).astype(jnp.int32)
        ex = hv.unpack(examples, cfg.dim) if packed else examples
        bipolar = 2 * ex.astype(jnp.int32) - 1              # [B_l, d]
        sums = jnp.einsum("bc,bd->cd", onehot, bipolar)     # [C_l, d]
        for ax in dp:
            sums = jax.lax.psum(sums, ax)
        protos = (sums > 0).astype(jnp.uint8)
        return hv.pack(protos) if packed else protos

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp_spec, None), P(dp_spec)),
        out_specs=P("model", None),
        axis_names=manual,
        check_vma=False,
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-level helpers (inputs + single-device oracle)
# ---------------------------------------------------------------------------

def make_queries(
    key: jax.Array, cfg: ScaleOutConfig, protos: jax.Array, model_size: int
) -> tuple[jax.Array, jax.Array]:
    """Random trial queries: classes [B, m_tx], queries [B, S_tx, e_per, dim].

    `protos` is the unpacked [C, dim] codebook; with a packed cfg the returned
    queries are bit-packed to [B, S_tx, e_per, dim/32] uint32 (pack the protos
    with `hv.pack` before feeding the packed serve fn). With a sparse cfg the
    SAME classes draw yields sorted index lists [B, S_tx, e_per, k_max] int32
    (`sparse.sparsify` of each class HV — keep-smallest truncation past
    k_max), padded slots all-SENTINEL; feed the serve fn `hv.pack(protos)`.
    """
    k1 = jax.random.fold_in(key, 1)
    e_per = -(-cfg.m_tx // model_size)
    classes = jax.random.randint(k1, (cfg.batch, cfg.m_tx), 0, cfg.n_classes)
    if cfg.sparse:
        codes = sparse.sparsify(protos, cfg.k_max)        # [C, k_max]
        q = codes[classes]                                # [B, M, k_max]
        pad = jnp.full(
            (cfg.batch, model_size * e_per - cfg.m_tx, cfg.k_max),
            sparse.SENTINEL, jnp.int32)
        q = jnp.concatenate([q, pad], axis=1)
        return classes, q.reshape(cfg.batch, model_size, e_per, cfg.k_max)
    q = protos[classes]  # [B, M, d]
    pad = jnp.zeros((cfg.batch, model_size * e_per - cfg.m_tx, cfg.dim), jnp.uint8)
    q = jnp.concatenate([q, pad], axis=1)
    q = q.reshape(cfg.batch, model_size, e_per, cfg.dim)
    return classes, (hv.pack(q) if cfg.packed else q)


def serve_reference(
    cfg: ScaleOutConfig, protos: jax.Array, queries: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single-device noise-free oracle for the distributed serve step.

    Always computes in the unpacked representation; packed (uint32) protos or
    queries are unpacked first, and sparse (int32 index-list) queries are
    densified, so the same oracle serves every dataflow. Sparse queries carry
    the keep-smallest k_max truncation already; the oracle's dense majority
    has no further capacity, so it matches the sparse serve exactly whenever
    no bundle saturates.
    Honors ``cfg.m_active`` (only the first m_act TXs bundle — the M-drop
    oracle); permuted predictions keep all m_tx columns, of which only the
    first m_act are meaningful, matching the serve step.
    """
    if queries.dtype == jnp.int32:    # sparse index lists
        queries = sparse.densify(queries, cfg.dim)
    if queries.dtype == jnp.uint32:
        queries = hv.unpack(queries, cfg.dim)
    if protos.dtype == jnp.uint32:
        protos = hv.unpack(protos, cfg.dim)
    b = queries.shape[0]
    m_act = cfg.m_act
    q_act = queries.reshape(b, -1, cfg.dim)[:, :m_act, :]
    if cfg.permuted:
        shifts = jnp.arange(m_act)
        q_act = jax.vmap(lambda qs: hv.permute_batch(qs, shifts))(q_act)
        q = jnp.moveaxis(q_act, 1, 0)
        counts = jnp.sum(q.astype(jnp.int32), 0)
        bundled = (counts * 2 > m_act).astype(jnp.uint8)
        banks = jnp.stack([hv.permute(protos, m) for m in range(cfg.m_tx)], 0)
        sims = jnp.einsum(
            "bd,mcd->bmc",
            2.0 * bundled.astype(jnp.float32) - 1,
            2.0 * banks.astype(jnp.float32) - 1,
        )
        pred = jnp.argmax(sims, -1).astype(jnp.int32)
        maxsim = jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5
        return pred, maxsim
    q = jnp.moveaxis(q_act, 1, 0)
    counts = jnp.sum(q.astype(jnp.int32), 0)
    bundled = (counts * 2 > m_act).astype(jnp.uint8)
    sims = jnp.einsum(
        "bd,cd->bc",
        2.0 * bundled.astype(jnp.float32) - 1,
        2.0 * protos.astype(jnp.float32) - 1,
    )
    pred = jnp.argmax(sims, -1).astype(jnp.int32)
    maxsim = jnp.max(sims, -1) / (2.0 * cfg.dim) + 0.5
    return pred, maxsim
