"""Pallas TPU kernels for the compute hot-spots of the scale-out HDC system.

Each subpackage has kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py
(jit'd public wrapper with padding + backend dispatch) and ref.py (pure-jnp oracle
used by the allclose test sweeps).

* hamming/      packed XOR+popcount similarity search (memory-bound IMC path),
  incl. the fused top-1 `hamming_topk_banked` (class axis reduced in VMEM —
  the [G, B, C] distance tensor never reaches HBM; EXPERIMENTS.md §Perf)
* majority/     bit-wise majority bundling (the op the paper computes over-the-air)
* assoc_matmul/ bipolar MXU matmul (compute-bound IMC crossbar MVM analogue)
* flash_attention/ fused causal attention fwd (the fix for the dominant
  memory term of EXPERIMENTS.md §Roofline: block temporaries stay in VMEM)
"""
