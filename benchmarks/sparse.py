"""Ultra-sparse vs packed serve: density-crossover grid + the d=10^6 headline.

  PYTHONPATH=src python -m benchmarks.sparse [--fast]

The perf case for `representation="sparse"` (`core.sparse` + kernels/sparse):
at million-dimension, ~0.1%-density hypervectors a query is k_max sorted int32
indices (4*k_max bytes) instead of d/8 packed bytes, the OTA wire is the
`index_ag` all-gather of those lists, and the top-1 is an O(k) gather-overlap
scan instead of an O(d/32) popcount sweep. Four measurements on the 8-device
(2 data x 4 model) host mesh:

* **prediction identity** — the sparse serve (index_ag wire) against the
  packed serve (psum_packed) on the SAME codebook bits and RNG stream at
  channel="ideal": predictions and maxsim must match bit-for-bit (asserted —
  the hard gate in benchmarks/check_regression.py);
* **wire bytes** — compiled-HLO collective bytes/device (hlo_cost) of the
  sparse index_ag vs the packed guard-bit psum at the headline operating
  point: the index wire must be strictly smaller (asserted);
* **(dim, density) trials/s grid** — sparse and packed serve throughput over
  a density sweep at each dim; the per-dim crossover density (where sparse
  stops winning) is log-interpolated from the measured speedups and the
  geometric-mean fit is installable via `scaleout.set_crossover_table`;
* **the headline** — d = 10^6 at 0.1% density: sparse must beat packed by
  >= 5x trials/s (asserted), with the packed cell still RUNNING to prove the
  comparison is live, not vacuous.

`representation="auto"` resolution is exercised against the fitted table.
Artifact: benchmarks/artifacts/sparse.json (uploaded per-PR by the CI
perf-smoke step, gated against BENCH_BASELINE.json's "sparse_crossover" row
by benchmarks/check_regression.py).
"""
from __future__ import annotations

import os

# 8 fake CPU devices BEFORE jax initializes — the serve step needs a real
# data x model mesh for its collectives to exist in the HLO.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import math

from benchmarks.common import save, timed, timed_reps

HEADLINE_DIM = 1_048_576
HEADLINE_DENSITY = 0.001
HEADLINE_MIN_SPEEDUP = 5.0


def _serve_cell(mesh, cfg, protos_u, reps: int):
    """Compile + analyze + time one serve configuration (ideal channel).

    `protos_u` is the shared unpacked codebook — the serve always consumes it
    packed (sparse queries search packed prototype words too), and the sparse
    queries are its `sparsify` image, so both representations see the same
    bits. Returns (stats dict, pred).
    """
    import jax
    import jax.numpy as jnp

    from repro import phy
    from repro.analysis import hlo_cost
    from repro.core import hypervector as hv, scaleout

    model_size = mesh.axis_sizes[mesh.axis_names.index("model")]
    protos = hv.pack(protos_u)
    _, queries = scaleout.make_queries(
        jax.random.PRNGKey(1), cfg, protos_u, model_size)
    state = phy.state_from_ber(
        jnp.full((cfg.n_rx_cores,), 0.01, jnp.float32), cfg.m_tx)
    key = jax.random.PRNGKey(2)

    serve = scaleout.make_ota_serve(mesh, cfg)
    compiled = serve.lower(protos, queries, state, key).compile()
    hc = hlo_cost.analyze_compiled(compiled)

    (pred, _), _ = timed(compiled, protos, queries, state, key)  # warm-up
    _, stats = timed_reps(
        lambda i: compiled(protos, queries, state, jax.random.fold_in(key, i)),
        reps, 0)
    dt = stats["mean_s"]
    return {
        "representation": cfg.representation,
        "collective": cfg.collective,
        "k_max": cfg.k_max,
        "hbm_bytes_per_device": hc.hbm_bytes,
        "collective_bytes_per_device": hc.coll_total,
        "wall_s_per_step": dt,
        "wall_s_std": stats["std_s"],
        "wall_s_min": stats["min_s"],
        "wall_s_max": stats["max_s"],
        "trials_per_s": cfg.batch / dt,
    }, pred


def _pair(mesh, base_cfg, protos_u, k_max: int, reps: int):
    """One sparse/packed cell pair on the same codebook bits."""
    sp_cfg = dataclasses.replace(
        base_cfg, representation="sparse", collective="index_ag", k_max=k_max)
    pk_cfg = dataclasses.replace(
        base_cfg, representation="packed", collective="psum_packed")
    sp, sp_pred = _serve_cell(mesh, sp_cfg, protos_u, reps)
    pk, pk_pred = _serve_cell(mesh, pk_cfg, protos_u, reps)
    return sp, pk, sp_pred, pk_pred


def _sparse_protos(key, n, dim, k_max, density):
    """Dense uint8 codebook whose rows all fit the k_max capacity, so the
    sparse queries are a lossless image of the packed ones (the identity
    precondition)."""
    from repro.core import sparse

    return sparse.densify(
        sparse.random_sparse(key, n, dim, k_max, density), dim)


def _crossover_density(points):
    """Log-interpolated density where speedup crosses 1.0 (None if it never
    does inside the sweep). `points` = [(density, speedup)] sorted ascending."""
    prev = None
    for dens, sp in points:
        if prev is not None:
            (d0, s0), (d1, s1) = prev, (dens, sp)
            if (s0 - 1.0) * (s1 - 1.0) <= 0 and s0 != s1:
                t = (1.0 - s0) / (s1 - s0)
                return float(math.exp(
                    math.log(d0) + t * (math.log(d1) - math.log(d0))))
        prev = (dens, sp)
    return None


def run(fast: bool = False, quiet: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.compat import make_mesh
    from repro.core import scaleout

    n_dev = jax.device_count()
    model_size = 4 if n_dev >= 8 else 1
    data_size = n_dev // model_size
    mesh = make_mesh((data_size, model_size), ("data", "model"))

    reps = 2 if fast else 4
    out: dict = {
        "config": {
            "mesh": f"{data_size}x{model_size}", "m_tx": 3,
            "n_rx_cores": 2 * model_size, "channel": "ideal", "reps": reps,
            "fast": fast,
        },
        "serve": {},
    }

    # --- prediction identity: sparse (index_ag) == packed on the same bits,
    # RNG stream, and ideal channel — the pinned CI scenario -----------------
    id_cfg = scaleout.ScaleOutConfig(
        n_classes=1024, dim=32768, m_tx=3, n_rx_cores=2 * model_size,
        batch=64, channel="ideal", use_kernels=False,
        representation="packed", collective="psum_packed")
    id_kmax = 256  # density 0.002 * 32768 ~= 66 bits/row — ample headroom
    protos_id = _sparse_protos(jax.random.PRNGKey(0), id_cfg.n_classes,
                               id_cfg.dim, id_kmax, 0.002)
    sp, pk, sp_pred, pk_pred = _pair(mesh, id_cfg, protos_id, id_kmax, 1)
    identical = bool(jnp.all(sp_pred == pk_pred))
    out["serve"]["prediction_identical"] = identical
    out["serve"]["identity_scenario"] = {
        "n_classes": id_cfg.n_classes, "dim": id_cfg.dim, "k_max": id_kmax,
        "density": 0.002, "batch": id_cfg.batch,
    }
    assert identical, "sparse serve predictions diverged from packed"
    if not quiet:
        print(f"[serve] sparse (index_ag) == packed predictions at "
              f"d={id_cfg.dim}, k_max={id_kmax}: {identical}")

    # --- (dim, density) grid + crossover fit --------------------------------
    if fast:
        grid_dims = [(16384, 512, 32)]          # (dim, n_classes, batch)
        densities = [0.001, 0.008, 0.0625]
    else:
        grid_dims = [(65536, 1024, 32), (262144, 512, 32)]
        densities = [0.0005, 0.002, 0.008, 0.03125, 0.0625]

    grid = []
    per_dim_cross = {}
    for dim, n_classes, batch in grid_dims:
        base = scaleout.ScaleOutConfig(
            n_classes=n_classes, dim=dim, m_tx=3,
            n_rx_cores=2 * model_size, batch=batch, channel="ideal",
            use_kernels=False, representation="packed",
            collective="psum_packed")
        points = []
        for density in densities:
            k_max = max(64, int(2 * density * dim))
            protos_u = _sparse_protos(
                jax.random.PRNGKey(3), n_classes, dim, k_max, density)
            sp, pk, sp_pred, pk_pred = _pair(mesh, base, protos_u, k_max, reps)
            assert bool(jnp.all(sp_pred == pk_pred)), (dim, density)
            speedup = sp["trials_per_s"] / pk["trials_per_s"]
            cell = {"dim": dim, "density": density, "k_max": k_max,
                    "sparse": sp, "packed": pk, "speedup": speedup}
            grid.append(cell)
            points.append((density, speedup))
            if not quiet:
                print(f"[grid] d={dim} density={density:.4g} k_max={k_max}: "
                      f"sparse {sp['trials_per_s']:.1f}/s  "
                      f"packed {pk['trials_per_s']:.1f}/s  "
                      f"({speedup:.2f}x)")
        per_dim_cross[str(dim)] = _crossover_density(points)
    out["grid"] = grid

    crossings = [c for c in per_dim_cross.values() if c is not None]
    fitted = (float(math.exp(sum(math.log(c) for c in crossings)
                             / len(crossings)))
              if crossings else scaleout.DEFAULT_CROSSOVER["density"])
    out["crossover"] = {"per_dim": per_dim_cross, "density": fitted}
    if not quiet:
        print(f"[crossover] per-dim {per_dim_cross} -> fitted density "
              f"{fitted:.4g} (built-in default "
              f"{scaleout.DEFAULT_CROSSOVER['density']:.4g})")

    # --- auto representation against the fitted table -----------------------
    scaleout.set_crossover_table({"density": fitted})
    try:
        lo = scaleout.resolve_representation(dataclasses.replace(
            id_cfg, representation="auto", collective="psum",
            k_max=max(1, int(fitted * id_cfg.dim / 4))))
        hi = scaleout.resolve_representation(dataclasses.replace(
            id_cfg, representation="auto", collective="psum",
            k_max=min(id_cfg.dim, int(fitted * id_cfg.dim * 4))))
        out["auto"] = {"low_density": lo.representation,
                       "high_density": hi.representation,
                       "low_collective": lo.collective,
                       "high_collective": hi.collective}
        assert lo.representation == "sparse" and lo.collective == "index_ag"
        assert hi.representation == "packed" and hi.collective == "psum_packed"
    finally:
        scaleout.set_crossover_table(None)
    if not quiet:
        print(f"[auto] below-crossover -> {out['auto']['low_density']}/"
              f"{out['auto']['low_collective']}, above -> "
              f"{out['auto']['high_density']}/{out['auto']['high_collective']}")

    # --- the headline: d = 10^6 at 0.1% density -----------------------------
    # batch 32 keeps the cells compute-dominated (smaller batches drown both
    # representations in 8-device dispatch overhead and compress the ratio)
    h_classes, h_batch = 256, 32
    h_kmax = max(64, int(2 * HEADLINE_DENSITY * HEADLINE_DIM))  # 2048
    h_cfg = scaleout.ScaleOutConfig(
        n_classes=h_classes, dim=HEADLINE_DIM, m_tx=3,
        n_rx_cores=2 * model_size, batch=h_batch, channel="ideal",
        use_kernels=False, representation="packed",
        collective="psum_packed")
    protos_h = _sparse_protos(jax.random.PRNGKey(4), h_classes, HEADLINE_DIM,
                              h_kmax, HEADLINE_DENSITY)
    sp, pk, sp_pred, pk_pred = _pair(mesh, h_cfg, protos_h, h_kmax,
                                     max(1, reps // 2))
    assert bool(jnp.all(sp_pred == pk_pred)), "headline identity"
    speedup = sp["trials_per_s"] / pk["trials_per_s"]
    wire_ratio = (pk["collective_bytes_per_device"]
                  / max(sp["collective_bytes_per_device"], 1.0))
    out["headline"] = {
        "dim": HEADLINE_DIM, "density": HEADLINE_DENSITY, "k_max": h_kmax,
        "n_classes": h_classes, "batch": h_batch,
        "sparse": sp, "packed": pk, "speedup": speedup,
        "wire_ratio_packed_over_sparse": wire_ratio,
    }
    # packed must still RUN (a finite measured rate) for the comparison to be
    # live — a crashed/skipped packed cell would make the speedup vacuous
    assert pk["trials_per_s"] > 0 and math.isfinite(pk["trials_per_s"])
    assert speedup >= HEADLINE_MIN_SPEEDUP, (
        f"headline speedup {speedup:.2f}x < {HEADLINE_MIN_SPEEDUP}x at "
        f"d={HEADLINE_DIM}, density={HEADLINE_DENSITY}")
    # the index wire must be strictly smaller than the packed vote field at
    # this density (4*k_max*S bytes vs the guard-bit d-field)
    assert (sp["collective_bytes_per_device"]
            < pk["collective_bytes_per_device"]), (
        sp["collective_bytes_per_device"], pk["collective_bytes_per_device"])
    if not quiet:
        print(f"[headline] d={HEADLINE_DIM} density={HEADLINE_DENSITY} "
              f"(k_max={h_kmax}): sparse {sp['trials_per_s']:.1f}/s  "
              f"packed {pk['trials_per_s']:.1f}/s  ({speedup:.2f}x, "
              f"target >= {HEADLINE_MIN_SPEEDUP}x); wire "
              f"{wire_ratio:.1f}x smaller")

    save("sparse", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI perf-smoke sizes")
    args = ap.parse_args()
    run(fast=args.fast)


if __name__ == "__main__":
    main()
