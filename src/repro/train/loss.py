"""Chunked cross-entropy: full [B, S, V] logits never materialize.

With 262k vocabularies (gemma3) a full logits tensor is ~0.5 PB at the train_4k
cell; instead the sequence is scanned in `chunk`-sized slices, each slice's
logits are produced, consumed and freed (jax.checkpoint recomputes them in the
backward pass). Vocab stays sharded over `model`; the logsumexp and target-gather
reductions over the sharded axis lower to one small all-reduce per chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def _chunk_nll(h, w, targets, valid):
    """h [B,C,d], w [d,V], targets [B,C], valid [B,C] -> (sum nll, sum count)."""
    logits = jnp.einsum("bcd,dv->bcv", h, w, preferred_element_type=jnp.float32)
    logits = shard(logits, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    tgt = jnp.sum(
        jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2) == targets[..., None],
            logits,
            0.0,
        ),
        axis=-1,
    )
    nll = (lse - tgt) * valid
    return jnp.sum(nll), jnp.sum(valid)


def chunked_cross_entropy(
    h: jax.Array,
    w: jax.Array,
    targets: jax.Array,
    *,
    mask: jax.Array | None = None,
    chunk: int = 512,
    ignore_id: int = -1,
) -> jax.Array:
    """Mean token NLL. h [B, S, d]; w [d, V]; targets [B, S] (ignore_id skipped)."""
    b, s, d = h.shape
    c = min(chunk, s)
    while s % c:  # largest divisor <= chunk (vlm text lengths are not 2^k)
        c -= 1
    n = s // c
    valid = (targets != ignore_id).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    tgt = jnp.where(targets == ignore_id, 0, targets)

    hr = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)
    tr = jnp.moveaxis(tgt.reshape(b, n, c), 1, 0)
    vr = jnp.moveaxis(valid.reshape(b, n, c), 1, 0)

    def body(carry, xs):
        tot, cnt = carry
        hc, tc, vc = xs
        nll, k = _chunk_nll(hc, w, tc, vc)
        return (tot + nll, cnt + k), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body), (0.0, 0.0), (hr, tr, vr))
    return tot / jnp.maximum(cnt, 1.0)
