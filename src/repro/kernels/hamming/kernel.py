"""Pallas TPU kernel: batched packed Hamming distance (XOR + popcount).

The associative-memory similarity search of the paper (Fig. 2) over bit-packed
hypervectors. One output tile [bq, bc] is produced per grid step from a query tile
[bq, W] and a prototype tile [bc, W] resident in VMEM; the packed dimension W is
small (d/32 words; 16 words for d=512, 313 for d=10,000) so it is not tiled.

TPU mapping notes:
* uint32 bitwise XOR + population_count lower to the VPU; the [bq, bc, W] intermediate
  stays in VREGs/VMEM (bq=8, bc=128, W<=512 -> <=2 MiB).
* last-dim block sizes are multiples of 128 lanes; bq rides the 8-sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params


def _hamming_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...]  # [bq, W] uint32
    p = p_ref[...]  # [bc, W] uint32
    x = jnp.bitwise_xor(q[:, None, :], p[None, :, :])        # [bq, bc, W]
    pc = jax.lax.population_count(x).astype(jnp.int32)
    o_ref[...] = jnp.sum(pc, axis=-1)


def _hamming_banked_kernel(q_ref, p_ref, o_ref):
    q = q_ref[0]  # [bq, W] uint32 — this bank's query tile
    p = p_ref[0]  # [bc, W] uint32 — this bank's prototype tile
    x = jnp.bitwise_xor(q[:, None, :], p[None, :, :])        # [bq, bc, W]
    pc = jax.lax.population_count(x).astype(jnp.int32)
    o_ref[0] = jnp.sum(pc, axis=-1)


@functools.partial(jax.jit, static_argnames=("bq", "bc", "interpret"))
def hamming_banked_pallas(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int = 8,
    bc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-bank packed Hamming search in ONE kernel launch.

    q [G, B, W] uint32, protos [G, C, W] uint32 -> [G, B, C] int32: bank g's
    queries are searched only against bank g's prototypes. This is the scale-out
    per-IMC-core search ([n_core, B, W] noisy queries x [n_core, C_core, W]
    memory shards) as a single grid (G, B/bq, C/bc) launch — one pipeline over
    all cores instead of a vmap of G tiny calls. B % bq == C % bc == 0.
    """
    g, b, w = q.shape
    g2, c, w2 = protos.shape
    assert g == g2 and w == w2, (q.shape, protos.shape)
    assert b % bq == 0 and c % bc == 0, (b, bq, c, bc)
    grid = (g, b // bq, c // bc)
    return pl.pallas_call(
        _hamming_banked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, w), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bc, w), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, bc), lambda g, i, j: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, b, c), jnp.int32),
        interpret=interpret,
    )(q, protos)


def _topk_banked_kernel(c_real: int, bc: int, q_ref, p_ref, val_ref, idx_ref):
    """Fused top-1 step: revisits the (g, i) output tile across the j grid axis.

    The running (min_dist, argmin) pair lives in the output VMEM tiles — the
    [bq, bc] distance tile is reduced in-register and never reaches HBM (the
    IMC macro's in-memory argmax, Karunaratne et al. 2020). Ties break toward
    the lowest class index: argmin is first-match inside a tile and the strict
    `<` merge keeps the earlier tile, matching `jnp.argmax` on similarities
    (= first minimum of distances) exactly.
    """
    j = pl.program_id(2)
    q = q_ref[0]  # [bq, W] uint32 — this bank's query tile
    p = p_ref[0]  # [bc, W] uint32 — this bank's prototype tile
    x = jnp.bitwise_xor(q[:, None, :], p[None, :, :])        # [bq, bc, W]
    dist = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    # classes beyond c_real are padding: poison them so they can never win
    col = j * bc + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(col < c_real, dist, jnp.int32(2**30))
    loc_v = jnp.min(dist, axis=-1)                           # [bq]
    loc_i = j * bc + jnp.argmin(dist, axis=-1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        val_ref[0] = loc_v
        idx_ref[0] = loc_i

    @pl.when(j > 0)
    def _update():
        better = loc_v < val_ref[0]
        idx_ref[0] = jnp.where(better, loc_i, idx_ref[0])
        val_ref[0] = jnp.where(better, loc_v, val_ref[0])


@functools.partial(jax.jit, static_argnames=("c_real", "bq", "bc", "interpret"))
def hamming_topk_banked_pallas(
    q: jax.Array,
    protos: jax.Array,
    *,
    c_real: int,
    bq: int = 8,
    bc: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-bank fused top-1 Hamming search in ONE kernel launch.

    q [G, B, W] uint32, protos [G, C, W] uint32 -> (min_dist, argmin), each
    [G, B] int32, over bank g's own prototypes. Same grid (G, B/bq, C/bc) as
    `hamming_banked_pallas`, but the class axis is reduced inside the kernel:
    the output tile (indexed by (g, i) only) stays resident in VMEM across the
    j steps and carries the running (min, argmin), so the [G, B, C] distance
    tensor never exists in HBM. `c_real` (<= C) masks zero-padded prototype
    rows. B % bq == C % bc == 0.
    """
    g, b, w = q.shape
    g2, c, w2 = protos.shape
    assert g == g2 and w == w2, (q.shape, protos.shape)
    assert b % bq == 0 and c % bc == 0, (b, bq, c, bc)
    assert 0 < c_real <= c, (c_real, c)
    grid = (g, b // bq, c // bc)
    kernel = functools.partial(_topk_banked_kernel, c_real, bc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, w), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bc, w), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, b), jnp.int32),
            jax.ShapeDtypeStruct((g, b), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, protos)


# Sorted-key buffer sentinel: strictly greater than every real key (real keys
# are bounded by (d+1)*C < 2**31, checked by the caller), so padded classes and
# already-extracted entries can never win a rank. Kept as a Python int —
# a module-level jnp scalar would be captured as a constant by pallas_call.
_KEY_SENTINEL = 2**31 - 1


def _smallest_k(keys: jax.Array, k: int) -> jax.Array:
    """Ascending k smallest entries of keys [..., n] by repeated min-extraction.

    Real keys are globally unique (dist*C + col with distinct cols), so the
    extract-then-poison step retires exactly one real entry per rank; only
    sentinels ever collide, and poisoning a sentinel with a sentinel is a
    no-op. Unrolled k times — k is a small static (the coarse-screen keep).
    """
    sentinel = jnp.int32(_KEY_SENTINEL)
    outs = []
    for _ in range(k):
        m = jnp.min(keys, axis=-1, keepdims=True)
        outs.append(m)
        keys = jnp.where(keys == m, sentinel, keys)
    return jnp.concatenate(outs, axis=-1)


def _topk_k_banked_kernel(c_real: int, c_pad: int, bc: int, k: int,
                          q_ref, p_ref, key_ref):
    """Fused top-k step: the top-1 kernel's scalar carry generalized to a small
    SORTED key buffer per (g, i) output tile.

    The running state is [bq, k] int32 keys ``dist*c_pad + col`` (ascending);
    minimizing keys IS lexicographic (dist, col) order, so every rank keeps the
    first-minimum tie convention of the top-1 kernel. Each j step merges the
    buffer with the tile's bc candidate keys by k repeated min-extractions —
    the [bq, bc] distance tile is consumed in-register and never reaches HBM.
    Padded classes (col >= c_real) carry the sentinel key.
    """
    j = pl.program_id(2)
    q = q_ref[0]  # [bq, W] uint32 — this bank's query tile
    p = p_ref[0]  # [bc, W] uint32 — this bank's prototype tile
    x = jnp.bitwise_xor(q[:, None, :], p[None, :, :])        # [bq, bc, W]
    dist = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    col = j * bc + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    keys = jnp.where(col < c_real, dist * c_pad + col, jnp.int32(_KEY_SENTINEL))

    @pl.when(j == 0)
    def _init():
        key_ref[0] = _smallest_k(keys, k)

    @pl.when(j > 0)
    def _update():
        cand = jnp.concatenate([key_ref[0], keys], axis=-1)  # [bq, k + bc]
        key_ref[0] = _smallest_k(cand, k)


@functools.partial(
    jax.jit, static_argnames=("c_real", "k", "bq", "bc", "interpret")
)
def hamming_topk_k_banked_pallas(
    q: jax.Array,
    protos: jax.Array,
    *,
    c_real: int,
    k: int,
    bq: int = 8,
    bc: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-bank fused top-k Hamming search in ONE kernel launch.

    q [G, B, W] uint32, protos [G, C, W] uint32 -> (dists, idxs), each
    [G, B, k] int32 rank-sorted ascending by (distance, class index), over bank
    g's own prototypes. Same revisited-output-tile scheme as the fused top-1
    (`hamming_topk_banked_pallas`), with the carry widened to a sorted key
    buffer — the [G, B, C] distance tensor never exists in HBM. Requires the
    int32 key encoding to fit: (d+1)*C < 2**31. B % bq == C % bc == 0.
    """
    g, b, w = q.shape
    g2, c, w2 = protos.shape
    assert g == g2 and w == w2, (q.shape, protos.shape)
    assert b % bq == 0 and c % bc == 0, (b, bq, c, bc)
    assert 0 < c_real <= c, (c_real, c)
    assert 1 <= k <= c_real, (k, c_real)
    assert (w * 32 + 1) * c < 2**31, "key encoding would overflow int32"
    grid = (g, b // bq, c // bc)
    kernel = functools.partial(_topk_k_banked_kernel, c_real, c, bc, k)
    keys = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, w), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bc, w), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, k), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, b, k), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, protos)
    return keys // c, keys % c


@functools.partial(jax.jit, static_argnames=("bq", "bc", "interpret"))
def hamming_pallas(
    q: jax.Array,
    protos: jax.Array,
    *,
    bq: int = 8,
    bc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q [B, W] uint32, protos [C, W] uint32 -> [B, C] int32. B % bq == C % bc == 0."""
    b, w = q.shape
    c, w2 = protos.shape
    assert w == w2, (w, w2)
    assert b % bq == 0 and c % bc == 0, (b, bq, c, bc)
    grid = (b // bq, c // bc)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(q, protos)
