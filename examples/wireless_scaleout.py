"""Distributed OTA scale-out on whatever devices this host has.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/wireless_scaleout.py

Runs the paper's Fig. 3b dataflow as a shard_map program: encoders vote over the
model axis (one int8 psum == the OTA transmission), each IMC core decodes its
own noisy copy at its pre-characterized BER, similarity search stays sharded.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hypervector as hv, scaleout
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh()
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

cfg = scaleout.ScaleOutConfig(
    n_classes=256, dim=512, m_tx=3,
    n_rx_cores=64 if 64 % mesh.axis_sizes[1] == 0 else mesh.axis_sizes[1],
    batch=64,
)
key = jax.random.PRNGKey(0)
protos = hv.random_hv(key, cfg.n_classes, cfg.dim)
state = scaleout.precharacterize_state(cfg)  # full ChannelState pytree
print(f"pre-characterized per-core BER: avg {float(state.ber.mean()):.4f}, "
      f"max {float(state.ber.max()):.4f}")

classes, queries = scaleout.make_queries(key, cfg, protos, mesh.axis_sizes[1])
serve = scaleout.make_ota_serve(mesh, cfg)
pred, sim = serve(protos, queries, state, jax.random.PRNGKey(1))
hit = float(jnp.mean(jnp.any(pred[:, None] == classes, axis=1)))
print(f"OTA scale-out (bsc tier): top-1 in sent set for {hit*100:.1f}% "
      f"of {cfg.batch} trials")

# --- the physical channel tier: same state, full constellation + AWGN +
# decision-region decode in-graph instead of the Eq. 1 BSC abstraction ---
serve_s = scaleout.make_ota_serve(mesh, dataclasses.replace(cfg, channel="symbol"))
pred_s, _ = serve_s(protos, queries, state, jax.random.PRNGKey(1))
hit_s = float(jnp.mean(jnp.any(pred_s[:, None] == classes, axis=1)))
print(f"OTA scale-out (symbol tier, physical OTA): top-1 in sent set for "
      f"{hit_s*100:.1f}%")

train = scaleout.make_hdc_train(mesh, cfg)
labels = jnp.arange(cfg.batch, dtype=jnp.int32) % cfg.n_classes
protos_hat = train(protos[labels], labels)
print("one-shot HDC training recovered prototype shards:",
      bool(jnp.all(protos_hat[labels] == protos[labels])))

# --- the bit-packed fast path: same pipeline on uint32 words (d/8 bytes/HV),
# prediction-identical to the unpacked serve on the same RNG stream ---
cfg_p = dataclasses.replace(cfg, representation="packed")
serve_p = scaleout.make_ota_serve(mesh, cfg_p)
pred_p, _ = serve_p(hv.pack(protos), hv.pack(queries), state, jax.random.PRNGKey(1))
print(f"packed fast path ({cfg.dim // 32} uint32 words/HV): predictions identical "
      f"to unpacked: {bool(jnp.all(pred_p == pred))}")
